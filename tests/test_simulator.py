"""Cluster-simulator behaviour tests (the paper's evaluation methodology)."""

import numpy as np
import pytest

from repro.core import simulator as sim
from repro.serving import traces

PROF = sim.profile_for("8b")


def _trace(duration=60.0, rate=4.0, seed=0):
    return traces.burstgpt(duration=duration, base_rate=rate, seed=seed)


def test_conservation_every_request_completes():
    """Every arriving request is prefillled and decodes to completion."""
    tr = _trace(40.0, 3.0)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert len(r.requests) == len(tr)
    for req in r.requests:
        assert req.prefill_done is not None
        assert req.decoded >= req.output  # all tokens emitted


def test_blitz_beats_ssd_scaling():
    """Network multicast scaling must dominate SSD-only scaling on bursts."""
    tr = _trace(60.0, 6.0)
    blitz = sim.run_system(sim.BLITZ, PROF, tr)
    ssd = sim.run_system(sim.SSD_ONLY, PROF, tr)
    assert blitz.mean_ttft() <= ssd.mean_ttft()
    assert blitz.p99_ttft() <= ssd.p99_ttft()


def test_live_scaling_improves_queueing():
    """Live cooperative execution drains queued requests during loading.
    Compared over several bursty seeds (policy feedback can invert a single
    run), live scaling must win on mean TTFT in aggregate."""
    deltas = []
    for seed in range(3):
        tr = _trace(60.0, 10.0, seed=seed)
        live = sim.run_system(sim.BLITZ, PROF, tr)
        nolive = sim.run_system(sim.BLITZ_NOLIVE, PROF, tr)
        deltas.append(nolive.mean_ttft() - live.mean_ttft())
    assert sum(deltas) >= 0.0


def test_o1_cache_vs_sllm_growth():
    """Fig. 19: ServerlessLLM's host cache grows with hosts touched; Blitz
    keeps O(1) (the simulator tracks S-LLM's per-host keepalive cache)."""
    tr = _trace(60.0, 8.0)
    sllm = sim.run_system(sim.SLLM, PROF, tr)
    blitz = sim.run_system(sim.BLITZ, PROF, tr)
    assert blitz.host_cache_total() <= PROF.param_bytes  # <= one copy
    # S-LLM touches >= 1 host caches under bursts
    assert sllm.host_cache_total() >= PROF.param_bytes


def test_fixed_system_never_scales():
    tr = _trace(30.0, 2.0)
    r = sim.run_system(sim.fixed_system("fixed", 2, 2), PROF, tr)
    assert r.scale_events == 0
    assert all(n_p == 2 for _, n_p, _ in r.timeline)


def test_scaling_stop_sweep_monotone():
    """Fig. 3 methodology: longer scaling stops -> worse mean TTFT."""
    tr = _trace(60.0, 6.0, seed=3)
    ttfts = []
    for delay in (0.1, 2.0, 12.8):
        r = sim.run_system(sim.delay_system(delay), PROF, tr)
        ttfts.append(r.mean_ttft())
    assert ttfts[0] <= ttfts[1] <= ttfts[2]


def test_gpu_time_accounting():
    tr = _trace(30.0, 2.0)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert r.gpu_time_s > 0
    # autoscaled usage is below always-max provisioning
    full = sim.run_system(sim.fixed_system("full", 16, 16), PROF, tr)
    assert r.gpu_time_s < full.gpu_time_s


def test_multicast_plan_used_for_batch_scales():
    tr = _trace(60.0, 10.0, seed=5)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert r.scale_events > 0
    assert r.net_scale_bytes > 0


def test_per_request_kv_flows_replace_background_streams():
    """Request-granular serving traffic: every served request ships its
    actual KV volume over the network (bounded by the trace's total), and
    no persistent background stream exists anymore; the legacy flag
    restores the PR-3 background-stream model."""
    tr = _trace(40.0, 3.0)
    s = sim.Simulator(sim.BLITZ, PROF, seed=0)
    r = s.run(tr)
    total = sum(traces.request_kv_bytes(p, PROF.kv_bytes_per_token)
                for _, p, _ in tr)
    assert 0 < r.kv_stream_bytes <= total
    assert not s._serving_flows  # no persistent streams in kv mode
    for req in r.requests:
        assert req.prefill_done is not None and req.decoded >= req.output

    legacy = sim.Simulator(sim.BLITZ, PROF, seed=0, per_request_kv=False)
    rl = legacy.run(tr)
    assert rl.kv_stream_bytes == 0.0
    assert legacy._serving_flows  # background streams still synced


def test_latency_terms_stretch_scale_up_times():
    """Per-hop latency adds a floor to every multicast hop: the same trace
    under 5 ms/hop propagation must show strictly larger mean scale-up
    duration, while zero latency reproduces the default exactly."""
    tr = _trace(60.0, 6.0, seed=3)
    base = sim.run_system(sim.BLITZ, PROF, tr)
    lat = sim.Simulator(
        sim.BLITZ, PROF, seed=0, link_latency_s=5e-3, switch_latency_s=1e-3
    ).run(tr)
    zero = sim.Simulator(
        sim.BLITZ, PROF, seed=0, link_latency_s=0.0, switch_latency_s=0.0
    ).run(tr)
    assert base.scale_events > 0 and lat.scale_events > 0
    # compare the FIRST scale event: both runs are identical up to that
    # point, so its duration isolates the latency floor (later events sit
    # on diverged autoscaler trajectories and are not comparable)
    assert lat.scale_seconds[0] > base.scale_seconds[0]
    assert zero.scale_seconds == base.scale_seconds
    for req in lat.requests:
        assert req.decoded >= req.output  # realism never drops a request


def test_dead_kv_source_pays_a_re_prefill_not_a_free_handoff():
    """When the device holding a request's frozen KV dies, the request is
    re-prefilled on a healthy instance (compute time paid, KV re-routed
    from the new device) — it does NOT teleport to decode for free."""
    tr = _trace(40.0, 4.0, seed=2)
    s = sim.Simulator(sim.BLITZ, PROF, seed=0)

    def kill_first_prefill(s_):
        pres = s_._active_instances("prefill")
        if pres:
            s_.flowsim.fail_device(pres[0].device_ids[0], s_.now)

    # repeated kills across the burst guarantee some handoff hits a dead src
    for t in (6.0, 8.0, 10.0):
        s.schedule(t, kill_first_prefill)
    r = s.run(tr)
    assert r.kv_re_prefills > 0
    done = sum(1 for req in r.requests if req.decoded >= req.output)
    assert done >= 0.9 * len(r.requests)  # the cluster still serves


@pytest.mark.parametrize("name", ["burstgpt", "azure_code", "azure_conv"])
def test_traces_have_burst_structure(name):
    tr = traces.TRACES[name](duration=120.0, seed=1)
    assert len(tr) > 50
    times = np.array([t for t, _, _ in tr])
    # rate in 5s windows varies at least 3x (bursty by construction)
    hist, _ = np.histogram(times, bins=int(120 / 5))
    nonzero = hist[hist > 0]
    # azure_conv is continuous surges (paper: "bursts continuously arrive"),
    # so its peak/median ratio is lower than the isolated-burst traces
    factor = 2.0 if name == "azure_conv" else 3.0
    assert nonzero.max() >= factor * max(np.median(nonzero), 1)
