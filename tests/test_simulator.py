"""Cluster-simulator behaviour tests (the paper's evaluation methodology)."""

import numpy as np
import pytest

from repro.core import simulator as sim
from repro.serving import traces

PROF = sim.profile_for("8b")


def _trace(duration=60.0, rate=4.0, seed=0):
    return traces.burstgpt(duration=duration, base_rate=rate, seed=seed)


def test_conservation_every_request_completes():
    """Every arriving request is prefillled and decodes to completion."""
    tr = _trace(40.0, 3.0)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert len(r.requests) == len(tr)
    for req in r.requests:
        assert req.prefill_done is not None
        assert req.decoded >= req.output  # all tokens emitted


def test_blitz_beats_ssd_scaling():
    """Network multicast scaling must dominate SSD-only scaling on bursts."""
    tr = _trace(60.0, 6.0)
    blitz = sim.run_system(sim.BLITZ, PROF, tr)
    ssd = sim.run_system(sim.SSD_ONLY, PROF, tr)
    assert blitz.mean_ttft() <= ssd.mean_ttft()
    assert blitz.p99_ttft() <= ssd.p99_ttft()


def test_live_scaling_improves_queueing():
    """Live cooperative execution drains queued requests during loading.
    Compared over several bursty seeds (policy feedback can invert a single
    run), live scaling must win on mean TTFT in aggregate."""
    deltas = []
    for seed in range(3):
        tr = _trace(60.0, 10.0, seed=seed)
        live = sim.run_system(sim.BLITZ, PROF, tr)
        nolive = sim.run_system(sim.BLITZ_NOLIVE, PROF, tr)
        deltas.append(nolive.mean_ttft() - live.mean_ttft())
    assert sum(deltas) >= 0.0


def test_o1_cache_vs_sllm_growth():
    """Fig. 19: ServerlessLLM's host cache grows with hosts touched; Blitz
    keeps O(1) (the simulator tracks S-LLM's per-host keepalive cache)."""
    tr = _trace(60.0, 8.0)
    sllm = sim.run_system(sim.SLLM, PROF, tr)
    blitz = sim.run_system(sim.BLITZ, PROF, tr)
    assert blitz.host_cache_total() <= PROF.param_bytes  # <= one copy
    # S-LLM touches >= 1 host caches under bursts
    assert sllm.host_cache_total() >= PROF.param_bytes


def test_fixed_system_never_scales():
    tr = _trace(30.0, 2.0)
    r = sim.run_system(sim.fixed_system("fixed", 2, 2), PROF, tr)
    assert r.scale_events == 0
    assert all(n_p == 2 for _, n_p, _ in r.timeline)


def test_scaling_stop_sweep_monotone():
    """Fig. 3 methodology: longer scaling stops -> worse mean TTFT."""
    tr = _trace(60.0, 6.0, seed=3)
    ttfts = []
    for delay in (0.1, 2.0, 12.8):
        r = sim.run_system(sim.delay_system(delay), PROF, tr)
        ttfts.append(r.mean_ttft())
    assert ttfts[0] <= ttfts[1] <= ttfts[2]


def test_gpu_time_accounting():
    tr = _trace(30.0, 2.0)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert r.gpu_time_s > 0
    # autoscaled usage is below always-max provisioning
    full = sim.run_system(sim.fixed_system("full", 16, 16), PROF, tr)
    assert r.gpu_time_s < full.gpu_time_s


def test_multicast_plan_used_for_batch_scales():
    tr = _trace(60.0, 10.0, seed=5)
    r = sim.run_system(sim.BLITZ, PROF, tr)
    assert r.scale_events > 0
    assert r.net_scale_bytes > 0


@pytest.mark.parametrize("name", ["burstgpt", "azure_code", "azure_conv"])
def test_traces_have_burst_structure(name):
    tr = traces.TRACES[name](duration=120.0, seed=1)
    assert len(tr) > 50
    times = np.array([t for t, _, _ in tr])
    # rate in 5s windows varies at least 3x (bursty by construction)
    hist, _ = np.histogram(times, bins=int(120 / 5))
    nonzero = hist[hist > 0]
    # azure_conv is continuous surges (paper: "bursts continuously arrive"),
    # so its peak/median ratio is lower than the isolated-burst traces
    factor = 2.0 if name == "azure_conv" else 3.0
    assert nonzero.max() >= factor * max(np.median(nonzero), 1)
