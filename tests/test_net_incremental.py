"""Incremental FlowSim engine == reference engine, bit for bit.

The fleet-scale engine (``incremental=True``, the default) re-solves only
the bottleneck component an event touches and replaces the per-step linear
min/done scans with an event calendar.  Its contract is EXACT equivalence:
same rates (float for float), same event stream, same completion times as
the pre-refactor full-solve engine, which survives as ``incremental=False``.

This module drives both engines through mirrored randomized op sequences
(starts, batched starts, removals, advances, degrades, failures with
reroutes, recoveries) and asserts lockstep equality after every op — as
seeded deterministic tests that always run, and as a hypothesis property
when hypothesis is installed.  It also pins the two satellite fixes that
rode along with the refactor: the live/estimator completion-epsilon
unification (``flow_done_eps``) and the reroute latency re-charge +
``FLOW_REROUTED`` emission on path failover.
"""

import math
import random

import pytest

from repro.core import topology as tp
from repro.net import (
    DEV_IN,
    DEV_OUT,
    FLOW_REROUTED,
    LEAF_UP,
    LINK_FAILED,
    Flow,
    FlowEventLog,
    FlowKind,
    FlowSim,
    flow_done_eps,
    maxmin_rates,
)

GB = 1e9


def _flat_cluster(n_devs: int, *, hosts_per_leaf: int = 2, bw: float = 8.0):
    return tp.make_cluster(n_devs, 1, hosts_per_leaf=hosts_per_leaf, bw_gbps=bw)


# ---------------------------------------------------------------------------
# Mirrored-op differential driver
# ---------------------------------------------------------------------------


def _assert_lockstep(a: FlowSim, b: FlowSim):
    """Exact state equality between the incremental and reference engines."""
    assert a.now == b.now
    assert [f.tag for f in a.flows] == [f.tag for f in b.flows]
    # the headline claim: identical allocations, float for float
    assert [f.rate for f in a.flows] == [f.rate for f in b.flows]
    assert [f.remaining for f in a.flows] == [f.remaining for f in b.flows]
    assert [f.active_at for f in a.flows] == [f.active_at for f in b.flows]
    assert a.next_event_time() == b.next_event_time()
    assert (a.completed_count, a.aborted_count) == (
        b.completed_count,
        b.aborted_count,
    )


def _assert_indices_coherent(sim: FlowSim):
    """The link/endpoint indices agree with a from-scratch linear scan."""
    for key, d in sim._link_flows.items():
        expect = {f for f in sim.flows if any(l.key == key for l in f.path)}
        assert set(d) == expect, key
    for f in sim.flows:
        for l in f.path:
            assert f in sim._link_flows[l.key]
        assert f in sim._src_flows[f.src]
        assert f in sim._dst_flows[f.dst]


def _assert_rates_match_full_solve(sim: FlowSim):
    """Incremental per-component rates == one fresh full progressive-filling
    solve over the current active set (exact equality — the component
    decomposition argument, checked empirically)."""
    active = [f for f in sim.flows if f.active_at is None]
    fresh = maxmin_rates([f.path for f in active])
    assert [f.rate for f in active] == fresh


def _run_mirrored(seed: int, *, n_devs=8, n_ops=40, latency=0.0, planes=1):
    rng = random.Random(seed)
    kw = dict(link_latency_s=latency, spine_planes=planes)
    a = FlowSim(_flat_cluster(n_devs), incremental=True, **kw)
    b = FlowSim(_flat_cluster(n_devs), incremental=False, **kw)
    assert a.incremental and not b.incremental
    la, lb = FlowEventLog(), FlowEventLog()
    a.subscribe(la)
    b.subscribe(lb)
    done_a, done_b = [], []
    a_by_tag, b_by_tag = {}, {}
    uid = 0

    def mk_pair(src, dst, size):
        nonlocal uid
        tag = f"f{uid}"
        uid += 1
        fa = Flow(FlowKind.KV_MIGRATION, src, dst, size, tag=tag,
                  on_complete=lambda f, t: done_a.append((f.tag, t)))
        fb = Flow(FlowKind.KV_MIGRATION, src, dst, size, tag=tag,
                  on_complete=lambda f, t: done_b.append((f.tag, t)))
        a_by_tag[tag], b_by_tag[tag] = fa, fb
        return fa, fb

    def rand_size():
        r = rng.random()
        if r < 0.1:
            return math.inf  # persistent background stream
        if r < 0.2:
            return 1e-10  # sub-epsilon payload (instant-ish completion)
        return rng.uniform(0.05, 4.0) * GB

    for _ in range(n_ops):
        op = rng.random()
        if op < 0.35:  # start one flow
            src, dst = rng.randrange(n_devs), rng.randrange(n_devs)
            fa, fb = mk_pair(src, dst, rand_size())
            a.start(fa, a.now)
            b.start(fb, b.now)
        elif op < 0.5:  # batched start (one re-solve for the whole batch)
            batch_a, batch_b = [], []
            for _ in range(rng.randint(2, 5)):
                src, dst = rng.randrange(n_devs), rng.randrange(n_devs)
                fa, fb = mk_pair(src, dst, rand_size())
                batch_a.append(fa)
                batch_b.append(fb)
            a.start_many(batch_a)
            b.start_many(batch_b)
        elif op < 0.7:  # advance (sometimes exactly onto the next event)
            nxt = a.next_event_time()
            if nxt is not None and math.isfinite(nxt) and rng.random() < 0.4:
                t = nxt
            else:
                t = a.now + rng.uniform(0.0, 2.0)
            a.advance_to(t)
            b.advance_to(t)
        elif op < 0.8:  # withdraw a random live flow
            if a.flows:
                tag = rng.choice([f.tag for f in a.flows])
                ab = rng.random() < 0.5
                a.remove(a_by_tag[tag], abort=ab)
                b.remove(b_by_tag[tag], abort=ab)
        elif op < 0.88:  # degrade / restore a random NIC
            key = (rng.choice([DEV_OUT, DEV_IN]), rng.randrange(n_devs))
            a.degrade_link(key, rng.choice([0.0, 0.25, 1.0]))
            b.degrade_link(key, a.net.link(key).degrade)
        elif op < 0.96:  # fail + recover a device (aborts and/or reroutes)
            dev = rng.randrange(n_devs)
            a.fail_device(dev)
            b.fail_device(dev)
            if rng.random() < 0.7:
                a.recover_device(dev)
                b.recover_device(dev)
        else:  # fail one spine uplink plane (reroute when planes > 1)
            leaf = rng.choice(sorted({d.leaf for d in a.net.topo.devices}))
            plane = rng.randrange(planes)
            key = (LEAF_UP, leaf, plane)
            a.fail_link(key)
            b.fail_link(key)
            if rng.random() < 0.7:
                a.recover_link(key)
                b.recover_link(key)
        _assert_lockstep(a, b)
        _assert_indices_coherent(a)
        _assert_rates_match_full_solve(a)
    a.advance_to(a.now + 1e4)
    b.advance_to(b.now + 1e4)
    _assert_lockstep(a, b)
    # identical event streams, rendered bit-for-bit (repr floats)
    assert la.lines() == lb.lines()
    # completion callbacks fired in the same order at the same instants
    assert [t for t, _ in zip(done_a, done_b)] == done_a  # same length
    assert done_a == done_b
    return a


@pytest.mark.parametrize("seed", range(8))
def test_incremental_engine_matches_reference_randomized(seed):
    _run_mirrored(seed)


@pytest.mark.parametrize("seed", range(4))
def test_incremental_engine_matches_reference_with_latency_and_planes(seed):
    # first-byte setup latency exercises the activation calendar; two spine
    # planes exercise load-balanced routing and failover reroutes
    _run_mirrored(100 + seed, latency=1e-4, planes=2)


def test_incremental_engine_matches_reference_large_sparse():
    # many disjoint bottleneck components: the regime the incremental
    # engine exists for — still exact
    sim = _run_mirrored(7, n_devs=24, n_ops=60)
    assert sim.completed_count > 0


# ---------------------------------------------------------------------------
# Completion-epsilon parity (live engine vs what-if estimator)
# ---------------------------------------------------------------------------


def test_flow_done_eps_is_the_shared_threshold():
    assert flow_done_eps(0.0) == 1e-9
    assert flow_done_eps(1e-10) == 1e-9  # tiny flows clamp at the floor
    assert flow_done_eps(4e9) == 4.0  # large flows scale with size


@pytest.mark.parametrize("nbytes", [1e-10, 1.0, 1e6, GB, 512 * GB])
@pytest.mark.parametrize("latency", [0.0, 2.5e-4])
def test_estimator_matches_realized_time_uncontended(nbytes, latency):
    sim = FlowSim(_flat_cluster(4), link_latency_s=latency)
    est = sim.estimate_transfer_time(0, 1, nbytes)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 1, nbytes), 0.0)
    sim.advance_to(est * 4 + 10.0)
    assert f.done
    assert f.finished_at == pytest.approx(est, rel=1e-9, abs=1e-12)


def test_estimator_matches_realized_time_under_contention():
    """The boundary case the old per-step epsilon got wrong: a flow whose
    final segment (after a competitor departs) is far smaller than its
    total size, so a threshold relative to the *remaining* bytes disagrees
    with the live engine's size-relative one."""
    sim = FlowSim(_flat_cluster(4))
    # competitor on the same ingress: both share dev 1's NIC until it lands
    sim.start(Flow(FlowKind.KV_MIGRATION, 2, 1, 0.5 * GB), 0.0)
    nbytes = 100 * GB
    est = sim.estimate_transfer_time(0, 1, nbytes)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 1, nbytes), 0.0)
    sim.advance_to(est * 2 + 10.0)
    assert f.done
    assert f.finished_at == pytest.approx(est, rel=1e-9)


def test_estimator_live_parity_on_shared_sub_epsilon_boundary():
    # a payload sitting exactly on the live done-zone boundary is "done" to
    # both sides — the estimator must not predict a longer transfer than
    # the engine realizes (the old divergence was exactly here)
    sim = FlowSim(_flat_cluster(4))
    nbytes = 1e-10  # below flow_done_eps floor -> completes on first step
    est = sim.estimate_transfer_time(0, 1, nbytes)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 1, nbytes), 0.0)
    sim.advance_to(1.0)
    assert f.done
    assert f.finished_at == pytest.approx(est, abs=1e-9)


# ---------------------------------------------------------------------------
# Reroute fix: latency re-charge + FLOW_REROUTED emission
# ---------------------------------------------------------------------------


def test_reroute_recharges_first_byte_latency_and_emits_event():
    # cross-leaf path with 2 planes; per-link 0.25s -> 1.0s first-byte setup
    topo = tp.make_cluster(4, 1, hosts_per_leaf=1, bw_gbps=8.0)
    sim = FlowSim(topo, link_latency_s=0.25, spine_planes=2)
    log = sim.subscribe(FlowEventLog())
    f = sim.start(Flow(FlowKind.COLD_START, 0, 2, GB, tag="x"), 0.0)
    assert f.active_at == pytest.approx(1.0)  # 4 hops x 0.25s
    plane0 = next(l for l in f.path if l.is_spine).key
    # plane 0 dies at t=0.5 while the first byte is still in flight: the
    # flow fails over to plane 1 and its setup clock RESTARTS — the old
    # engine kept the dead path's active_at (finishing impossibly early)
    sim.fail_link(plane0, 0.5)
    assert not f.aborted and f in sim.flows
    assert all(not l.failed for l in f.path)
    assert f.active_at == pytest.approx(1.5)  # 0.5 + fresh 1.0s setup
    kinds = [e.kind for e in log.events]
    assert kinds.index(FLOW_REROUTED) < kinds.index(LINK_FAILED)
    (rr,) = log.iter_kinds(FLOW_REROUTED)
    assert rr.flow is f and rr.t == pytest.approx(0.5)
    sim.advance_to(10.0)
    # 1.5s activate + 2s transfer (the per-plane uplink carries 0.5 GB/s)
    assert f.finished_at == pytest.approx(3.5)


def test_reroute_of_active_flow_does_not_recharge_latency():
    # a flow already past its setup keeps streaming: failover changes its
    # path, not its activation state
    topo = tp.make_cluster(4, 1, hosts_per_leaf=1, bw_gbps=8.0)
    sim = FlowSim(topo, link_latency_s=0.25, spine_planes=2)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 2, GB), 0.0)
    sim.advance_to(1.5)  # active since t=1.0 at the 0.5 GB/s plane share
    plane0 = next(l for l in f.path if l.is_spine).key
    sim.fail_link(plane0, 1.5)
    assert not f.aborted and f.active_at is None
    sim.advance_to(10.0)
    assert f.finished_at == pytest.approx(3.0)  # no second setup charge


# ---------------------------------------------------------------------------
# Hypothesis property (skipped when hypothesis is absent, like test_net)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_devs=st.integers(4, 16),
        latency=st.sampled_from([0.0, 1e-4]),
        planes=st.integers(1, 2),
    )
    def test_incremental_matches_reference_property(seed, n_devs, latency, planes):
        _run_mirrored(seed, n_devs=n_devs, n_ops=25, latency=latency, planes=planes)
