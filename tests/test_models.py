"""Per-arch smoke tests (reduced configs) + model numerics properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_config
from repro.models import transformer as TF
from repro.models import mamba2
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


def _frames_for(cfg, b):
    if cfg.family in ("vlm", "encdec"):
        n = max(cfg.n_frontend_tokens, 4)
        return jax.random.normal(KEY, (b, n, cfg.d_model), cfg.dtype) * 0.02
    return None


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_arch_smoke_forward_prefill_decode(arch):
    """One forward + train loss + prefill + decode step per architecture:
    shapes correct, outputs finite."""
    cfg = get_config(arch, reduced=True)
    params = TF.init_params(KEY, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    frames = _frames_for(cfg, b)

    logits, aux = TF.train_forward(cfg, params, tokens, frames)
    assert logits.shape == (b, s, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss = TF.lm_loss(cfg, params, tokens, tokens, frames)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0

    caches = TF.init_caches(cfg, b, s + 8)
    nxt, caches = TF.prefill(cfg, params, tokens, caches, frames)
    assert nxt.shape == (b,) and int(nxt.max()) < cfg.vocab_size
    nxt2, caches = TF.decode_step(cfg, params, nxt, caches)
    assert nxt2.shape == (b,) and int(nxt2.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["granite-8b", "minicpm3-4b", "mamba2-370m", "zamba2-2.7b"])
def test_prefill_then_decode_matches_longer_prefill(arch):
    """KV-cache correctness: prefill(S)+decode(1) must predict the same
    next-token as prefill(S+1) given teacher-forced input."""
    cfg = get_config(arch, reduced=True)
    params = TF.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab_size)

    caches = TF.init_caches(cfg, b, s + 8)
    _, caches = TF.prefill(cfg, params, tokens[:, :s], caches)
    nxt_inc, _ = TF.decode_step(cfg, params, tokens[:, s], caches)

    caches2 = TF.init_caches(cfg, b, s + 8)
    nxt_full, _ = TF.prefill(cfg, params, tokens, caches2)
    np.testing.assert_array_equal(np.asarray(nxt_inc), np.asarray(nxt_full))


def test_gqa_equals_mha_when_kv_equals_heads():
    """GQA with kv=h is plain MHA: grouped attention must equal reference."""
    from repro.models.layers import attention_reference, chunked_attention

    b, s, h, d = 2, 33, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d))
    out_c = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=16)
    out_r = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out_c, out_r, atol=2e-5, rtol=2e-5)


def test_mamba2_chunked_equals_sequential():
    """SSD chunked algorithm == naive per-token recurrence."""
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a = -jnp.exp(jax.random.uniform(k3, (h,)))
    b_in = jax.random.normal(k4, (b, s, g, n))
    c_in = jax.random.normal(k5, (b, s, g, n))
    y_chunk, h_chunk = mamba2.ssd_chunked(x, dt, a, b_in, c_in, chunk=16)
    y_seq, h_seq = mamba2.ssd_reference(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(y_chunk, y_seq, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_chunk, h_seq, atol=1e-4, rtol=1e-4)


def test_mamba2_decode_continues_prefill():
    """Recurrent decode from the prefill state == prefill over the longer
    sequence (state-space consistency)."""
    cfg = get_config("mamba2-370m", reduced=True)
    params = TF.init_params(jax.random.PRNGKey(5), cfg)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(6), (b, s + 1), 0, cfg.vocab_size)
    caches = TF.init_caches(cfg, b, s + 8)
    _, caches = TF.prefill(cfg, params, tokens[:, :s], caches)
    nxt_inc, _ = TF.decode_step(cfg, params, tokens[:, s], caches)
    caches2 = TF.init_caches(cfg, b, s + 8)
    nxt_full, _ = TF.prefill(cfg, params, tokens, caches2)
    np.testing.assert_array_equal(np.asarray(nxt_inc), np.asarray(nxt_full))


def test_moe_router_prob_mass_and_aux_loss():
    from repro.models import moe

    cfg = get_config("olmoe-1b-7b", reduced=True)
    params_tree = TF.init_params(KEY, cfg)
    lp = jax.tree.map(lambda x: x[0], params_tree["layers"])  # layer 0
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), cfg.dtype)
    out, aux = moe.moe_forward(lp["moe"], x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    # balanced-ish routing: aux loss near coef (perfect balance -> coef * 1)
    assert 0 < float(aux) < cfg.router_aux_coef * cfg.n_experts


def test_vocab_padding_masked():
    """Padded vocab rows must never be predicted."""
    cfg = get_config("minicpm3-4b", reduced=True).replace(vocab_size=250)  # pads to 512
    assert cfg.padded_vocab_size == 512
    params = TF.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    caches = TF.init_caches(cfg, 2, 16)
    nxt, caches = TF.prefill(cfg, params, tokens, caches)
    for _ in range(3):
        nxt, caches = TF.decode_step(cfg, params, nxt, caches)
        assert int(nxt.max()) < 250


@pytest.mark.slow
def test_forward_layers_range_composes():
    """forward_layers_range(0,k) ∘ forward_layers_range(k,L) == full stack —
    the layer-level serving abstraction is exact (paper §4)."""
    cfg = get_config("granite-8b", reduced=True)
    params = TF.init_params(KEY, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = TF._embed(cfg, params, tokens)
    full = TF.forward_layers_range(cfg, params["layers"], x, 0, cfg.n_layers, positions)
    for k in [0, 1, cfg.n_layers // 2, cfg.n_layers]:
        a = TF.forward_layers_range(cfg, params["layers"], x, 0, k, positions)
        out = TF.forward_layers_range(cfg, params["layers"], a, k, cfg.n_layers, positions)
        np.testing.assert_allclose(
            out.astype(jnp.float32), full.astype(jnp.float32), atol=1e-2, rtol=1e-2
        )
