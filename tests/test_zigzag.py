"""ZigZag scheduling: exact ILP solver properties + ILP-free rule quality."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import zigzag as zz


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 8), layers=st.integers(2, 10), time_l=st.floats(1.0, 12.0))
def test_ilp_solution_satisfies_constraints(n, layers, time_l):
    plan = zz.solve_pipeline_ilp(n, layers, time_l)
    assert len(plan.configs) == n
    pref_t, pref_s = 0, 0
    for i, (t, s) in enumerate(plan.configs, start=1):
        assert t + s == layers  # C1 pipeline limit
        assert 0 <= t <= layers
        if i > 1:
            assert pref_t + t <= pref_s  # C2 pipeline dependency
        if t > 0:
            # C3 load limit (paper Fig. 15b reading — see zigzag.py note)
            assert time_l * (t - 1) <= pref_t + (n - i + 1) * (t - 1) + 1e-6
        pref_t += t
        pref_s += s


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 8), layers=st.integers(2, 10), time_l=st.floats(1.0, 12.0))
def test_ilp_beats_or_ties_all_source(n, layers, time_l):
    """The optimal pipeline is never worse than running everything on the
    overloaded source instance."""
    plan = zz.solve_pipeline_ilp(n, layers, time_l)
    base = zz.avg_latency_of([(0, layers)] * n)
    assert plan.avg_latency <= base + 1e-9


def test_paper_fig15_example():
    """The worked example: 7-layer model, Time_l = 6, 7 requests.  ZigZag
    completes request 7 by t=22 vs 32 for best-effort (paper Fig. 15)."""
    n, layers, time_l = 7, 7, 6.0
    be = zz.simulate_best_effort(n, layers, time_l)
    zg = zz.simulate_zigzag(n, layers, time_l)
    assert zg.avg_latency <= be.avg_latency
    assert zg.makespan <= be.makespan
    ilp = zz.solve_pipeline_ilp(n, layers, time_l)
    assert ilp.avg_latency <= be.avg_latency


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 10), layers=st.integers(2, 12), time_l=st.floats(0.5, 10.0))
def test_zigzag_not_worse_than_best_effort(n, layers, time_l):
    be = zz.simulate_best_effort(n, layers, time_l)
    zg = zz.simulate_zigzag(n, layers, time_l)
    assert zg.avg_latency <= be.avg_latency + 1e-6


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 10), layers=st.integers(2, 12), time_l=st.floats(0.5, 10.0))
def test_schedules_complete_all_requests(n, layers, time_l):
    for sim in (zz.simulate_zigzag, zz.simulate_best_effort):
        r = sim(n, layers, time_l)
        assert len(r.completion) == n
        assert all(c > 0 for c in r.completion)
        assert r.makespan == pytest.approx(max(r.completion))


def test_live_throughput_multiplier():
    """§4: throughput 1/L -> 2x ramp, peaking at half the layers."""
    L = 8
    assert zz.live_throughput_multiplier(0, L) == 1.0
    assert zz.live_throughput_multiplier(L // 2, L) == 2.0
    assert zz.live_throughput_multiplier(L, L) == 2.0
    vals = [zz.live_throughput_multiplier(k, L) for k in range(L + 1)]
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))  # monotone ramp
    # the paper's 7-layer example: after 1 layer, source runs 6/7 of work
    assert zz.live_throughput_multiplier(1, 7) == pytest.approx(7 / 6)


def test_ilp_solve_time_small():
    """Paper: <40 ms for Llama3-8B-sized problems (32 layers, ~12 batches)."""
    plan = zz.solve_pipeline_ilp(12, 32, 6.0)
    assert plan.solve_ms < 2_000  # generous CPU bound; paper reports 40 ms
