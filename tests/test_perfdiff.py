"""Perf-regression gate: BENCH record diffing, direction rules, CLI exit
codes, and the committed-baseline self-diff CI relies on."""

import copy
import json
import pathlib

import pytest

from repro.obs.perfdiff import (
    DEFAULT_RULES,
    EITHER,
    HIGHER_BETTER,
    INFO,
    LOWER_BETTER,
    diff_paths,
    diff_records,
    direction_for,
    main,
    rule_for,
)

REPO_ROOT = pathlib.Path(__file__).parent.parent


def _rec(metrics, *, name="bench", smoke=False, schema=1):
    return {"bench": name, "schema": schema, "git_sha": "abc", "seed": 0,
            "smoke": smoke, "metrics": metrics}


BASE = _rec({
    "ttft_p99_s": 1.0,
    "slo_attainment": 0.99,
    "gpu_time_s": 500.0,
    "wall_s_untraced": 3.0,
    "net_scale_bytes": 1e9,
})


# ---------------------------------------------------------------------------
# direction rules
# ---------------------------------------------------------------------------


def test_direction_rules_first_match_wins():
    assert direction_for("ttft_p99_s") == LOWER_BETTER
    assert direction_for("tbt_p99_s") == LOWER_BETTER
    assert direction_for("slo_attainment") == HIGHER_BETTER
    assert direction_for("tokens_throughput") == HIGHER_BETTER
    assert direction_for("gpu_time_s") == LOWER_BETTER
    assert direction_for("wall_s_untraced") == INFO
    assert direction_for("plan_gen_ms.p50") == INFO
    assert direction_for("net_scale_bytes") == EITHER  # catch-all
    # overhead_frac gates (lower-better) with its own wide tolerance rather
    # than following the informational wall-clock rules
    assert direction_for("overhead_frac") == LOWER_BETTER
    assert rule_for("overhead_frac") == (LOWER_BETTER, 2.0)
    assert rule_for("ttft_p99_s") == (LOWER_BETTER, None)
    # attainment wall-clock? attainment wins (listed earlier than *_ms*)...
    # actually *_ms* is earlier — verify precedence is literal list order
    order = [r[0] for r in DEFAULT_RULES]
    assert order.index("*_ms*") < order.index("*attainment*")


# ---------------------------------------------------------------------------
# diff_records statuses
# ---------------------------------------------------------------------------


def test_identical_records_no_findings():
    rep = diff_records(BASE, copy.deepcopy(BASE))
    assert rep.regressions() == [] and rep.improvements() == []
    assert len(rep.diffs) == len(BASE["metrics"])


def test_ttft_regression_flagged_at_20pct():
    new = copy.deepcopy(BASE)
    new["metrics"]["ttft_p99_s"] = 1.2  # +20% vs 10% tolerance
    rep = diff_records(BASE, new, tolerance=0.1)
    (r,) = rep.regressions()
    assert r.name == "ttft_p99_s" and r.rel_delta == pytest.approx(0.2)
    assert "regression" in r.describe() or "+20.0%" in r.describe()


def test_attainment_drop_is_a_regression_rise_is_improvement():
    worse = copy.deepcopy(BASE)
    worse["metrics"]["slo_attainment"] = 0.80
    rep = diff_records(BASE, worse, tolerance=0.1)
    assert [d.name for d in rep.regressions()] == ["slo_attainment"]
    better = copy.deepcopy(BASE)
    better["metrics"]["ttft_p99_s"] = 0.5
    rep = diff_records(BASE, better, tolerance=0.1)
    assert [d.name for d in rep.improvements()] == ["ttft_p99_s"]
    assert rep.regressions() == []


def test_wall_clock_never_gates():
    new = copy.deepcopy(BASE)
    new["metrics"]["wall_s_untraced"] = 300.0  # 100x slower machine
    rep = diff_records(BASE, new)
    assert rep.regressions() == []
    (d,) = [d for d in rep.diffs if d.name == "wall_s_untraced"]
    assert d.status == "info"


def test_deterministic_counter_drifts_both_ways():
    for factor in (2.0, 0.5):
        new = copy.deepcopy(BASE)
        new["metrics"]["net_scale_bytes"] = 1e9 * factor
        rep = diff_records(BASE, new, tolerance=0.1)
        assert [d.name for d in rep.regressions()] == ["net_scale_bytes"]


def test_missing_and_added_metrics():
    new = copy.deepcopy(BASE)
    del new["metrics"]["gpu_time_s"]
    new["metrics"]["brand_new"] = 1.0
    rep = diff_records(BASE, new)
    assert [d.name for d in rep.missing()] == ["gpu_time_s"]
    assert [d.name for d in rep.diffs if d.status == "added"] == ["brand_new"]
    assert rep.regressions() == []  # neither gates by default


def test_zero_baseline_uses_atol_floor():
    old = _rec({"ttft_p99_s": 0.0})
    new = _rec({"ttft_p99_s": 1e-12})
    rep = diff_records(old, new, tolerance=0.1, atol=1e-9)
    assert rep.regressions() == []  # noise over a 0 baseline doesn't explode


def test_smoke_and_schema_mismatch_warn():
    rep = diff_records(_rec({"a": 1.0}), _rec({"a": 1.0}, smoke=True, schema=2))
    assert any("smoke" in w for w in rep.warnings)
    assert any("schema" in w for w in rep.warnings)


# ---------------------------------------------------------------------------
# directory mode + CLI
# ---------------------------------------------------------------------------


def _write(tmp, sub, name, rec):
    d = tmp / sub
    d.mkdir(exist_ok=True)
    (d / f"BENCH_{name}.json").write_text(json.dumps(rec))


def test_dir_mode_pairs_by_name_and_warns_on_unpaired(tmp_path):
    _write(tmp_path, "old", "a", BASE)
    _write(tmp_path, "old", "only_old", _rec({"x": 1.0}))
    _write(tmp_path, "new", "a", copy.deepcopy(BASE))
    _write(tmp_path, "new", "only_new", _rec({"x": 1.0}))
    rep = diff_paths(str(tmp_path / "old"), str(tmp_path / "new"))
    assert rep.regressions() == []
    assert any("only_old" in w for w in rep.warnings)
    assert any("only_new" in w for w in rep.warnings)


def test_mixed_file_and_dir_rejected(tmp_path):
    _write(tmp_path, "old", "a", BASE)
    with pytest.raises(ValueError):
        diff_paths(str(tmp_path / "old"), str(tmp_path / "old" / "BENCH_a.json"))


def test_cli_exit_codes(tmp_path, capsys):
    old = tmp_path / "old.json"
    old.write_text(json.dumps(BASE))
    same = tmp_path / "same.json"
    same.write_text(json.dumps(BASE))
    bad = copy.deepcopy(BASE)
    bad["metrics"]["ttft_p99_s"] = 1.2  # the acceptance scenario: p99 +20%
    worse = tmp_path / "worse.json"
    worse.write_text(json.dumps(bad))

    assert main([str(old), str(same)]) == 0
    out = capsys.readouterr().out
    assert "PERF GATE: OK" in out

    report = tmp_path / "report.json"
    assert main([str(old), str(worse), "--tolerance", "0.1",
                 "--json-out", str(report)]) == 1
    err = capsys.readouterr().err
    assert "PERF GATE: FAIL" in err
    doc = json.loads(report.read_text())
    assert doc["n_regressions"] == 1
    assert doc["diffs"]


def test_cli_fail_on_missing(tmp_path):
    old = tmp_path / "old.json"
    old.write_text(json.dumps(BASE))
    shrunk = copy.deepcopy(BASE)
    del shrunk["metrics"]["gpu_time_s"]
    new = tmp_path / "new.json"
    new.write_text(json.dumps(shrunk))
    assert main([str(old), str(new)]) == 0
    assert main([str(old), str(new), "--fail-on-missing"]) == 1


# ---------------------------------------------------------------------------
# committed baselines: the CI contract
# ---------------------------------------------------------------------------


def test_committed_smoke_baselines_self_diff_clean():
    """The committed smoke baselines must diff clean against themselves —
    the trivial soundness check for the CI perf-gate invocation."""
    smoke_dir = REPO_ROOT / "benchmarks" / "baselines" / "smoke"
    assert smoke_dir.is_dir(), "committed smoke baselines missing"
    assert list(smoke_dir.glob("BENCH_*.json")), "no records committed"
    rep = diff_paths(str(smoke_dir), str(smoke_dir), tolerance=0.25)
    assert rep.regressions() == [] and rep.warnings == []


def test_committed_root_records_self_diff_clean():
    names = list(REPO_ROOT.glob("BENCH_*.json"))
    assert names, "no committed BENCH records at repo root"
    rep = diff_paths(str(REPO_ROOT), str(REPO_ROOT))
    assert rep.regressions() == []
