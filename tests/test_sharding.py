"""Logical-axis sharding substrate: shape-aware resolution properties."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


class _FakeMesh:
    """Mesh stand-in: spec resolution only needs axis names + sizes."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self._shape = tuple(sizes.values())

    @property
    def devices(self):
        import numpy as np

        return np.zeros(self._shape)


MESH = _FakeMesh({"pod": 2, "data": 16, "model": 16})
RULES = sh.ShardingRules(MESH)


def _axis_prod(spec_entry):
    sizes = {"pod": 2, "data": 16, "model": 16}
    if spec_entry is None:
        return 1
    if isinstance(spec_entry, str):
        return sizes[spec_entry]
    return int(__import__("numpy").prod([sizes[a] for a in spec_entry]))


@settings(max_examples=60, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    axes=st.lists(
        st.sampled_from(["batch", "heads", "d_ff", "vocab", "seq", None]),
        min_size=1, max_size=4,
    ),
)
def test_spec_for_shape_always_divides(dims, axes):
    """Divisibility invariant: every resolved mesh-axis product divides its
    tensor dim (pjit would reject anything else)."""
    n = min(len(dims), len(axes))
    dims, axes = dims[:n], axes[:n]
    spec = RULES.spec_for_shape(tuple(dims), axes)
    entries = list(spec) + [None] * (n - len(spec))
    used = set()
    for d, e in zip(dims, entries):
        assert d % _axis_prod(e) == 0
        if e is not None:
            names = (e,) if isinstance(e, str) else tuple(e)
            assert not (set(names) & used)  # no mesh axis reused
            used.update(names)


def test_spec_prefers_full_rule_when_divisible():
    spec = RULES.spec_for_shape((256, 128), ("batch", "d_ff"))
    assert spec == P(("pod", "data"), "model")


def test_spec_drops_trailing_axes_until_divisible():
    # batch 16 divides pod*data=32? No -> drop 'data': 16 % 2 == 0 -> ('pod',)
    spec = RULES.spec_for_shape((16, 128), ("batch", "d_ff"))
    assert spec[0] in ("pod", ("pod",))
    # batch=1 (long_500k): fully replicated
    spec1 = RULES.spec_for_shape((1, 128), ("batch", "d_ff"))
    assert spec1[0] is None


def test_spec_replicates_non_divisible_heads():
    # 20 heads on a 16-way model axis -> replicate (qwen/whisper case)
    spec = RULES.spec_for_shape((4096, 20, 128), ("d_model", "heads", "head_dim"))
    assert len(spec) == 0 or all(e is None for e in spec)


def test_overrides_merge():
    r2 = RULES.with_overrides({"d_model": ("data",)})
    spec = r2.spec_for_shape((4096, 14336), ("d_model", "d_ff"))
    assert spec == P("data", "model")
    # base rules unchanged
    assert RULES.spec_for_shape((4096, 14336), ("d_model", "d_ff")) == P(None, "model")


def test_no_mesh_is_noop():
    r = sh.ShardingRules(None)
    assert r.spec_for(("batch", "d_ff")) == P()


def test_template_roundtrip():
    t = {"w": sh.TensorSpec((64, 128), ("d_model", "d_ff"))}
    params = sh.init_from_template(jax.random.PRNGKey(0), t)
    assert params["w"].shape == (64, 128)
    abstract = sh.abstract_from_template(t)
    assert abstract["w"].shape == (64, 128)
    specs = sh.specs_from_template(t, RULES)
    assert specs["w"] == P(None, "model")
    stacked = sh.stack_template(t, 4)
    assert stacked["w"].shape == (4, 64, 128)
    assert stacked["w"].axes[0] == "layers"
    assert sh.param_count(t) == 64 * 128
