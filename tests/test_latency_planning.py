"""Latency-aware multicast planning (tentpole of the planner/data-plane
convergence PR): chain cost carries per-hop (link + switch) latency, source
selection and target ordering re-rank on projected arrival, deep serial
chains lose to wider plans when switching delay dominates, and the analytic
``transfer_seconds`` agrees with FlowSim-realized completion.  Also pins the
degenerate-chain and ``validate_plan`` sharded-slice fixes."""

import math

import pytest

from repro.core import multicast as mc
from repro.core import topology as tp
from repro.net import FlowSim, MulticastExecution

GB = 1e9
MB_MODEL = int(2e8)  # 0.2 s at 8 Gbps (1 GB/s) — comparable to big latencies
LINK_LAT = 0.01
SWITCH_LAT = 0.05  # switching delay dominates: intra-leaf hop pays 0.07 s


class _FlatLatency:
    """Duck-typed planner latency view: uniform per-hop first-byte delay."""

    has_latency = True

    def __init__(self, hop_s: float):
        self.hop_s = hop_s

    def hop_latency(self, src: int, dst: int) -> float:
        return 0.0 if src == dst else self.hop_s


def _single_leaf_two_sources(n_devs=8, bw=8.0):
    """One leaf, one device per scale-up domain, two model sources: the
    bandwidth-only planner serializes every target behind ONE source (deep
    chain) because freshly scaled targets are inserted at the queue head
    and win max() ties."""
    topo = tp.make_cluster(n_devs, 1, hosts_per_leaf=n_devs, bw_gbps=bw)
    srcs = [0, 1]
    for i in srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE  # egress free
    tgts = [d.id for d in topo.spares()]
    return topo, srcs, tgts


def _chain_depth(plan: mc.MulticastPlan) -> int:
    return max((len(c.edges) for c in plan.chains), default=0)


def _realize(topo, plan, model_bytes, **flowsim_kw) -> float:
    sim = FlowSim(topo, **flowsim_kw)
    ex = MulticastExecution(plan, model_bytes)
    ex.start(sim, 0.0)
    sim.advance_to(1e6)
    assert ex.done and not ex.aborted
    return ex.done_at


# ---------------------------------------------------------------------------
# Tentpole: deep chains lose to wide plans when switching delay dominates
# ---------------------------------------------------------------------------


def test_bandwidth_only_builds_deep_chain_latency_aware_splits():
    topo, srcs, tgts = _single_leaf_two_sources()
    plan_bw = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    assert _chain_depth(plan_bw) >= 4  # the PR-4 divergence scenario

    sim = FlowSim(topo, link_latency_s=LINK_LAT, switch_latency_s=SWITCH_LAT)
    plan_lat = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=sim, model_bytes=MB_MODEL
    )
    assert mc.validate_plan(topo, plan_lat) == []
    assert sorted(plan_lat.covered) == sorted(plan_bw.covered) == sorted(tgts)
    # both sources now head a chain and no chain is as deep as the serial one
    assert len(plan_lat.chains) > len(plan_bw.chains)
    assert _chain_depth(plan_lat) < _chain_depth(plan_bw)


def test_latency_aware_plan_realizes_faster_than_bandwidth_only():
    """Acceptance: on a switching-latency-dominated topology the
    latency-aware plan's FlowSim-REALIZED completion beats the
    bandwidth-only plan's, and the latency-aware ``transfer_seconds``
    predicts its own realization within 1%."""
    topo, srcs, tgts = _single_leaf_two_sources()
    lat_kw = dict(link_latency_s=LINK_LAT, switch_latency_s=SWITCH_LAT)

    plan_bw = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    sim_view = FlowSim(topo, **lat_kw)
    plan_lat = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=sim_view, model_bytes=MB_MODEL
    )

    t_bw = _realize(topo, plan_bw, MB_MODEL, **lat_kw)
    t_lat = _realize(topo, plan_lat, MB_MODEL, **lat_kw)
    assert t_lat < t_bw * (1 - 1e-6), (t_lat, t_bw)
    # the planner now predicts what the data plane charges (<= 1% drift);
    # the bandwidth-only plan's analytic time misses its own latency cost
    assert plan_lat.transfer_seconds(MB_MODEL) == pytest.approx(t_lat, rel=1e-2)
    assert plan_bw.transfer_seconds(MB_MODEL) < t_bw


def test_zero_latency_net_plans_bit_for_bit_like_bandwidth_only():
    """A zero-latency FlowSim view must not perturb planning at all — the
    configuration the legacy golden trace pins."""
    topo, srcs, tgts = _single_leaf_two_sources()
    plan_a = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    plan_b = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=FlowSim(topo), model_bytes=MB_MODEL
    )

    def shape(plan):
        return [
            [(e.src.device_ids, e.dst.device_ids, e.bw_gbps, e.sharded_ways,
              e.intra_scaleup, e.latency_s) for e in c.edges]
            for c in plan.chains
        ]

    assert shape(plan_a) == shape(plan_b)
    assert plan_a.covered == plan_b.covered
    assert plan_a.transfer_seconds(MB_MODEL) == plan_b.transfer_seconds(MB_MODEL)


def test_latency_aware_source_selection_with_duck_typed_view():
    """The planner only needs ``hop_latency`` — any stand-in works, and a
    bigger hop delay pushes plans wider (more, shallower chains)."""
    topo, srcs, tgts = _single_leaf_two_sources()
    deep = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=_FlatLatency(1e-9), model_bytes=MB_MODEL
    )
    wide = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=_FlatLatency(0.1), model_bytes=MB_MODEL
    )
    assert _chain_depth(wide) <= _chain_depth(deep)
    assert len(wide.chains) >= len(deep.chains)
    assert all(e.latency_s == pytest.approx(0.1) for e in wide.all_edges())


def test_latency_aware_target_order_defers_high_latency_targets():
    """Fastest-first re-ranked on cost: a high-bandwidth target behind a
    slow path no longer jumps the queue."""
    topo = tp.make_cluster(4, 1, hosts_per_leaf=4, bw_gbps=8.0)
    topo.device(0).model = "m"
    topo.device(0).role = tp.Role.DECODE
    topo.device(3).bw_gbps = 16.0  # fastest target, but behind a slow hop

    class _SlowTo3:
        has_latency = True

        def hop_latency(self, src, dst):
            return 0.5 if dst == 3 else 1e-3

    tgts = [d.id for d in topo.spares()]
    plan_bw = mc.plan_multicast(topo, [0], tgts, len(tgts))
    first_bw = plan_bw.covered[0]
    assert first_bw == 3  # bandwidth-only: fastest NIC goes first
    plan_lat = mc.plan_multicast(
        topo, [0], tgts, len(tgts), net=_SlowTo3(), model_bytes=MB_MODEL
    )
    assert plan_lat.covered[0] != 3
    assert plan_lat.covered[-1] == 3  # deferred behind the low-latency ones


# ---------------------------------------------------------------------------
# Chain cost model (Fig. 13a + latency term)
# ---------------------------------------------------------------------------


def test_chain_transfer_seconds_includes_store_and_forward_latency():
    n0 = mc.Node(device_ids=(0,), scaleup=0, leaf=0, agg_bw_gbps=8.0, is_source=True)
    n1 = mc.Node(device_ids=(1,), scaleup=1, leaf=0, agg_bw_gbps=8.0)
    n2 = mc.Node(device_ids=(2,), scaleup=2, leaf=0, agg_bw_gbps=8.0)
    e1 = mc.Edge(src=n0, dst=n1, bw_gbps=8.0, sharded_ways=1, latency_s=0.07)
    e2 = mc.Edge(src=n1, dst=n2, bw_gbps=8.0, sharded_ways=1, latency_s=0.07)
    ch = mc.Chain(nodes=[n0, n1, n2], edges=[e1, e2])
    assert ch.latency_seconds == pytest.approx(0.14)
    # uniform hop bandwidth: closed form |M|/bottleneck + total latency
    assert ch.transfer_seconds(int(GB)) == pytest.approx(1.0 + 0.14)
    # heterogeneous hops: completion is the max over hop prefixes — a fast
    # late hop does not hide the slow early hop's time
    e2_fast = mc.Edge(src=n1, dst=n2, bw_gbps=80.0, sharded_ways=1, latency_s=0.07)
    ch2 = mc.Chain(nodes=[n0, n1, n2], edges=[e1, e2_fast])
    assert ch2.transfer_seconds(int(GB)) == pytest.approx(
        max(0.07 + 1.0, 0.14 + 0.1)
    )


def test_chain_time_model_gains_latency_term():
    base = mc.chain_time_model(int(GB), 8.0, 4)
    assert mc.chain_time_model(int(GB), 8.0, 4, total_latency_s=0.25) == pytest.approx(
        base + 0.25
    )
    sf = mc.chain_time_model(int(GB), 8.0, 4, pipelined=False, total_latency_s=0.25)
    assert sf == pytest.approx(4 * base + 0.25)


def test_degenerate_source_only_chain_is_explicit():
    """Satellite: edge-less chains are a first-class degenerate case — no
    bottleneck to rank on, zero transfer time — and ranking/division
    callers must branch on ``is_degenerate``."""
    n0 = mc.Node(device_ids=(0,), scaleup=0, leaf=0, agg_bw_gbps=8.0, is_source=True)
    ch = mc.Chain(nodes=[n0], edges=[])
    assert ch.is_degenerate
    assert math.isinf(ch.bottleneck_gbps)
    assert ch.transfer_seconds(int(GB)) == 0.0
    assert ch.latency_seconds == 0.0
    plan = mc.MulticastPlan(
        chains=[ch], covered=[], gen_seconds=0.0, pruned_sources=[]
    )
    assert plan.transfer_seconds(int(GB)) == 0.0
    assert plan.live_scale_nodes == []  # a degenerate chain has no tail hop
    # a non-degenerate chain is not misclassified
    n1 = mc.Node(device_ids=(1,), scaleup=1, leaf=0, agg_bw_gbps=8.0)
    real = mc.Chain(
        nodes=[n0, n1],
        edges=[mc.Edge(src=n0, dst=n1, bw_gbps=8.0, sharded_ways=1)],
    )
    assert not real.is_degenerate and real.bottleneck_gbps == 8.0


def test_interference_pruning_host_fallback_and_ablation_baseline():
    """Line-1 pruning: all-busy sources seed the chain from the O(1) host
    copy; ``allow_interference=True`` (the Fig. 8 ablation baseline) keeps
    them and produces a plan validate_plan rejects."""
    topo = tp.add_host_sources(tp.make_cluster(4, 1, hosts_per_leaf=4, bw_gbps=8.0))
    for i in (0, 1):
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.PREFILL  # egress busy -> pruned
    tgts = [d.id for d in topo.spares()]
    pruned = mc.plan_multicast(topo, [0, 1], tgts, len(tgts))
    assert pruned.pruned_sources == [0, 1]
    assert pruned.chains[0].nodes[0].is_host
    assert mc.validate_plan(topo, pruned) == []
    ablation = mc.plan_multicast(topo, [0, 1], tgts, len(tgts),
                                 allow_interference=True)
    assert ablation.pruned_sources == []
    assert not ablation.chains[0].nodes[0].is_host
    assert mc.validate_plan(topo, ablation) != []  # collides with serving
    # degraded cluster with no host tier: last resort keeps the busy sources
    topo2 = tp.make_cluster(4, 1, hosts_per_leaf=4, bw_gbps=8.0)
    for i in (0, 1):
        topo2.device(i).model = "m"
        topo2.device(i).role = tp.Role.PREFILL
    tgts2 = [d.id for d in topo2.spares()]
    last_resort = mc.plan_multicast(topo2, [0, 1], tgts2, len(tgts2))
    assert sorted(last_resort.covered) == sorted(tgts2)


# ---------------------------------------------------------------------------
# validate_plan: sharded slice clamp (satellite bugfix)
# ---------------------------------------------------------------------------


def test_validate_plan_flags_and_clamps_oversharded_edge():
    """``sharded_ways`` larger than an endpoint silently truncated the
    device slices and under-counted link usage; now it is flagged AND the
    accounting clamps to the pairs that actually transfer."""
    topo = tp.make_cluster(2, 4, bw_gbps=100.0)
    big = mc.Node(device_ids=(0, 1, 2, 3), scaleup=0, leaf=0,
                  agg_bw_gbps=400.0, is_source=True)
    small = mc.Node(device_ids=(4, 5), scaleup=1, leaf=0, agg_bw_gbps=200.0)
    bad = mc.Edge(src=big, dst=small, bw_gbps=400.0, sharded_ways=4)
    plan = mc.MulticastPlan(
        chains=[mc.Chain(nodes=[big, small], edges=[bad])],
        covered=[4, 5],
        gen_seconds=0.0,
        pruned_sources=[],
    )
    errors = mc.validate_plan(topo, plan)
    assert any("sharded_ways 4 exceeds endpoint size 2" in e for e in errors)
    # a well-formed plan with matched endpoints raises no such violation
    ok = mc.Edge(src=big, dst=small, bw_gbps=200.0, sharded_ways=2)
    plan_ok = mc.MulticastPlan(
        chains=[mc.Chain(nodes=[big, small], edges=[ok])],
        covered=[4, 5],
        gen_seconds=0.0,
        pruned_sources=[],
    )
    assert mc.validate_plan(topo, plan_ok) == []


def test_validate_plan_clamped_usage_still_counts_collisions():
    """The clamp keeps the accounting sound: the pairs that DO transfer
    still collide with a second same-direction flow on the same device."""
    topo = tp.make_cluster(2, 4, bw_gbps=100.0)
    big = mc.Node(device_ids=(0, 1, 2, 3), scaleup=0, leaf=0,
                  agg_bw_gbps=400.0, is_source=True)
    small = mc.Node(device_ids=(4, 5), scaleup=1, leaf=0, agg_bw_gbps=200.0)
    other = mc.Node(device_ids=(6,), scaleup=1, leaf=0, agg_bw_gbps=100.0)
    oversharded = mc.Edge(src=big, dst=small, bw_gbps=400.0, sharded_ways=4)
    reuse_egress = mc.Edge(src=mc.Node(device_ids=(0,), scaleup=0, leaf=0,
                                       agg_bw_gbps=100.0, is_source=True),
                           dst=other, bw_gbps=100.0, sharded_ways=1)
    plan = mc.MulticastPlan(
        chains=[
            mc.Chain(nodes=[big, small], edges=[oversharded]),
            mc.Chain(nodes=[reuse_egress.src, other], edges=[reuse_egress]),
        ],
        covered=[4, 5, 6],
        gen_seconds=0.0,
        pruned_sources=[],
    )
    errors = mc.validate_plan(topo, plan)
    assert any("sharded_ways" in e for e in errors)
    # device 0 feeds both the clamped edge (pair 0->4) and the second chain
    assert any("device 0: 2 same-direction egress flows" in e for e in errors)
