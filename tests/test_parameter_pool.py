"""Global parameter pool: the O(1) host-cache invariant + fault tolerance."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import topology as tp
from repro.core.parameter_pool import ParameterPool


def _pool(n_hosts=4, devs=4):
    topo = tp.make_cluster(n_hosts, devs)
    return topo, ParameterPool(topo)


def test_o1_host_cache_per_model():
    """Each model occupies exactly ONE host cache slot cluster-wide (vs
    ServerlessLLM's per-host caching — paper Fig. 19)."""
    topo, pool = _pool()
    for i in range(8):
        pool.register(f"model-{i}", 10 * 2**30)
    usage = pool.host_cache_bytes()
    assert sum(usage.values()) == 8 * 10 * 2**30  # one copy per model total
    # round-robin placement: max one more model than min per host
    counts = [v // (10 * 2**30) for v in usage.values()]
    assert max(counts) - min(counts) <= 1


def test_deploy_reclaim_tracks_sources():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    pool.deploy("m", [0, 1])
    gpus, host = pool.sources("m")
    assert gpus == [0, 1] and host is not None
    assert pool.n_copies("m") == 3
    pool.reclaim("m", [0, 1])
    gpus, host = pool.sources("m")
    assert gpus == [] and host is not None  # O(1) copy survives reclaim
    assert pool.invariant_ok()


def test_host_failure_rehomes_cached_copy():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    victim = pool.models["m"].host_copy
    rehomed = pool.fail_host(victim)
    assert "m" in rehomed
    assert pool.models["m"].host_copy != victim
    assert pool.invariant_ok()


def test_host_failure_drops_gpu_copies_on_that_host():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    dev_host0 = [d.id for d in topo.devices if d.host == 0]
    dev_host1 = [d.id for d in topo.devices if d.host == 1]
    pool.deploy("m", dev_host0 + dev_host1[:1])
    pool.fail_host(0)
    gpus, _ = pool.sources("m")
    assert set(gpus) == set(dev_host1[:1])
    assert pool.invariant_ok()


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["reg", "dep", "rec", "fail", "recover"]),
                              st.integers(0, 7)), max_size=30))
def test_invariant_under_random_operations(ops):
    """>=1 copy of every model survives any register/deploy/reclaim/failure
    sequence as long as one host remains."""
    topo, pool = _pool(n_hosts=4)
    accel = [d.id for d in topo.devices]
    failed = set()
    for op, arg in ops:
        if op == "reg":
            pool.register(f"m{arg}", 1 << 20)
        elif op == "dep" and pool.models:
            name = sorted(pool.models)[arg % len(pool.models)]
            pool.deploy(name, [accel[arg % len(accel)]])
        elif op == "rec" and pool.models:
            name = sorted(pool.models)[arg % len(pool.models)]
            pool.reclaim(name, list(pool.models[name].gpu_devices)[:1])
        elif op == "fail" and len(failed) < 3:
            h = arg % 4
            failed.add(h)
            pool.fail_host(h)
        elif op == "recover" and failed:
            h = sorted(failed)[arg % len(failed)]
            failed.discard(h)
            pool.recover_host(h)
        assert pool.invariant_ok()
