"""Global parameter pool: the O(1) host-cache invariant + fault tolerance."""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # property test skips; plain tests still run
    HAVE_HYPOTHESIS = False

from repro.core import topology as tp
from repro.core.parameter_pool import NoAliveHostError, ParameterPool


def _pool(n_hosts=4, devs=4):
    topo = tp.make_cluster(n_hosts, devs)
    return topo, ParameterPool(topo)


def test_o1_host_cache_per_model():
    """Each model occupies exactly ONE host cache slot cluster-wide (vs
    ServerlessLLM's per-host caching — paper Fig. 19)."""
    topo, pool = _pool()
    for i in range(8):
        pool.register(f"model-{i}", 10 * 2**30)
    usage = pool.host_cache_bytes()
    assert sum(usage.values()) == 8 * 10 * 2**30  # one copy per model total
    # round-robin placement: max one more model than min per host
    counts = [v // (10 * 2**30) for v in usage.values()]
    assert max(counts) - min(counts) <= 1


def test_deploy_reclaim_tracks_sources():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    pool.deploy("m", [0, 1])
    gpus, host = pool.sources("m")
    assert gpus == [0, 1] and host is not None
    assert pool.n_copies("m") == 3
    pool.reclaim("m", [0, 1])
    gpus, host = pool.sources("m")
    assert gpus == [] and host is not None  # O(1) copy survives reclaim
    assert pool.invariant_ok()


def test_host_failure_rehomes_cached_copy():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    victim = pool.models["m"].host_copy
    rehomed = pool.fail_host(victim)
    assert "m" in rehomed
    assert pool.models["m"].host_copy != victim
    assert pool.invariant_ok()


def test_host_failure_drops_gpu_copies_on_that_host():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    dev_host0 = [d.id for d in topo.devices if d.host == 0]
    dev_host1 = [d.id for d in topo.devices if d.host == 1]
    pool.deploy("m", dev_host0 + dev_host1[:1])
    pool.fail_host(0)
    gpus, _ = pool.sources("m")
    assert set(gpus) == set(dev_host1[:1])
    assert pool.invariant_ok()


def test_register_with_all_hosts_failed_raises_clearly():
    """All hosts down: registration must fail with a clear error, not a
    ZeroDivisionError from the round-robin placement."""
    topo, pool = _pool(n_hosts=2)
    pool.fail_host(0)
    pool.fail_host(1)
    with pytest.raises(NoAliveHostError, match="every host is failed"):
        pool.register("m", 1 << 30)
    pool.recover_host(0)
    pool.register("m", 1 << 30)  # registration works again after recovery
    assert pool.invariant_ok()


def test_deactivate_keeps_single_host_copy():
    """Scale-to-zero: every GPU copy reclaimed, exactly one host copy left."""
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    pool.deploy("m", [0, 1, 5])
    freed = pool.deactivate("m")
    assert freed == [0, 1, 5]
    gpus, host = pool.sources("m")
    assert gpus == [] and host is not None
    assert pool.n_copies("m") == 1 and pool.invariant_ok()
    for i in freed:
        assert topo.device(i).model is None
        assert topo.device(i).role is tp.Role.FREE


def test_evict_removes_model_entirely():
    topo, pool = _pool()
    pool.register("m", 1 << 30)
    pool.deploy("m", [0])
    pool.evict("m")
    assert "m" not in pool.models
    assert topo.device(0).model is None and topo.device(0).role is tp.Role.FREE
    assert sum(pool.host_cache_bytes().values()) == 0
    pool.evict("m")  # idempotent


def test_multi_model_churn_keeps_o1_invariant():
    """MaaS churn: several models register/deploy/reclaim across host
    failures and recoveries; the >=1-copy invariant holds at every step and
    host cache stays at exactly ONE copy per model cluster-wide."""
    topo, pool = _pool(n_hosts=4, devs=4)
    names = [f"m{i}" for i in range(6)]
    size = 1 << 30
    accel = [d.id for d in topo.devices]
    for i, name in enumerate(names):
        pool.register(name, size)
        pool.deploy(name, accel[2 * i : 2 * i + 2])
        assert pool.invariant_ok()

    def check_o1():
        # every model's host-cache footprint is one copy, fleet-wide
        alive_total = sum(
            1 for rec in pool.models.values()
            if rec.host_copy is not None and rec.host_copy not in pool._failed_hosts
        )
        assert sum(pool.host_cache_bytes().values()) == alive_total * size
        assert alive_total == len(names)

    check_o1()
    for name in names[:3]:  # a few models scale to zero ...
        pool.deactivate(name)
        assert pool.invariant_ok()
    check_o1()
    pool.fail_host(0)  # ... a host dies ...
    assert pool.invariant_ok()  # every victim re-homed before return
    check_o1()
    pool.recover_host(0)
    for name in names[:3]:  # ... parked models come back
        pool.deploy(name, [accel[-1 - names.index(name)]])
        assert pool.invariant_ok()
    check_o1()
    pool.fail_host(1)
    pool.fail_host(2)
    assert pool.invariant_ok()
    usage = pool.host_cache_bytes()
    assert all(v % size == 0 for v in usage.values())
    assert sum(usage.values()) == len(names) * size  # still one copy each


def _random_ops_body(ops):
    """>=1 copy of every model survives any register/deploy/reclaim/failure
    sequence as long as one host remains."""
    topo, pool = _pool(n_hosts=4)
    accel = [d.id for d in topo.devices]
    failed = set()
    for op, arg in ops:
        if op == "reg":
            pool.register(f"m{arg}", 1 << 20)
        elif op == "dep" and pool.models:
            name = sorted(pool.models)[arg % len(pool.models)]
            pool.deploy(name, [accel[arg % len(accel)]])
        elif op == "rec" and pool.models:
            name = sorted(pool.models)[arg % len(pool.models)]
            pool.reclaim(name, list(pool.models[name].gpu_devices)[:1])
        elif op == "fail" and len(failed) < 3:
            h = arg % 4
            failed.add(h)
            pool.fail_host(h)
        elif op == "recover" and failed:
            h = sorted(failed)[arg % len(failed)]
            failed.discard(h)
            pool.recover_host(h)
        assert pool.invariant_ok()


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["reg", "dep", "rec", "fail", "recover"]),
                  st.integers(0, 7)), max_size=30))
    def test_invariant_under_random_operations(ops):
        _random_ops_body(ops)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_invariant_under_random_operations():
        pass
