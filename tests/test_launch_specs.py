"""Launch layer unit tests that run on 1 device: cell enumeration, abstract
input specs, roofline bookkeeping.  (The real lower/compile sweep is
launch/dryrun.py — too heavy for unit tests.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, shape_applicable
from repro.launch.dryrun import model_flops
from repro.launch.steps import input_specs, make_rules, opt_config_for
from repro.training.train_step import make_batch_abstract


def test_cell_grid_is_the_assignment():
    grid = list(cells())
    # 10 archs x 4 shapes minus long_500k for the 8 full-attention archs
    assert len(grid) == 10 * 4 - 8
    long_archs = {a for a, s, _ in grid if s == "long_500k"}
    assert long_archs == {"mamba2-370m", "zamba2-2.7b"}


@pytest.mark.parametrize("arch,shape", [(a, s) for a, s, _ in cells()])
def test_input_specs_are_abstract_and_complete(arch, shape):
    specs = input_specs(arch, shape)
    sp = SHAPES[shape]
    leaves = jax.tree.leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)  # no alloc
    cfg = get_config(arch)
    if sp.kind == "train":
        assert specs["tokens"].shape == (sp.global_batch, sp.seq_len)
        assert specs["labels"].shape == (sp.global_batch, sp.seq_len)
        if cfg.family in ("vlm", "encdec"):
            assert "frames" in specs
    elif sp.kind == "prefill":
        assert specs["tokens"].shape == (sp.global_batch, sp.seq_len)
    else:
        assert specs["last_tokens"].shape == (sp.global_batch,)
        assert "caches" in specs


def test_long_500k_skips_are_principled():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == (cfg.family in ("ssm", "hybrid"))


def test_opt_config_bf16_moments_for_big_archs():
    assert opt_config_for(get_config("nemotron-4-340b")).moment_dtype == jnp.bfloat16
    assert opt_config_for(get_config("grok-1-314b")).moment_dtype == jnp.bfloat16
    assert opt_config_for(get_config("granite-8b")).moment_dtype == jnp.float32


def test_model_flops_formulas():
    # dense train: 6 N D
    cfg = get_config("granite-8b")
    n = cfg.approx_params()
    d = SHAPES["train_4k"].seq_len * SHAPES["train_4k"].global_batch
    assert model_flops("granite-8b", "train_4k") == pytest.approx(6.0 * n * d)
    # MoE uses active params only
    moe_active = get_config("olmoe-1b-7b").approx_active_params()
    moe_total = get_config("olmoe-1b-7b").approx_params()
    assert moe_active < moe_total
    assert model_flops("olmoe-1b-7b", "decode_32k") == pytest.approx(
        2.0 * moe_active * 128
    )


def test_make_rules_applies_arch_overrides():
    class _M:
        axis_names = ("data", "model")
        devices = __import__("numpy").zeros((16, 16))

    rules = make_rules(get_config("nemotron-4-340b"), _M())
    assert rules.rules["d_model"] == ("data",)
    base = make_rules(get_config("granite-8b"), _M())
    assert base.rules["d_model"] is None


def test_batch_abstract_covers_frontends():
    cfg = get_config("pixtral-12b")
    b = make_batch_abstract(cfg, 8, 128)
    assert "frames" in b and b["frames"].shape[1] == cfg.n_frontend_tokens
