"""Tests for the simcheck static-analysis framework (src/repro/analysis).

Each rule is driven against a golden *bad* fixture (every violation class,
asserted by line) and a golden *clean* fixture (sanctioned patterns stay
silent).  Fixtures live in tests/analysis_fixtures/ and are never imported
— they are parsed as SourceUnits with an explicit in-scope module name
(the files sit outside src/, so their on-disk module would be out of
scope for every rule).

On top of the per-rule goldens: pragma semantics, baseline round-trip
(incl. the justification gate), CLI exit codes, --rule / --fix-sorted /
--format json, import-graph dumps, the import-smoke walker, and the gate
test that the real tree under src/repro is clean.
"""

from __future__ import annotations

import json
import pathlib
import shutil

import pytest

from repro.analysis import (
    AnalysisContext,
    Baseline,
    SourceUnit,
    default_config,
    load_tree,
    run_rules,
)
from repro.analysis import check as check_cli
from repro.analysis import import_smoke
from repro.analysis.baseline import PLACEHOLDER
from repro.analysis.core import module_name_for

FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"
REPO = pathlib.Path(__file__).resolve().parent.parent
SRC_REPRO = REPO / "src" / "repro"


def _unit(fixture: str, module: str) -> SourceUnit:
    path = FIXTURES / fixture
    return SourceUnit(str(path), path.read_text(encoding="utf-8"), module=module)


def _run(units, only=None, fix_sorted=False):
    ctx = AnalysisContext(
        config=default_config(), units=list(units), fix_sorted=fix_sorted
    )
    return run_rules(ctx, only=only)


def _lines(findings):
    return sorted(f.line for f in findings)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


class TestDeterminismRule:
    def test_bad_fixture_fires_on_every_pattern(self):
        findings = _run([_unit("det_bad.py", "repro.net._fix_det_bad")],
                        only=["determinism"])
        # 3 wall-clock reads + 3 global/unseeded RNG uses
        assert _lines(findings) == [15, 16, 17, 22, 23, 24]
        assert all(f.rule == "determinism" for f in findings)
        symbols = {f.symbol for f in findings}
        assert "time.time" in symbols
        assert "time.perf_counter" in symbols
        assert "numpy.random.rand" in symbols
        assert "numpy.random.default_rng" in symbols

    def test_clean_fixture_is_silent(self):
        findings = _run([_unit("det_clean.py", "repro.net._fix_det_clean")],
                        only=["determinism"])
        assert findings == []

    def test_allowlisted_module_is_exempt(self):
        # same bad source, but under the planner-metadata allowlist
        findings = _run([_unit("det_bad.py", "repro.core.multicast")],
                        only=["determinism"])
        assert findings == []

    def test_out_of_scope_module_is_exempt(self):
        findings = _run([_unit("det_bad.py", "repro.models.block")],
                        only=["determinism"])
        assert findings == []


# ---------------------------------------------------------------------------
# set-iteration
# ---------------------------------------------------------------------------


class TestSetIterationRule:
    def test_bad_fixture_fires_on_every_pattern(self):
        findings = _run([_unit("iter_bad.py", "repro.net._fix_iter_bad")],
                        only=["set-iteration"])
        # for-over-param, inferred comprehension, union, dict.fromkeys,
        # list() passthrough, self-attr, literal, sum() reducer
        assert _lines(findings) == [11, 19, 23, 29, 34, 43, 47, 52]
        assert all(f.rule == "set-iteration" for f in findings)

    def test_clean_fixture_is_silent(self):
        findings = _run([_unit("iter_clean.py", "repro.net._fix_iter_clean")],
                        only=["set-iteration"])
        assert findings == []

    def test_fix_sorted_attaches_patch(self):
        findings = _run([_unit("iter_bad.py", "repro.net._fix_iter_bad")],
                        only=["set-iteration"], fix_sorted=True)
        by_line = {f.line: f for f in findings}
        assert by_line[11].suggestion is not None
        assert "sorted(devs)" in by_line[11].suggestion

    def test_no_suggestion_without_flag(self):
        findings = _run([_unit("iter_bad.py", "repro.net._fix_iter_bad")],
                        only=["set-iteration"])
        assert all(f.suggestion is None for f in findings)


# ---------------------------------------------------------------------------
# exact-float
# ---------------------------------------------------------------------------


class TestExactFloatRule:
    def test_bad_fixture_fires_on_every_pattern(self):
        findings = _run([_unit("float_bad.py", "repro.net._fix_float_bad")],
                        only=["exact-float"])
        # literal, annotated params, division, dataclass field, math const,
        # float() call, chained comparison
        assert _lines(findings) == [19, 23, 27, 31, 35, 39, 43]
        assert all(f.rule == "exact-float" for f in findings)

    def test_clean_fixture_is_silent(self):
        findings = _run([_unit("float_clean.py", "repro.net._fix_float_clean")],
                        only=["exact-float"])
        assert findings == []

    def test_rule_is_scoped_to_repro_net(self):
        findings = _run([_unit("float_bad.py", "repro.core.sim")],
                        only=["exact-float"])
        assert findings == []


# ---------------------------------------------------------------------------
# layering
# ---------------------------------------------------------------------------


class TestLayeringRule:
    def test_bad_fixture_fires_on_both_import_forms(self):
        findings = _run([_unit("layer_bad.py", "repro.net._fix_layer_bad")],
                        only=["layering"])
        assert _lines(findings) == [7, 12]
        by_line = {f.line: f for f in findings}
        assert "repro.serving" in by_line[7].message
        assert "lazy" in by_line[12].message
        assert "repro.obs" in by_line[12].message

    def test_clean_fixture_is_silent(self):
        findings = _run([_unit("layer_clean.py", "repro.net._fix_layer_clean")],
                        only=["layering"])
        assert findings == []


# ---------------------------------------------------------------------------
# event-reentrancy
# ---------------------------------------------------------------------------


class TestEventReentrancyRule:
    def test_bad_fixture_direct_and_transitive(self):
        findings = _run([_unit("reent_bad.py", "repro.net._fix_reent_bad")],
                        only=["event-reentrancy"])
        assert len(findings) == 2
        symbols = sorted(f.symbol for f in findings)
        # direct callback -> engine internal
        assert any("_evict_failed" in s for s in symbols)
        # helper chain -> capacity mutator
        assert any("fail_device" in s for s in symbols)
        transitive = next(f for f in findings if "fail_device" in f.symbol)
        # the reported chain walks through the intermediate helpers
        assert "_react" in transitive.symbol
        assert "_teardown" in transitive.symbol

    def test_clean_fixture_is_silent(self):
        findings = _run([_unit("reent_clean.py", "repro.net._fix_reent_clean")],
                        only=["event-reentrancy"])
        assert findings == []


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------


class TestPragmas:
    def test_same_line_disable(self):
        u = SourceUnit("x.py", "a = 1  # simcheck: disable=determinism\n")
        assert u.disabled("determinism", 1)
        assert not u.disabled("layering", 1)

    def test_standalone_pragma_covers_next_line(self):
        u = SourceUnit(
            "x.py",
            "# simcheck: disable=set-iteration\nfor_x = 1\nuntouched = 2\n",
        )
        assert u.disabled("set-iteration", 2)
        assert not u.disabled("set-iteration", 3)

    def test_disable_file_scope(self):
        u = SourceUnit(
            "x.py", "# simcheck: disable-file=exact-float\na = 1\nb = 2\n"
        )
        assert u.disabled("exact-float", 1)
        assert u.disabled("exact-float", 3)
        assert not u.disabled("determinism", 3)

    def test_multiple_rules_one_pragma(self):
        u = SourceUnit(
            "x.py", "a = 1  # simcheck: disable=determinism,set-iteration\n"
        )
        assert u.disabled("determinism", 1)
        assert u.disabled("set-iteration", 1)

    def test_justification_tail_is_not_a_rule(self):
        u = SourceUnit(
            "x.py",
            "a = 1  # simcheck: disable=layering -- CLI entrypoint, not library\n",
        )
        assert u.disabled("layering", 1)
        assert not u.disabled("CLI", 1)

    def test_exact_float_shorthand(self):
        u = SourceUnit(
            "x.py", "a = 1  # simcheck: exact-float -- sentinel compare\n"
        )
        assert u.disabled("exact-float", 1)

    def test_star_disables_everything(self):
        u = SourceUnit("x.py", "a = 1  # simcheck: disable=*\n")
        assert u.disabled("determinism", 1)
        assert u.disabled("event-reentrancy", 1)

    def test_pragma_suppresses_through_driver(self):
        src = (
            "import time\n"
            "def f():\n"
            "    return time.time()  # simcheck: disable=determinism -- ok\n"
        )
        findings = _run(
            [SourceUnit("p.py", src, module="repro.net._fix_pragma")],
            only=["determinism"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# module naming
# ---------------------------------------------------------------------------


class TestModuleNameFor:
    def test_src_tree(self):
        assert module_name_for("src/repro/net/flowsim.py") == "repro.net.flowsim"

    def test_package_init(self):
        assert module_name_for("src/repro/net/__init__.py") == "repro.net"

    def test_out_of_tree_falls_back_to_stem(self):
        assert module_name_for("tests/analysis_fixtures/det_bad.py") == "det_bad"


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


class TestBaseline:
    def _findings(self):
        return _run([_unit("det_bad.py", "repro.net._fix_det_bad")],
                    only=["determinism"])

    def test_placeholder_justification_fails_load(self, tmp_path):
        bl = Baseline.from_findings(self._findings())
        path = tmp_path / "baseline.json"
        bl.save(str(path))
        with pytest.raises(ValueError, match="justification"):
            Baseline.load(str(path))

    def test_round_trip_with_justifications(self, tmp_path):
        findings = self._findings()
        bl = Baseline.from_findings(findings)
        for e in bl.entries:
            e["justification"] = "golden fixture; kept for the rule test"
        path = tmp_path / "baseline.json"
        bl.save(str(path))
        loaded = Baseline.load(str(path))
        new, old, stale = loaded.split(findings)
        assert new == []
        assert len(old) == len(findings)
        assert stale == []

    def test_stale_entries_are_reported(self, tmp_path):
        findings = self._findings()
        bl = Baseline.from_findings(findings)
        for e in bl.entries:
            e["justification"] = "x"
        # the violations got fixed: nothing fires any more
        new, old, stale = bl.split([])
        assert new == []
        assert old == []
        assert len(stale) == len(bl.entries)

    def test_entry_missing_keys_fails_load(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps(
            {"version": 1, "entries": [{"rule": "determinism"}]}
        ))
        with pytest.raises(ValueError, match="missing"):
            Baseline.load(str(path))

    def test_wrong_version_fails_load(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps({"version": 99, "entries": []}))
        with pytest.raises(ValueError, match="v1"):
            Baseline.load(str(path))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _make_tree(tmp_path, fixture="det_bad.py", name="bad.py"):
    """Copy a fixture into an src-style tree so it scans with an in-scope
    module name (tmp/src/repro/net/bad.py -> repro.net.bad)."""
    pkg = tmp_path / "src" / "repro" / "net"
    pkg.mkdir(parents=True, exist_ok=True)
    shutil.copy(FIXTURES / fixture, pkg / name)
    return tmp_path / "src"


class TestCheckCLI:
    def test_findings_exit_1(self, tmp_path, capsys):
        root = _make_tree(tmp_path)
        assert check_cli.main([str(root)]) == 1
        out = capsys.readouterr().out
        assert "[determinism]" in out
        assert "6 finding(s)" in out

    def test_clean_exit_0(self, tmp_path, capsys):
        root = _make_tree(tmp_path, fixture="det_clean.py", name="clean.py")
        assert check_cli.main([str(root)]) == 0
        assert "simcheck: clean" in capsys.readouterr().out

    def test_rule_filter(self, tmp_path, capsys):
        root = _make_tree(tmp_path)  # det_bad has no set-iteration findings
        assert check_cli.main([str(root), "--rule", "set-iteration"]) == 0
        capsys.readouterr()

    def test_unknown_rule_exit_2(self, tmp_path, capsys):
        root = _make_tree(tmp_path)
        assert check_cli.main([str(root), "--rule", "nonsense"]) == 2
        assert "unknown rule" in capsys.readouterr().err

    def test_missing_baseline_exit_2(self, tmp_path, capsys):
        root = _make_tree(tmp_path)
        rc = check_cli.main([str(root), "--baseline", str(tmp_path / "no.json")])
        assert rc == 2
        capsys.readouterr()

    def test_list_rules(self, capsys):
        assert check_cli.main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in ("determinism", "set-iteration", "layering",
                    "exact-float", "event-reentrancy"):
            assert rid in out

    def test_json_format_and_json_out(self, tmp_path, capsys):
        root = _make_tree(tmp_path)
        json_file = tmp_path / "report.json"
        rc = check_cli.main(
            [str(root), "--format", "json", "--json-out", str(json_file)]
        )
        assert rc == 1
        stdout_report = json.loads(capsys.readouterr().out)
        file_report = json.loads(json_file.read_text())
        assert stdout_report == file_report
        assert file_report["counts"]["new"] == 6
        assert all(f["rule"] == "determinism" for f in file_report["findings"])

    def test_fix_sorted_prints_patch(self, tmp_path, capsys):
        root = _make_tree(tmp_path, fixture="iter_bad.py", name="iterbad.py")
        rc = check_cli.main([str(root), "--rule", "set-iteration", "--fix-sorted"])
        assert rc == 1
        assert "sorted(" in capsys.readouterr().out

    def test_update_baseline_then_clean_run(self, tmp_path, capsys):
        root = _make_tree(tmp_path)
        bl_path = tmp_path / "baseline.json"
        rc = check_cli.main(
            [str(root), "--baseline", str(bl_path), "--update-baseline"]
        )
        assert rc == 0
        capsys.readouterr()

        # placeholder justifications must fail the next load
        rc = check_cli.main([str(root), "--baseline", str(bl_path)])
        assert rc == 2
        capsys.readouterr()

        # fill justifications -> findings are baselined, exit 0
        data = json.loads(bl_path.read_text())
        for e in data["entries"]:
            e["justification"] = "grandfathered for the CLI round-trip test"
        bl_path.write_text(json.dumps(data))
        rc = check_cli.main([str(root), "--baseline", str(bl_path)])
        assert rc == 0
        assert "[baselined]" in capsys.readouterr().out

        # fix the file -> entries go stale, exit 1 so they get deleted
        shutil.copy(FIXTURES / "det_clean.py", root / "repro" / "net" / "bad.py")
        rc = check_cli.main([str(root), "--baseline", str(bl_path)])
        assert rc == 1
        assert "stale baseline entry" in capsys.readouterr().out

    def test_import_graph_dot_and_json(self, tmp_path, capsys):
        rc = check_cli.main(["--import-graph", "json", str(SRC_REPRO)])
        assert rc == 0
        graph = json.loads(capsys.readouterr().out)
        assert "repro.net.flowsim" in graph["nodes"]
        # the layering fix: simulator sizes KV flows from repro.workloads
        assert any(
            e["src"] == "repro.core.simulator"
            and e["dst"].startswith("repro.workloads")
            for e in graph["edges"]
        )
        out_file = tmp_path / "graph.dot"
        rc = check_cli.main(
            ["--import-graph", "dot", "--import-graph-out", str(out_file),
             str(SRC_REPRO)]
        )
        assert rc == 0
        capsys.readouterr()
        dot = out_file.read_text()
        assert dot.startswith("digraph")
        assert "repro.net.flowsim" in dot

    def test_import_graph_is_deterministic(self, capsys):
        assert check_cli.main(["--import-graph", "json", str(SRC_REPRO)]) == 0
        first = capsys.readouterr().out
        assert check_cli.main(["--import-graph", "json", str(SRC_REPRO)]) == 0
        assert capsys.readouterr().out == first


# ---------------------------------------------------------------------------
# import smoke
# ---------------------------------------------------------------------------


class TestImportSmoke:
    def test_iter_modules_src_style(self, tmp_path):
        pkg = tmp_path / "src" / "mypkg"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "mod.py").write_text("X = 1\n")
        mods = import_smoke.iter_modules(str(tmp_path / "src"))
        assert [m for _, m in mods] == ["mypkg", "mypkg.mod"]

    def test_iter_modules_plain_package(self, tmp_path):
        pkg = tmp_path / "benchmarks"
        pkg.mkdir()
        (pkg / "common.py").write_text("X = 1\n")
        mods = import_smoke.iter_modules(str(pkg))
        assert [m for _, m in mods] == ["benchmarks.common"]

    def test_clean_tree_exit_0(self, tmp_path, capsys):
        pkg = tmp_path / "src" / "smokepkg_ok"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "good.py").write_text("VALUE = 40 + 2\n")
        assert import_smoke.main([str(tmp_path / "src")]) == 0
        assert "0 failure(s)" in capsys.readouterr().out

    def test_syntax_error_exit_1(self, tmp_path, capsys):
        pkg = tmp_path / "src" / "smokepkg_syn"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "broken.py").write_text("def f(:\n")
        assert import_smoke.main([str(tmp_path / "src")]) == 1
        assert "compile FAILED" in capsys.readouterr().out

    def test_import_error_exit_1(self, tmp_path, capsys):
        pkg = tmp_path / "src" / "smokepkg_imp"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "dead.py").write_text("import no_such_module_anywhere_xyz\n")
        assert import_smoke.main([str(tmp_path / "src")]) == 1
        assert "import FAILED" in capsys.readouterr().out

    def test_missing_root_exit_2(self, tmp_path, capsys):
        assert import_smoke.main([str(tmp_path / "nope")]) == 2
        capsys.readouterr()


# ---------------------------------------------------------------------------
# the gate: the real tree is clean
# ---------------------------------------------------------------------------


class TestRepoIsClean:
    def test_src_repro_has_no_findings(self):
        new, old, stale = check_cli.run_check([str(SRC_REPRO)])
        assert new == [], "\n".join(f.format() for f in new)
        assert stale == []

    def test_committed_baseline_is_loadable_and_empty_or_justified(self):
        path = REPO / "analysis_baseline.json"
        bl = Baseline.load(str(path))
        # ISSUE acceptance: empty, or at most 3 entries each with a
        # committed justification (load() already enforces justifications)
        assert len(bl.entries) <= 3

    def test_load_tree_is_sorted_and_parses_everything(self):
        units = load_tree([str(SRC_REPRO / "analysis")])
        paths = [u.path for u in units]
        assert paths == sorted(paths)
        assert any(u.module == "repro.analysis.core" for u in units)
