"""TPU data-plane collectives validated numerically on 8 host devices.

Runs in a subprocess because --xla_force_host_platform_device_count must be
set before jax initializes (the main pytest process has 1 device).
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_chain_broadcast_delivers_to_all_ranks():
    """The pipelined ppermute chain broadcast (Fig. 13a TPU adaptation):
    every rank ends with the full parameter vector injected at rank 0."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.collectives import chain_broadcast
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((8,), ("chain",))
        params = jnp.arange(1000, dtype=jnp.float32)
        out = chain_broadcast(params, mesh, "chain", n_blocks=4)
        np.testing.assert_allclose(np.asarray(out), np.arange(1000))
        print("ok")
    """)


def test_chain_broadcast_step_count_matches_pipelining_model():
    from repro.core.collectives import pipelined_chain_steps

    # Fig. 13a: n_blocks + n_ranks - 2 forwarding steps, not n_blocks*(R-1)
    assert pipelined_chain_steps(16, 8) == 16 + 7 - 1
    assert pipelined_chain_steps(16, 2) < 16 * 1 + 8


def test_chain_broadcast_seconds_independent_of_ranks():
    from repro.core.collectives import chain_broadcast_seconds

    t2 = chain_broadcast_seconds(16e9, 12.5e9, n_blocks=64, n_ranks=2)
    t8 = chain_broadcast_seconds(16e9, 12.5e9, n_blocks=64, n_ranks=8)
    assert t8 / t2 < 1.15  # ~independent of receiver count (pipelined)


def test_sharded_group_transfer_allgather():
    """Fig. 14: each source device ships a 1/g shard one chain hop; the
    target scale-up domain AllGathers to reconstruct the full block."""
    _run("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.collectives import sharded_group_transfer
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((2, 4), ("chain", "scaleup"))
        full = jnp.arange(64, dtype=jnp.float32)

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(None, "scaleup"),), out_specs=P("chain", None),
                           check_rep=False)
        def xfer(shard):
            out = sharded_group_transfer(shard[0], "scaleup", "chain", 0, 1)
            return out[None]

        # each scaleup rank of chain-rank 0 holds a distinct 16-elem shard
        out = xfer(full.reshape(1, 64))
        got = np.asarray(out)[1]  # chain rank 1 view
        np.testing.assert_allclose(got, np.arange(64))
        print("ok")
    """)
