"""Anomaly-triggered flight recorder (repro.obs.flightrec).

The load-bearing properties:

  * a device/leaf failure observed through the recorder's FlowSim
    subscription dumps ONE incident bundle, byte-identical across
    identically-seeded runs, that still loads as a Chrome/Perfetto trace
    (the ``incident`` header is an ignored unknown top-level key);
  * an SLO-monitor escalation to ``page`` is edge-triggered: one bundle
    per escalation, re-armed only after the fleet recovers;
  * attaching the recorder changes NOTHING about the simulation — the
    flow-event stream is bit-for-bit the unrecorded one;
  * ring eviction is surfaced, not silent: the bundle header carries the
    ring's ``dropped`` count and an explicit ``truncated`` flag when
    eviction ate into the dump window, plus a one-time warning metric.
"""

import json

import pytest

import repro.core.simulator as sim
from repro.net import FlowEventLog
from repro.net.events import DEVICE_FAILED, FLOW_STARTED, NetEvent
from repro.obs import FlightRecorder, MetricRegistry, SLOMonitor, Tracer
from repro.serving import traces


def _failure_run(tmp_path, *, seed=0, ring=1024):
    tracer = Tracer()
    rec = FlightRecorder(tracer, out_dir=str(tmp_path), ring=ring)
    s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=seed,
                      tracer=tracer, flight_recorder=rec)
    s.schedule(6.0, lambda sm: sm.flowsim.fail_device(3, sm.now))
    s.run(traces.burstgpt(duration=12.0, base_rate=4.0, seed=seed + 11))
    return rec


def test_device_failure_dumps_incident_bundle(tmp_path):
    rec = _failure_run(tmp_path)
    assert len(rec.dumps) == 1
    doc = json.loads(open(rec.dumps[0]).read())
    inc = doc["incident"]
    assert inc["trigger"] == "net:device_failed"
    assert inc["context"]["device"] == 3
    assert inc["t"] == 6.0 and inc["schema"] == 1
    # the ring captured the pre-incident window and nothing was lost
    assert inc["ring"]["dropped"] == 0 and inc["ring"]["truncated"] is False
    assert inc["ring"]["events"], "dump window contains no net events"
    # the mid-flight scale op appears in the critical-path section
    assert inc["critical_path"]["n_ops"] >= 1
    for op in inc["critical_path"]["ops"]:
        assert op["coverage"] >= 0.95


def test_incident_bundle_is_perfetto_loadable(tmp_path):
    rec = _failure_run(tmp_path)
    doc = json.loads(open(rec.dumps[0]).read())
    # regular Chrome trace shape: viewers ignore the extra "incident" key
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs and all(e["ph"] in ("M", "X", "i") for e in evs)
    names = {e["name"] for e in evs if e["ph"] != "M"}
    assert "scale_op" in names  # the op in flight at the failure
    # only the trailing window is shipped, not the whole run
    w0 = (6.0 - rec.window_s) * 1e6
    for e in evs:
        if e["ph"] != "M":
            assert e["ts"] + e.get("dur", 0.0) >= w0 - 1.0


def test_incident_bundle_is_byte_deterministic(tmp_path):
    a = _failure_run(tmp_path / "a")
    b = _failure_run(tmp_path / "b")
    ba = open(a.dumps[0], "rb").read()
    bb = open(b.dumps[0], "rb").read()
    assert ba == bb


def test_flight_recorder_changes_nothing(tmp_path):
    def lines(flight_recorder):
        s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=0,
                          tracer=Tracer() if flight_recorder else None,
                          flight_recorder=flight_recorder)
        log = FlowEventLog()
        s.flowsim.subscribe(log)
        s.schedule(6.0, lambda sm: sm.flowsim.fail_device(3, sm.now))
        res = s.run(traces.burstgpt(duration=12.0, base_rate=4.0, seed=7))
        return log.lines(), res.p99_ttft()

    off_lines, off_p99 = lines(None)
    rec = FlightRecorder(Tracer(), out_dir=str(tmp_path))
    on_lines, on_p99 = lines(rec)
    assert off_lines == on_lines
    assert off_p99 == on_p99
    assert rec.dumps  # and it still dumped the incident


# ---------------------------------------------------------------------------
# SLO-page trigger (edge-triggered via poll)
# ---------------------------------------------------------------------------


def _paging_monitor():
    mon = SLOMonitor(ttft_slo_s=0.1, windows_s=(5.0,))
    for i in range(40):  # every observation misses -> fast burn -> page
        mon.observe_ttft("m", 1.0 + i * 0.1, 5.0)
    return mon


def test_slo_page_triggers_one_dump(tmp_path):
    mon = _paging_monitor()
    rec = FlightRecorder(Tracer(), slo_monitor=mon, out_dir=str(tmp_path))
    assert mon.fleet_health(5.0)["status"] == "page"
    rec.poll(5.0)
    assert len(rec.dumps) == 1
    doc = json.loads(open(rec.dumps[0]).read())
    assert doc["incident"]["trigger"] == "slo:page"
    assert doc["incident"]["context"]["tenants"] == ["m"]
    assert doc["incident"]["fleet_health"]["status"] == "page"
    # edge-triggered: still paging -> no second dump
    rec.poll(5.5)
    assert len(rec.dumps) == 1


def test_slo_page_rearms_after_recovery(tmp_path):
    mon = _paging_monitor()
    rec = FlightRecorder(Tracer(), slo_monitor=mon, out_dir=str(tmp_path))
    rec.poll(5.0)
    assert len(rec.dumps) == 1
    # burn windows drain -> status recovers -> re-armed
    far = 5.0 + 10 * max(mon.windows_s)
    assert mon.fleet_health(far)["status"] != "page"
    rec.poll(far)
    for i in range(40):
        mon.observe_ttft("m", far + i * 0.1, 5.0)
    rec.poll(far + 4.0)
    assert len(rec.dumps) == 2


def test_fleet_scheduler_polls_recorder(tmp_path):
    """The MaaS control loop drives poll(): a paging tenant mid-run dumps
    without any simulator involvement."""
    from repro.core import topology as tp
    from repro.serving.maas import FleetScheduler

    mon = _paging_monitor()
    rec = FlightRecorder(Tracer(), slo_monitor=mon, out_dir=str(tmp_path))
    fleet = FleetScheduler(
        tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0)),
        slo_monitor=mon, flight_recorder=rec,
    )
    fleet.tick(5.0)
    assert len(rec.dumps) == 1


# ---------------------------------------------------------------------------
# ring truncation surfacing (the silent-eviction bugfix)
# ---------------------------------------------------------------------------


def _flow_event(t):
    from repro.net.flows import Flow, FlowKind

    return NetEvent(FLOW_STARTED, t,
                    flow=Flow(FlowKind.COLD_START, 0, 1, 1.0))


def test_truncated_dump_is_flagged_and_counted(tmp_path):
    metrics = MetricRegistry()
    rec = FlightRecorder(Tracer(), ring=4, metrics=metrics,
                         out_dir=str(tmp_path), window_s=100.0)
    for i in range(20):  # 16 evictions: the window start is long gone
        rec._on_net_event(_flow_event(float(i)))
    rec.trigger("test:manual", 19.0)
    doc = json.loads(open(rec.dumps[0]).read())
    ring = doc["incident"]["ring"]
    assert ring["dropped"] == 16
    assert ring["truncated"] is True
    assert len(ring["events"]) == 4
    assert metrics.counter("flightrec.truncated_dumps").value == 1
    # one-time: a second truncated dump doesn't re-count
    rec.trigger("test:manual", 19.5)
    assert metrics.counter("flightrec.truncated_dumps").value == 1


def test_untruncated_ring_with_drops_outside_window(tmp_path):
    """Evictions older than the window are NOT truncation: everything the
    dump asked for is still in the ring."""
    rec = FlightRecorder(Tracer(), ring=4, out_dir=str(tmp_path),
                         window_s=2.0)
    for i in range(20):
        rec._on_net_event(_flow_event(float(i)))
    rec.trigger("test:manual", 19.0)  # window [17, 19]; ring holds [16..19]
    ring = json.loads(open(rec.dumps[0]).read())["incident"]["ring"]
    assert ring["dropped"] == 16
    assert ring["truncated"] is False


def test_max_dumps_cap(tmp_path):
    metrics = MetricRegistry()
    rec = FlightRecorder(Tracer(), out_dir=str(tmp_path), max_dumps=2,
                         metrics=metrics)
    for i in range(5):
        rec.trigger("test:storm", float(i))
    assert len(rec.dumps) == 2 and rec.skipped == 3
    assert metrics.counter("flightrec.skipped_dumps").value == 3


def test_failure_events_trigger_via_subscription(tmp_path):
    rec = FlightRecorder(Tracer(), out_dir=str(tmp_path))
    rec._on_net_event(NetEvent(DEVICE_FAILED, 3.0, device=7))
    assert len(rec.dumps) == 1
    doc = json.loads(open(rec.dumps[0]).read())
    assert doc["incident"]["trigger"] == "net:device_failed"
    assert doc["incident"]["context"]["device"] == 7
