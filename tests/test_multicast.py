"""Hypothesis property tests for the Algorithm-11 multicast planner."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import multicast as mc
from repro.core import topology as tp


def _cluster(n_hosts, devs, bw=100.0, hosts_per_leaf=2):
    topo = tp.make_cluster(n_hosts, devs, bw_gbps=bw, hosts_per_leaf=hosts_per_leaf)
    return tp.add_host_sources(topo)


@settings(max_examples=40, deadline=None)
@given(
    n_hosts=st.integers(2, 6),
    devs=st.integers(2, 8),
    n_src=st.integers(1, 4),
    n_tgt_frac=st.floats(0.2, 1.0),
)
def test_plan_covers_each_target_exactly_once(n_hosts, devs, n_src, n_tgt_frac):
    topo = _cluster(n_hosts, devs)
    accel = [d.id for d in topo.devices if not d.is_host]
    srcs = accel[:n_src]
    for i in srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE  # egress free
    spares = [d.id for d in topo.spares()]
    n = max(1, int(len(spares) * n_tgt_frac))
    plan = mc.plan_multicast(topo, srcs, spares, n)
    assert len(plan.covered) == min(n, len(spares))
    assert len(set(plan.covered)) == len(plan.covered)  # exactly once
    assert set(plan.covered) <= set(spares)
    assert mc.validate_plan(topo, plan) == []


@settings(max_examples=40, deadline=None)
@given(n_hosts=st.integers(2, 5), devs=st.integers(2, 8), seed=st.integers(0, 100))
def test_interference_freedom(n_hosts, devs, seed):
    """No multicast flow may share a direction with serving traffic, and no
    link carries two same-direction multicast flows (full-duplex rule)."""
    import random

    rng = random.Random(seed)
    topo = _cluster(n_hosts, devs)
    accel = [d.id for d in topo.devices if not d.is_host]
    srcs = []
    for i in accel[: len(accel) // 2]:
        role = rng.choice([tp.Role.PREFILL, tp.Role.DECODE])
        topo.device(i).role = role
        topo.device(i).model = "m"
        srcs.append(i)
    spares = [d.id for d in topo.spares()]
    if not spares:
        return
    plan = mc.plan_multicast(topo, srcs, spares, len(spares))
    assert mc.validate_plan(topo, plan) == []
    # prefill sources (busy egress) must have been pruned
    busy = {i for i in srcs if topo.device(i).egress_busy}
    chain_sources = {
        i for c in plan.chains for i in c.nodes[0].device_ids if c.nodes[0].is_source
    }
    assert not (chain_sources & busy)


def test_chain_time_independent_of_receiver_count():
    """Fig. 13a: pipelined serial chain time ~ |M|/B regardless of targets."""
    model_bytes = 16_000_000_000
    t1 = mc.chain_time_model(model_bytes, 100.0, 1)
    t8 = mc.chain_time_model(model_bytes, 100.0, 8)
    assert t1 == pytest.approx(t8)
    # unpipelined store-and-forward scales linearly (the strawman)
    t8_sf = mc.chain_time_model(model_bytes, 100.0, 8, pipelined=False)
    assert t8_sf == pytest.approx(8 * t1)


def test_plan_generation_under_40ms():
    """Paper §5.2: plan generation must be online-fast (<40 ms) even for a
    large cluster."""
    topo = _cluster(32, 8)
    accel = [d.id for d in topo.devices if not d.is_host]
    for i in accel[:8]:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, accel[:8], spares, len(spares))
    assert plan.gen_seconds < 0.040
    assert mc.validate_plan(topo, plan) == []


def test_multi_chain_per_leaf():
    """Fig. 12: with sources in two leaves, the planner forms >=2 chains so
    live scaling has more interference-free tails."""
    topo = _cluster(4, 4, hosts_per_leaf=1)  # leaf per host
    # one decode source in leaf 0 and one in leaf 2
    for i in (0, 8):
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, [0, 8], spares, len(spares))
    assert len(plan.chains) >= 2
    assert mc.validate_plan(topo, plan) == []
    assert len(plan.live_scale_nodes) == len(plan.chains)


def test_sharded_transfer_speedup():
    """Fig. 14: a g-device source group to a g-device target group moves
    1/g of the bytes per link -> g x effective bandwidth."""
    topo = _cluster(2, 4)
    for i in range(4):  # host 0 group = scale-up domain 0
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()][:4]  # host 1 group
    plan = mc.plan_multicast(topo, list(range(4)), spares, 4)
    assert mc.validate_plan(topo, plan) == []
    edge = plan.all_edges()[0]
    assert edge.sharded_ways == 4
    assert edge.bw_gbps == pytest.approx(4 * 100.0)


def test_fastest_first_chain_order():
    """Fig. 13b: within a leaf, higher-aggregate-bandwidth targets come
    earlier (Algorithm 11 Line 3 orders leaves by the SOURCE leaf rank
    first, so the cross-leaf order is intra-leaf-first, not global-bw)."""
    topo = tp.make_cluster(3, 2, bw_gbps=100.0)
    # host2's devices are faster (different leaf from the source)
    for d in topo.devices:
        if d.host == 2:
            d.bw_gbps = 400.0
    topo.device(0).model = "m"
    topo.device(0).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, [0], spares, len(spares))
    by_leaf: dict[int, list[float]] = {}
    order: list[int] = []
    for c in plan.chains:
        for n in c.targets:
            by_leaf.setdefault(n.leaf, []).append(n.agg_bw_gbps)
            if n.leaf not in order:
                order.append(n.leaf)
    for leaf, aggs in by_leaf.items():
        assert aggs == sorted(aggs, reverse=True), (leaf, aggs)
    assert order[0] == topo.device(0).leaf  # source leaf served first
