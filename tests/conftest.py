import os
import sys

# Tests run on the single real CPU device — the 512-device flag is ONLY for
# the dry-run (launch/dryrun.py sets it before any jax import).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
