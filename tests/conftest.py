import os
import sys

# Tests run on the single real CPU device — the 512-device flag is ONLY for
# the dry-run (launch/dryrun.py sets it before any jax import).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
