"""Exact-float fixture (BAD): bare ==/!= touching floats.

Scanned with module name ``repro.net._fix_float_bad`` — never imported.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class Probe:
    rate: float
    count: int


def literal_compare(x):
    return x == 1.0                      # BAD: float literal


def annotated_param(remaining: float, size: float):
    return remaining == size             # BAD: both annotated float


def division_result(a, b, c):
    return a / b == c                    # BAD: true division is float


def dataclass_field(p: Probe, q: Probe):
    return p.rate != q.rate              # BAD: float-annotated field


def math_const(x):
    return x == math.inf                 # BAD: float constant attribute


def float_call(x):
    return float(x) == 3                 # BAD: float() result


def chained(a: float, b, c):
    return a == b == c                   # BAD: chain contains float operand
