"""Layering fixture (BAD): upward imports from a low layer.

Scanned with module name ``repro.net._fix_layer_bad`` — NEVER imported
(the imports below would be violations precisely because they resolve).
"""

import repro.serving                     # BAD: net must not see serving


def lazy_violation():
    # function-level import is still a dependency edge
    from repro.obs import trace          # BAD: net must not see obs
    return trace
