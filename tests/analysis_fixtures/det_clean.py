"""Determinism fixture (CLEAN): the sanctioned ways to do time and RNG.

Scanned with module name ``repro.net._fix_det_clean`` — never imported.
"""

import random
import time

import numpy as np


def seeded_rng(seed: int):
    rng = np.random.default_rng(seed)          # OK: explicit seed
    rng2 = np.random.default_rng(np.random.SeedSequence([seed, 1]))  # OK
    r = random.Random(seed)                    # OK: seeded instance
    return rng.random() + rng2.random() + r.random()  # instance methods, not global


def pragma_escape():
    # a deliberate wall-clock read, visibly justified:
    t = time.perf_counter()  # simcheck: disable=determinism -- metadata only
    return t
