"""Event-reentrancy fixture (BAD): a subscriber mutating the engine.

Scanned with module name ``repro.net._fix_reent_bad`` — never imported.
Mirrors the real shape: an engine with ``subscribe`` + private mutators,
a subscriber whose callback reaches one through a helper chain.
"""

from __future__ import annotations


class Engine:
    def __init__(self):
        self._subscribers = []

    def subscribe(self, cb):
        self._subscribers.append(cb)
        return cb

    def start(self, flow):
        pass

    def fail_device(self, dev):
        self._evict_failed({dev})

    def _evict_failed(self, dead):
        pass


class BadDirect:
    def __init__(self, eng: Engine):
        self.eng = eng
        eng.subscribe(self._on_event)

    def _on_event(self, event):
        self.eng._evict_failed(set())        # BAD: engine internal


class BadTransitive:
    def __init__(self, eng: Engine):
        self.eng = eng
        eng.subscribe(self._on_event)

    def _on_event(self, event):
        self._react(event)

    def _react(self, event):
        self._teardown(event)

    def _teardown(self, event):
        self.eng.fail_device(0)              # BAD: nested capacity mutation
