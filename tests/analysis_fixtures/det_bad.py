"""Determinism fixture (BAD): every banned pattern, one per line.

Scanned with module name ``repro.net._fix_det_bad`` — never imported.
"""

import random
import time as _time
from datetime import datetime
from time import perf_counter

import numpy as np


def wall_clock_reads():
    a = _time.time()          # BAD: aliased module
    b = perf_counter()        # BAD: from-import
    c = datetime.now()        # BAD: datetime
    return a, b, c


def global_rng():
    x = random.random()       # BAD: global random module
    y = np.random.rand(4)     # BAD: numpy hidden global RNG
    z = np.random.default_rng()  # BAD: seedable ctor without a seed
    return x, y, z
