"""Event-reentrancy fixture (CLEAN): reacting through the sanctioned APIs.

Scanned with module name ``repro.net._fix_reent_clean`` — never imported.
"""

from __future__ import annotations


class Engine:
    def __init__(self):
        self._subscribers = []

    def subscribe(self, cb):
        self._subscribers.append(cb)
        return cb

    def start(self, flow):
        pass

    def remove(self, flow):
        pass

    def estimate_transfer_time(self, src, dst, nbytes):
        return 0.0

    def _evict_failed(self, dead):
        pass


class GoodSubscriber:
    """Reacts inside the event, but only through the designed surface."""

    def __init__(self, eng: Engine):
        self.eng = eng
        self.log = []
        eng.subscribe(self._on_event)

    def _on_event(self, event):
        self.log.append(event)               # OK: observing
        self._replan(event)

    def _replan(self, event):
        t = self.eng.estimate_transfer_time(0, 1, 1024)  # OK: read-only
        if t > 0:
            self.eng.start(object())         # OK: sanctioned reaction API
