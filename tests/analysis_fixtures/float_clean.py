"""Exact-float fixture (CLEAN): epsilon discipline and honest sentinels.

Scanned with module name ``repro.net._fix_float_clean`` — never imported.
"""

from __future__ import annotations

import dataclasses


def flow_done_eps(size: float) -> float:
    return max(1e-9, 1e-12 * size)


def epsilon_compare(remaining: float, size: float) -> bool:
    return remaining <= flow_done_eps(size)   # OK: the sanctioned helper


def ordering(a: float, b: float) -> bool:
    return a < b                              # OK: ordering, not equality


def int_compare(n: int, m: int) -> bool:
    return n == m                             # OK: ints compare exactly


@dataclasses.dataclass
class Probe:
    count: int


def int_field(p: Probe) -> bool:
    return p.count == 0                       # OK: int-annotated field


def sentinel(degrade: float) -> str:
    # a deliberate exact compare against an assigned-only sentinel:
    if degrade != 1.0:  # simcheck: exact-float -- sentinel set by assignment
        return "degraded"
    return "ok"
