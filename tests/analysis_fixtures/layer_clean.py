"""Layering fixture (CLEAN): the edges repro.net is allowed.

Scanned with module name ``repro.net._fix_layer_clean`` — never imported.
"""

import dataclasses                        # OK: stdlib is unconstrained

from repro.core.topology import Topology  # OK: net -> core.topology
from repro.core import multicast          # OK: net -> core.multicast
from repro.net import flows               # OK: intra-package

__all__ = ["dataclasses", "Topology", "multicast", "flows"]
