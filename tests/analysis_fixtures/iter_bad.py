"""Set-iteration fixture (BAD): order-dependent walks over sets.

Scanned with module name ``repro.net._fix_iter_bad`` — never imported.
"""

from __future__ import annotations


def direct_iteration(devs: set[int]) -> list[int]:
    out = []
    for d in devs:                      # BAD: param annotated set
        out.append(d)
    return out


def inferred_from_assignment():
    seen = set()
    seen.add(3)
    return [x * 2 for x in seen]        # BAD: comprehension over inferred set


def union_of_sets(a: set[int], b: frozenset[int]):
    for x in a | b:                     # BAD: union is still a set
        yield x


def dict_built_from_set(keys: set[str]):
    d = dict.fromkeys(keys, 0)
    for k in d:                         # BAD: dict inherits set order
        yield k


def passthrough(devs: set[int]):
    for d in list(devs):                # BAD: list() preserves set order
        yield d


class Holder:
    def __init__(self):
        self.members: set[int] = set()

    def walk(self):
        return [m for m in self.members]  # BAD: self-attr set


def literal_set():
    for x in {3, 1, 2}:                 # BAD: set literal
        yield x


def float_accumulation(rates: set[float]) -> float:
    return sum(rates)                   # BAD: float sum in hash order
