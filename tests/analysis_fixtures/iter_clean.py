"""Set-iteration fixture (CLEAN): every sanctioned way to consume a set.

Scanned with module name ``repro.net._fix_iter_clean`` — never imported.
"""

from __future__ import annotations


def sorted_iteration(devs: set[int]) -> list[int]:
    return [d for d in sorted(devs)]    # OK: sorted() fixes the order


def order_insensitive(devs: set[int]):
    return (
        len(devs),
        min(devs),
        max(devs),
        any(d > 3 for d in devs),       # OK: short-circuit reductions
        all(d < 9 for d in devs),
    )


def membership(devs: set[int], x: int) -> bool:
    return x in devs                    # OK: membership, not iteration


def lists_are_fine(devs: list[int]):
    for d in devs:                      # OK: lists have defined order
        yield d


def pragma_escape(devs: set[int]):
    for d in devs:  # simcheck: disable=set-iteration -- feeds an order-free counter
        yield d
