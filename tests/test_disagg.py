"""PD-disaggregated runtime: KV migration numerics + §5.4 policy paths."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving.disagg import ClusterRuntime, KVMigrationChannel
from repro.serving.disagg import pools as P
from repro.serving.disagg.kv_migration import MigrationPayload, payload_bytes
from repro.serving.engine import InstanceEngine, ServeRequest

CFG = get_config("granite-8b", reduced=True)
PARAMS = TF.init_params(jax.random.PRNGKey(0), CFG)


def _engine(n_slots=2, max_seq=32):
    return InstanceEngine(CFG, PARAMS, n_slots=n_slots, max_seq=max_seq)


def _runtime(**kw):
    kw.setdefault("topo", tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0)))
    kw.setdefault(
        "policy", PolicyConfig(max_instances=4, kv_upper=0.5, scale_down_timeout_s=0.4)
    )
    kw.setdefault("n_prefill", 2)
    kw.setdefault("n_decode", 1)
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 48)
    kw.setdefault("prefill_capacity_tps", 200.0)
    kw.setdefault("decode_capacity_tps", 50.0)
    kw.setdefault("model_bytes", int(50e6))
    return ClusterRuntime(CFG, PARAMS, **kw)


# ---------------------------------------------------------------------------
# KV migration correctness
# ---------------------------------------------------------------------------


def test_migrated_decode_matches_colocated():
    """Prefill on engine A, migrate KV, decode on engine B == one engine."""
    prompt = (np.arange(9) % CFG.vocab_size).astype(np.int32)

    colo = _engine()
    colo.submit(ServeRequest(1, prompt, 6))
    (ref,) = colo.run_until_done()

    pre, dec = _engine(), _engine()
    req = ServeRequest(1, prompt, 6)
    first, one = pre.prefill_only(req)
    assert dec.admit_prefilled(req, first, one)
    for _ in range(50):
        dec.step()
        if req.done:
            break
    assert req.done
    assert req.out_tokens == ref.out_tokens  # bit-identical continuation


def test_runtime_tokens_match_colocated_reference():
    """Every request served through the full disagg runtime (pools +
    migration channel + handoff) decodes the same tokens as a lone engine."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab_size, size=7).astype(np.int32) for _ in range(4)]

    rt = _runtime()
    t = 0.0
    rids = [rt.submit(p, 5, t) for p in prompts]
    for _ in range(500):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
    assert rt.n_outstanding == 0

    ref_eng = _engine(n_slots=1)
    for rid, prompt in zip(rids, prompts):
        ref_eng.submit(ServeRequest(100 + rid, prompt, 5))
        (ref,) = ref_eng.run_until_done()
        assert rt.completed[rid].out_tokens == ref.out_tokens


def test_no_dropped_or_gapped_tokens():
    rt = _runtime()
    rng = np.random.default_rng(1)
    t = 0.0
    n = 6
    for _ in range(n):
        rt.submit(rng.integers(0, CFG.vocab_size, size=8).astype(np.int32), 4, t)
    for _ in range(500):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
    assert rt.n_outstanding == 0
    handoffs, gapped = rt.router.handoff_report()
    assert handoffs == rt.stats.migrations == n
    assert gapped == 0
    for r in rt.completed.values():
        assert len(r.out_tokens) == r.max_new_tokens  # contiguous, no gaps


def test_payload_bytes_scales_with_prompt():
    one = TF.init_caches(CFG, 1, 32)
    b8, b16 = payload_bytes(one, 8, 32), payload_bytes(one, 16, 32)
    assert 0 < b8 < b16
    assert b16 == pytest.approx(2 * b8, rel=0.01)


# ---------------------------------------------------------------------------
# Migration channel: topology bandwidth + incast
# ---------------------------------------------------------------------------


def _payload(nbytes, src=0, dst=1, rid=1):
    return MigrationPayload(
        rid=rid, request=None, first_token=0, cache_one=None, prompt_len=16,
        total_bytes=nbytes, n_pages=1, src_dev=src, dst_dev=dst,
    )


def test_channel_transfers_at_link_bandwidth():
    # two hosts x 1 dev: distinct scale-up domains, so the transfer rides
    # the 1e9 bytes/s scale-out NICs (not the NVLink fabric)
    topo = tp.make_cluster(2, 1, bw_gbps=8.0)
    ch = KVMigrationChannel(topo)
    ch.start(_payload(int(1e9)), now=0.0)
    assert ch.poll(0.5) == []  # half transferred
    done = ch.poll(1.01)
    assert [p.rid for p in done] == [1]


def test_incast_param_stream_halves_migration_bandwidth():
    """A live-scaling parameter stream into the destination shares its
    ingress link — the §5.4 motivation for mutation over direct scaling.
    The incast now *emerges* from the FlowSim's max-min sharing instead of
    the old per-ingress stream counter."""
    from repro.net import Flow, FlowKind

    topo = tp.make_cluster(3, 1, bw_gbps=8.0)
    ch = KVMigrationChannel(topo)
    param = Flow(FlowKind.MULTICAST_HOP, 2, 1, 5e9)  # parameters streaming in
    ch.net.start(param, 0.0)
    ch.start(_payload(int(1e9)), now=0.0)
    assert ch.poll(1.01) == []  # would have finished without the incast
    assert ch.poll(2.01) != []  # ingress shared 50/50 -> 2x the solo time
    assert ch.inflight_to(1) == 0
    # the migration finishing returns its ingress share to the param stream
    assert param.rate == pytest.approx(1e9)


# ---------------------------------------------------------------------------
# §5.4 policy: mutation, pre-scaling wiring, drain/retire
# ---------------------------------------------------------------------------


def test_loading_decode_is_a_migration_target():
    """A directly live-scaled decode instance must receive migrations while
    its parameters stream in — that shared ingress is the §5.4 incast the
    mutation policy avoids, so it has to be reachable to be modelled."""
    topo = tp.add_host_sources(tp.make_cluster(1, 2, bw_gbps=100.0))
    pool = P.EnginePool(topo)
    eng = _engine()
    eng.set_loaded_layers(0)
    pe = pool.add(P.PooledEngine(eng, 0, P.DECODE, state=P.LOADING))
    assert pool.serving(P.DECODE) == []  # cannot serve yet
    assert pool.migration_targets() == [pe]  # but KV pages may route to it


def test_decode_pressure_mutates_prefill_and_live_scales_replacement():
    rt = _runtime(n_slots=2)  # tiny decode KV -> pressure builds fast
    rng = np.random.default_rng(2)
    t = 0.0
    for _ in range(8):
        rt.submit(rng.integers(0, CFG.vocab_size, size=16).astype(np.int32), 6, t)
    saw_loading = False
    for _ in range(800):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
        saw_loading = saw_loading or any(
            pe.state == P.LOADING and pe.phase == P.PREFILL for pe in rt.pool.all()
        )
    assert rt.n_outstanding == 0
    assert rt.stats.mutations >= 1  # prefill flipped to decode in place ...
    assert rt.stats.mutation_param_bytes == 0  # ... moving zero parameter bytes
    assert rt.stats.live_scaled_prefill >= 1  # replacement prefill provisioned
    assert saw_loading  # and it actually went through the loading ramp
    _, gapped = rt.router.handoff_report()
    assert gapped == 0


def test_mutated_engine_keeps_decoding_correctly():
    """Requests admitted to a mutated (ex-prefill) engine still match the
    colocated reference — the mutation reuses the resident parameters."""
    rt = _runtime(n_slots=2)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, CFG.vocab_size, size=16).astype(np.int32) for _ in range(8)]
    t = 0.0
    rids = [rt.submit(p, 6, t) for p in prompts]
    for _ in range(800):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
    assert rt.n_outstanding == 0 and rt.stats.mutations >= 1
    ref_eng = _engine(n_slots=1, max_seq=48)
    for rid, prompt in zip(rids[:3], prompts[:3]):
        ref_eng.submit(ServeRequest(100 + rid, prompt, 6))
        (ref,) = ref_eng.run_until_done()
        assert rt.completed[rid].out_tokens == ref.out_tokens


def test_failed_nic_aborts_live_scale_and_replans_elsewhere():
    """A device-link failure mid-live-scale is handled entirely by the
    standalone runtime's OWN FlowSim failure subscription: the doomed
    engine is torn down INSIDE the failure event (no drain/retire
    round-trip), the failed device is never re-picked, and a replacement
    live-scale starts on a healthy spare within the same event."""
    # one device per host: the live-scale hop crosses scale-out NICs (an
    # intra-scale-up hop would finish at NVLink speed before the failure)
    rt = _runtime(
        topo=tp.add_host_sources(tp.make_cluster(5, 1, bw_gbps=100.0)),
        model_bytes=int(500e6),  # ~40 ms on a 100 Gbps NIC
    )
    t = 0.01
    rt.tick(t)
    pe = rt._live_scale(P.PREFILL, t)
    assert pe is not None and pe.state == P.LOADING
    target = pe.device_id
    # the parameter stream is real flows on the shared FlowSim
    assert rt.net.flows_into(target)
    retired_before = rt.stats.retired
    rt.net.fail_device(target, t + 0.01)
    # abort recorded + engine torn down + replacement planned, all inside
    # the failure event — zero ticks elapsed
    assert rt.stats.aborted_param_streams == 1
    assert rt.stats.cancelled_scales == 1
    assert rt.stats.failure_replans == 1
    assert all(pe2.device_id != target for pe2 in rt.pool.all())
    repl = [pe2 for pe2 in rt.pool.all() if pe2.state == P.LOADING]
    assert len(repl) == 1 and repl[0].device_id != target
    t += 0.02
    rt.tick(t)
    # nothing left for the drain path: no drain-path retirement happened
    assert rt.stats.retired == retired_before
    assert all(pe2.device_id != target or pe2.state != P.LOADING for pe2 in rt.pool.all())


def test_leaf_failure_handled_entirely_by_runtime_subscription():
    """Standalone-runtime mirror of the MaaS failure-subscription test
    (test_maas.py): a leaf dies mid-live-scale and the runtime's OWN
    FlowSim subscription retires the doomed LOADING engines and re-plans
    inside the failure event — ZERO per-flow-abort drains, no
    double-handling, and a replayed failure for the same devices is a
    no-op."""
    topo = tp.add_host_sources(tp.make_cluster(4, 2, hosts_per_leaf=1, bw_gbps=100.0))
    rt = _runtime(
        topo=topo,
        n_prefill=1,
        n_decode=1,
        policy=PolicyConfig(max_instances=3, kv_upper=0.5),
        prefill_capacity_tps=50.0,
        decode_capacity_tps=20.0,
        model_bytes=int(2e9),  # slow enough to catch the scale in flight
    )
    rng = np.random.default_rng(3)
    now = 0.0
    for _ in range(16):
        rt.submit(rng.integers(0, CFG.vocab_size, size=16).astype(np.int32), 6, now)
    loading = []
    for _ in range(400):
        now += 0.02
        rt.tick(now)
        # only fail a leaf that carries no initial engine, so the doomed
        # set is exactly its LOADING engines
        loading = [
            pe for pe in rt.pool.all()
            if pe.state == P.LOADING and topo.leaf_of(pe.device_id) != 0
        ]
        if loading:
            break
    assert loading, "no live-scale ever started"
    dead_leaf = topo.leaf_of(loading[0].device_id)
    doomed = {
        pe.device_id for pe in rt.pool.all()
        if pe.state == P.LOADING and topo.leaf_of(pe.device_id) == dead_leaf
    }
    n_doomed = len(doomed)
    engine_devs = {pe.device_id for pe in rt.pool.all()}
    # spares the in-event re-plan can land on (outside the dying leaf)
    avail = [
        d.id for d in topo.spares()
        if topo.leaf_of(d.id) != dead_leaf and rt.net.device_ok(d.id)
    ]
    expected_replans = min(n_doomed, len(avail))
    aborted_before = rt.stats.aborted_param_streams
    cancelled_before = rt.stats.cancelled_scales
    retired_before = rt.stats.retired

    rt.net.fail_leaf(dead_leaf, now)

    # handled entirely INSIDE the failure event: doomed engines gone from
    # the pool, replacements loading on a surviving leaf — zero ticks later
    assert rt.stats.aborted_param_streams == aborted_before + n_doomed
    assert rt.stats.cancelled_scales == cancelled_before + n_doomed
    assert rt.stats.failure_replans == expected_replans
    assert not doomed & {pe.device_id for pe in rt.pool.all()}
    repl = [
        pe for pe in rt.pool.all()
        if pe.state == P.LOADING and pe.device_id not in engine_devs
    ]
    assert len(repl) == expected_replans
    assert all(topo.leaf_of(pe.device_id) != dead_leaf for pe in repl)
    assert all(rt.net.device_ok(pe.device_id) for pe in repl)
    # 0 per-flow-abort drains: nothing was retired through the drain path
    assert rt.stats.retired == retired_before

    # replaying the failure for an already-dead device is a no-op
    before = (rt.stats.cancelled_scales, rt.stats.failure_replans,
              rt.stats.aborted_param_streams)
    rt.net.fail_device(next(iter(doomed)), now)
    assert (rt.stats.cancelled_scales, rt.stats.failure_replans,
            rt.stats.aborted_param_streams) == before

    # a few ticks later the drain path has not rediscovered the dead
    # engines, and the cluster still drains every request to completion
    for _ in range(3):
        now += 0.02
        rt.tick(now)
    assert rt.stats.cancelled_scales == cancelled_before + n_doomed
    assert not doomed & {pe.device_id for pe in rt.pool.all()}
    for _ in range(6000):
        if rt.n_outstanding == 0:
            break
        now += 0.02
        rt.tick(now)
    assert rt.n_outstanding == 0
    _, gapped = rt.router.handoff_report()
    assert gapped == 0


def test_failed_kv_migration_retargets_to_surviving_decode():
    """A NIC failure mid-KV-migration must not wedge the request: the
    frozen pages are re-targeted onto a surviving decode instance and the
    request completes without gaps.  Slow links keep the flow in flight
    across ticks; huge capacities pin the autoscaler so only the failure
    path is exercised."""
    topo = tp.add_host_sources(tp.make_cluster(5, 1, bw_gbps=0.001))
    rt = _runtime(
        topo=topo, n_prefill=1, n_decode=2,
        policy=PolicyConfig(max_instances=3, lower_util=0.0, kv_upper=0.99),
        prefill_capacity_tps=1e9, decode_capacity_tps=1e9,
    )
    rng = np.random.default_rng(11)
    t = 0.0
    for _ in range(3):
        rt.submit(rng.integers(0, CFG.vocab_size, size=8).astype(np.int32), 4, t)
    failed_dev = None
    for _ in range(3000):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
        if failed_dev is None and rt.channel.flows:
            failed_dev = rt.channel.flows[0].dst
            rt.net.fail_device(failed_dev, t)
    assert failed_dev is not None  # a migration really was in flight
    assert rt.n_outstanding == 0
    assert rt.stats.remigrations >= 1
    _, gapped = rt.router.handoff_report()
    assert gapped == 0
    for r in rt.completed.values():
        assert len(r.out_tokens) == r.max_new_tokens


def test_failed_kv_source_reprefills_on_healthy_engine():
    """Mirror failure: the SOURCE prefill NIC dies mid-migration.  The
    frozen pages are unreachable, so the request must be un-pinned and
    re-prefilled on a surviving engine — not re-targeted forever."""
    topo = tp.add_host_sources(tp.make_cluster(6, 1, bw_gbps=0.001))
    rt = _runtime(
        topo=topo, n_prefill=2, n_decode=2,
        policy=PolicyConfig(max_instances=4, lower_util=0.0, kv_upper=0.99),
        prefill_capacity_tps=1e9, decode_capacity_tps=1e9,
    )
    rng = np.random.default_rng(13)
    t = 0.0
    for _ in range(3):
        rt.submit(rng.integers(0, CFG.vocab_size, size=8).astype(np.int32), 4, t)
    failed_dev = None
    for _ in range(3000):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
        if failed_dev is None and rt.channel.flows:
            failed_dev = rt.channel.flows[0].src
            rt.net.fail_device(failed_dev, t)
    assert failed_dev is not None
    assert rt.n_outstanding == 0
    assert rt.stats.re_prefills >= 1
    assert rt.stats.remigrations < 100  # no abort/re-target livelock
    for r in rt.completed.values():
        assert len(r.out_tokens) == r.max_new_tokens


def test_live_scale_aborting_at_start_leaks_no_loading_engine():
    """A live-scale whose parameter flows abort synchronously at start (no
    live route to the target — a fully severed uplink that killed no NIC,
    invisible to device_ok) must not provision a stuck LOADING engine:
    the abort fires BEFORE the engine would exist, so neither the drain
    path nor the failure subscription could ever clean it up.  Holds in
    both subscription modes (standalone and fleet-managed)."""
    from repro.net import LEAF_UP

    for subscribed in (True, False):
        topo = tp.add_host_sources(
            tp.make_cluster(2, 2, hosts_per_leaf=1, bw_gbps=100.0)
        )
        rt = _runtime(
            topo=topo, n_prefill=1, n_decode=1,
            failure_subscription=subscribed,
        )
        rt.tick(0.01)
        # sever leaf 0's only uplink: cross-leaf flows have no route, but
        # every NIC stays up, so no device is "dead"
        rt.net.fail_link((LEAF_UP, 0, 0), 0.01)
        assert rt.net.dead_devices() == set()
        n_before = rt.n_engines
        pe = rt._live_scale(P.PREFILL, 0.02)  # spares are all on leaf 1
        assert pe is None
        assert rt.n_engines == n_before
        assert all(pe2.state != P.LOADING for pe2 in rt.pool.all())
        # the target device was not left reserved either
        assert [d.id for d in topo.spares() if d.leaf == 1]
        rt.tick(0.03)  # the abort sweep finds nothing to tear down
        assert rt.stats.cancelled_scales == 0


def test_live_scale_rejects_plan_not_covering_target(monkeypatch):
    """Degenerate-plan guard: if planning cannot cover the target (e.g. a
    source-only chain), no engine is provisioned — a LOADING engine with
    no inflow would otherwise 'load' instantly from the analytic
    fallback's absurd rate."""
    from repro.core import multicast as mc

    rt = _runtime()
    rt.tick(0.01)
    empty = mc.MulticastPlan(chains=[], covered=[], gen_seconds=0.0,
                             pruned_sources=[])
    monkeypatch.setattr(mc, "plan_multicast", lambda *a, **k: empty)
    n_before = rt.n_engines
    assert rt._live_scale(P.PREFILL, 0.02) is None
    assert rt.n_engines == n_before


def test_scale_down_drains_and_frees_devices():
    rt = _runtime()
    rng = np.random.default_rng(4)
    t = 0.0
    for _ in range(8):
        rt.submit(rng.integers(0, CFG.vocab_size, size=16).astype(np.int32), 4, t)
    for _ in range(800):
        if rt.n_outstanding == 0:
            break
        t += 0.01
        rt.tick(t)
    assert rt.n_outstanding == 0
    n_before = len(rt.pool.all())
    free_before = sum(
        1 for d in rt.topo.devices if d.role is tp.Role.FREE and not d.is_host
    )
    for _ in range(200):  # idle ticks past the scale-down timeout
        t += 0.05
        rt.tick(t)
    assert rt.stats.scale_downs >= 1
    assert rt.stats.retired >= 1
    assert len(rt.pool.all()) < n_before
    free_after = sum(
        1 for d in rt.topo.devices if d.role is tp.Role.FREE and not d.is_host
    )
    assert free_after > free_before  # retirement actually freed devices
