"""Autoscaler policy: scale-down timeout hysteresis + §5.4 decode pre-scaling."""

import pytest

from repro.core.autoscaler import Autoscaler, LoadSample, PolicyConfig


def _scaler(**kw):
    kw.setdefault("scale_down_timeout_s", 1.0)
    kw.setdefault("monitor_window_s", 10.0)  # keep samples alive across decides
    return Autoscaler(
        PolicyConfig(**kw), prefill_capacity_tps=100.0, decode_capacity_tps=100.0
    )


def _feed(sc, t, prefill_tps=0.0, decode_tps=0.0, kv=0.0):
    sc.prefill_mon.record(LoadSample(t, prefill_tps, 0.0, 0))
    sc.decode_mon.record(LoadSample(t, decode_tps, kv, 0))


# ---------------------------------------------------------------------------
# scale-down timeout hysteresis
# ---------------------------------------------------------------------------


def test_scale_down_waits_for_timeout():
    sc = _scaler()
    _feed(sc, 0.0, prefill_tps=5.0)  # far below lower bound with 2 instances
    assert sc.decide(0.0, n_prefill=2, n_decode=1).prefill_delta == 0  # timer arms
    _feed(sc, 0.5, prefill_tps=5.0)
    assert sc.decide(0.5, 2, 1).prefill_delta == 0  # 0.5s < 1.0s timeout
    _feed(sc, 1.1, prefill_tps=5.0)
    assert sc.decide(1.1, 2, 1).prefill_delta == -1  # timeout elapsed


def test_scale_down_timer_resets_on_load_blip():
    sc = _scaler()
    _feed(sc, 0.0, prefill_tps=5.0)
    sc.decide(0.0, 2, 1)  # arms at t=0
    _feed(sc, 0.8, prefill_tps=500.0)  # blip above the lower bound
    d = sc.decide(0.8, 2, 1)
    assert d.prefill_delta >= 0  # no scale-down
    # back to quiet: the timer must restart, not resume
    sc.prefill_mon.samples.clear()
    _feed(sc, 1.2, prefill_tps=5.0)
    assert sc.decide(1.2, 2, 1).prefill_delta == 0
    _feed(sc, 2.3, prefill_tps=5.0)
    assert sc.decide(2.3, 2, 1).prefill_delta == -1


def test_scale_down_rearms_after_firing():
    """After one -1 the timer restarts: no immediate second scale-down."""
    sc = _scaler()
    _feed(sc, 0.0, prefill_tps=5.0)
    sc.decide(0.0, 3, 1)
    _feed(sc, 1.1, prefill_tps=5.0)
    assert sc.decide(1.1, 3, 1).prefill_delta == -1
    _feed(sc, 1.2, prefill_tps=5.0)
    assert sc.decide(1.2, 2, 1).prefill_delta == 0  # rearmed, not repeated
    _feed(sc, 2.3, prefill_tps=5.0)
    assert sc.decide(2.3, 2, 1).prefill_delta == -1


def test_no_scale_down_below_one_instance():
    sc = _scaler()
    for t in (0.0, 1.1, 2.2):
        _feed(sc, t, prefill_tps=0.0, decode_tps=0.0)
        d = sc.decide(t, 1, 1)
        assert d.prefill_delta == 0 and d.decode_delta == 0


# ---------------------------------------------------------------------------
# scale-up + §5.4 decode pre-scaling
# ---------------------------------------------------------------------------


def test_prefill_surge_prescales_decode():
    sc = _scaler()
    _feed(sc, 0.0, prefill_tps=1000.0)  # 10x one instance's capacity
    d = sc.decide(0.0, n_prefill=1, n_decode=1)
    assert d.prefill_delta > 0
    assert d.decode_delta > 0  # raised by the forecast, not observed load
    assert d.prescaled  # and flagged as such


def test_prescale_disabled_leaves_decode_alone():
    sc = _scaler(decode_prescale=False)
    _feed(sc, 0.0, prefill_tps=1000.0)
    d = sc.decide(0.0, 1, 1)
    assert d.prefill_delta > 0
    assert d.decode_delta == 0


def test_kv_pressure_scales_decode():
    sc = _scaler(kv_upper=0.9)
    _feed(sc, 0.0, kv=0.95)
    d = sc.decide(0.0, 1, 1)
    assert d.decode_delta == 1
    assert not d.prescaled  # pressure-driven, not a forecast
    assert "KV" in d.reason
