"""Oracle tests for the GShard one-hot einsum MoE dispatch (§Perf B2) and
the int8 KV cache (§Perf C3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe
from repro.models import transformer as TF


def _moe_reference(params, x, cfg, group_size):
    """Per-token Python reference with identical capacity semantics:
    flattened (s, k) order per group, first-come-first-capacity drops."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = np.asarray(x.reshape(b * s, d), np.float32)
    logits = tokens @ np.asarray(params["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)
    topk_p = np.asarray(topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9))
    topk_i = np.asarray(topk_i)

    t = tokens.shape[0]
    g_sz = min(group_size, t)
    n_groups = -(-t // g_sz)
    cap = max(int(np.ceil(cfg.capacity_factor * g_sz * k / e)), 1)

    w_up = np.asarray(params["w_up"], np.float32)
    w_gate = np.asarray(params["w_gate"], np.float32) if "w_gate" in params else None
    w_down = np.asarray(params["w_down"], np.float32)

    out = np.zeros_like(tokens)
    for gi in range(n_groups):
        counts = np.zeros(e, int)
        for si in range(g_sz):
            ti = gi * g_sz + si
            if ti >= t:
                break
            for ki in range(k):
                eid = topk_i[ti, ki]
                if counts[eid] >= cap or topk_p[ti, ki] <= 0:
                    counts[eid] += counts[eid] < cap  # position still consumed? no
                    continue
                counts[eid] += 1
                h = tokens[ti] @ w_up[eid]
                if w_gate is not None:
                    gate = tokens[ti] @ w_gate[eid]
                    h = (gate / (1 + np.exp(-gate))) * h  # silu(gate) * up
                else:
                    from scipy.special import erf  # pragma: no cover

                    h = 0.5 * h * (1 + erf(h / np.sqrt(2)))
                out[ti] += topk_p[ti, ki] * (h @ w_down[eid])
    return out.reshape(b, s, d)


@pytest.mark.slow
def test_einsum_dispatch_matches_per_token_reference():
    cfg = get_config("olmoe-1b-7b", reduced=True).replace(capacity_factor=8.0)
    # high capacity factor -> no drops -> exact comparison
    params_tree = TF.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda p: p[0].astype(jnp.float32), params_tree["layers"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model), jnp.float32)
    got, aux = moe.moe_forward(lp["moe"], x, cfg, group_size=8)
    want = _moe_reference(lp["moe"], x, cfg, group_size=8)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_dispatch_capacity_drops_bounded():
    """With capacity_factor < topk pressure, output stays finite and within
    the convex hull scale of expert outputs (dropped tokens contribute 0)."""
    cfg = get_config("olmoe-1b-7b", reduced=True).replace(capacity_factor=0.25)
    params_tree = TF.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda p: p[0], params_tree["layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model), cfg.dtype)
    out, aux = moe.moe_forward(lp["moe"], x, cfg, group_size=16)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) > 0


def test_moe_group_size_config_used():
    cfg = get_config("grok-1-314b")
    assert cfg.moe_group_size == 512  # B4: unshardable expert axis


# ---------------------------------------------------------------------------
# int8 KV cache (§Perf C3)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_int8_kv_cache_matches_bf16_predictions():
    cfg = get_config("granite-8b", reduced=True)
    cfgq = cfg.replace(kv_quant=True)
    params = TF.init_params(jax.random.PRNGKey(3), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab_size)

    c = TF.init_caches(cfg, 2, 32)
    n, c = TF.prefill(cfg, params, tokens, c)
    cq = TF.init_caches(cfgq, 2, 32)
    m, cq = TF.prefill(cfgq, params, tokens, cq)
    assert cq["layers"]["k"].dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(n), np.asarray(m))
    for _ in range(4):
        n, c = TF.decode_step(cfg, params, n, c)
        m, cq = TF.decode_step(cfgq, params, m, cq)
    # greedy tokens may diverge after many steps; over 4 steps they agree
    # on the reduced config (validated deterministically)
    np.testing.assert_array_equal(np.asarray(n), np.asarray(m))


def test_quantize_kv_roundtrip_error_bounded():
    from repro.models.kvcache import quantize_kv

    x = jax.random.normal(jax.random.PRNGKey(5), (4, 8, 64)) * 3.0
    q, s = quantize_kv(x)
    recon = q.astype(jnp.float32) * s[..., None]
    err = jnp.max(jnp.abs(recon - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_uniform_append_matches_masked_append_when_lockstep():
    """With equal lengths the scalar-DUS append and the masked-where append
    are bit-identical."""
    from repro.models.kvcache import append_kv, append_kv_uniform, init_kv_cache

    cache = init_kv_cache(3, 16, 2, 8, jnp.float32)
    cache["lengths"] = jnp.full((3,), 5, jnp.int32)
    k_new = jax.random.normal(jax.random.PRNGKey(6), (3, 2, 8))
    v_new = jax.random.normal(jax.random.PRNGKey(7), (3, 2, 8))
    a = append_kv(dict(cache), k_new, v_new)
    b = append_kv_uniform(dict(cache), k_new, v_new)
    for key in ("k", "v", "lengths"):
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]))
