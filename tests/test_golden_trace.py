"""Golden-trace determinism harness for the unified network data plane.

A seeded end-to-end simulator run serializes its full flow-event log (via
the FlowSim subscription API) and diffs it against a checked-in golden
file, bit-for-bit on event times (``repr`` floats round-trip exactly):

  * ``flow_events_legacy.txt`` — zero latency terms + per-request KV flows
    DISABLED, i.e. the exact PR-3 FlowSim configuration.  Any drift here
    means the latency/per-request refactor (or a future change) perturbed
    the pure bandwidth-sharing model it promised to reproduce exactly.
  * ``flow_events_realistic.txt`` — latency terms on + request-granular
    serving flows, pinning the behaviour of the new model itself.

Regenerate intentionally with ``REGEN_GOLDEN=1 pytest tests/test_golden_trace.py``
after a change that is SUPPOSED to move timings, and commit the diff.
"""

import os
import pathlib

from repro.core import simulator as sim
from repro.net import FlowEventLog
from repro.serving import traces

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
PROF = sim.profile_for("8b")


def _seeded_run(**kw):
    log = FlowEventLog()
    s = sim.Simulator(sim.BLITZ, PROF, seed=0, **kw)
    s.flowsim.subscribe(log)
    trace = traces.burstgpt(duration=40.0, base_rate=5.0, seed=11)
    result = s.run(trace)
    return log, result


def _assert_matches_golden(name: str, lines: list[str]) -> None:
    path = GOLDEN_DIR / name
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text("\n".join(lines) + "\n")
    want = path.read_text().splitlines()
    for i, (got, exp) in enumerate(zip(lines, want)):
        assert got == exp, (
            f"{name}: first divergence at event {i}:\n"
            f"  got:  {got}\n  want: {exp}"
        )
    assert len(lines) == len(want), (
        f"{name}: event count changed: got {len(lines)}, want {len(want)}"
    )


def test_flow_event_log_matches_golden_legacy():
    """Zero-latency + background serving streams = the PR-3 configuration:
    every flow event of the seeded run must reproduce exactly."""
    log, result = _seeded_run(per_request_kv=False)
    assert result.kv_stream_bytes == 0.0  # legacy mode moves no per-req KV
    assert log.count("flow_started") > 0 and log.count("flow_completed") > 0
    _assert_matches_golden("flow_events_legacy.txt", log.lines())


def test_flow_event_log_matches_golden_realistic():
    """Latency terms + per-request KV flows enabled: the new model's own
    regression pin (request-granular serving traffic is on the wire)."""
    log, result = _seeded_run(link_latency_s=2e-5, switch_latency_s=5e-6)
    assert result.kv_stream_bytes > 0.0
    assert any("reqkv:" in line for line in log.lines())
    _assert_matches_golden("flow_events_realistic.txt", log.lines())


def test_seeded_run_is_deterministic_across_invocations():
    """Two fresh runs of the same seeded configuration produce the same
    event log — the property the golden files depend on."""
    a, _ = _seeded_run(link_latency_s=2e-5, switch_latency_s=5e-6)
    b, _ = _seeded_run(link_latency_s=2e-5, switch_latency_s=5e-6)
    assert a.lines() == b.lines()


def test_uniform_link_profiles_reproduce_realistic_golden():
    """Heterogeneous-profile machinery, uniform values: expressing the
    realistic run's uniform latency knobs as per-link ``LinkProfile``s (and
    passing ZERO uniform knobs) must reproduce the realistic golden trace
    bit-for-bit — the profile plumbing adds nothing until profiles actually
    differ per link."""
    from repro.net import LinkProfile

    probe = sim.Simulator(sim.BLITZ, PROF, seed=0)  # enumerate link keys
    profiles = {
        key: LinkProfile(latency_s=2e-5, switch_latency_s=5e-6)
        for key in probe.flowsim.net.links
    }
    log = FlowEventLog()
    s = sim.Simulator(sim.BLITZ, PROF, seed=0, link_profiles=profiles)
    s.flowsim.subscribe(log)
    result = s.run(traces.burstgpt(duration=40.0, base_rate=5.0, seed=11))
    assert result.kv_stream_bytes > 0.0
    _assert_matches_golden("flow_events_realistic.txt", log.lines())


def test_realistic_log_differs_from_legacy():
    """The latency + per-request configuration must actually change the
    event stream (otherwise the 'realistic' golden pins nothing new)."""
    legacy, _ = _seeded_run(per_request_kv=False)
    real, _ = _seeded_run(link_latency_s=2e-5, switch_latency_s=5e-6)
    assert legacy.lines() != real.lines()
