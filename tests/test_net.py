"""Flow-level network simulator: max-min invariants, incast regression,
scenario knobs (degrade / fail / reroute), multicast execution timing, the
per-hop latency model, and the event-subscription API."""

import math

import pytest

from repro.core import multicast as mc
from repro.core import topology as tp
from repro.net import (
    DEV_IN,
    DEV_OUT,
    DEVICE_FAILED,
    FLOW_ABORTED,
    FLOW_COMPLETED,
    FLOW_STARTED,
    LEAF_DOWN,
    LEAF_UP,
    LINK_FAILED,
    Flow,
    FlowEventLog,
    FlowKind,
    FlowSim,
    LinkProfile,
    MulticastExecution,
)

GB = 1e9  # 8 Gbps links -> 1e9 bytes/s, so times read as "GB at 1 GB/s"


def _flat_cluster(n_devs: int, *, hosts_per_leaf: int = 2, bw: float = 8.0):
    """One device per host = one NIC per scale-up domain (no NVLink shortcut)."""
    return tp.make_cluster(n_devs, 1, hosts_per_leaf=hosts_per_leaf, bw_gbps=bw)


def _check_maxmin_invariants(sim: FlowSim):
    """The two classic max-min properties (cf. module docstring):
    conservation and per-flow bottleneck saturation."""
    used: dict = {}
    for f in sim.flows:
        for l in f.path:
            used.setdefault(l.key, []).append(f)
    for key, flows in used.items():
        cap = sim.net.link(key).rate_cap
        total = sum(f.rate for f in flows)
        # 1. conservation: no link carries more than its capacity
        assert total <= cap * (1 + 1e-9) + 1e-6, (key, total, cap)
    for f in sim.flows:
        if not f.path or not math.isfinite(f.rate):
            continue
        if f.active_at is not None:
            # still propagating under the latency model: claims nothing by
            # design, so it has no bottleneck yet
            assert f.rate == 0.0
            continue
        # 2. bottleneck: some link on the path is saturated AND no flow on
        # that link gets more than f (else f's rate could be raised)
        bottlenecked = False
        for l in f.path:
            flows = used[l.key]
            total = sum(x.rate for x in flows)
            saturated = total >= l.rate_cap * (1 - 1e-9) - 1e-6
            if saturated and f.rate >= max(x.rate for x in flows) - 1e-6:
                bottlenecked = True
                break
        assert bottlenecked, (f.src, f.dst, f.rate)


# ---------------------------------------------------------------------------
# Deterministic invariants + regression vs the old per-ingress model
# ---------------------------------------------------------------------------


def test_single_flow_runs_at_link_bandwidth():
    sim = FlowSim(_flat_cluster(4))
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
    assert f.rate == pytest.approx(GB)
    done = sim.advance_to(2.0)
    assert done == [f] and f.finished_at == pytest.approx(1.0)
    assert f.transferred == pytest.approx(GB)


def test_incast_fair_share_single_ingress_special_case():
    """Regression: the deleted per-ingress fair-share incast model is the
    single-ingress special case of max-min — n flows into one device each
    get BW/n and finish together at n*|M|/BW."""
    n = 4
    sim = FlowSim(_flat_cluster(8, hosts_per_leaf=8))
    flows = [
        sim.start(Flow(FlowKind.KV_MIGRATION, src, 7, GB), 0.0)
        for src in range(n)
    ]
    for f in flows:
        assert f.rate == pytest.approx(GB / n)
    _check_maxmin_invariants(sim)
    done = sim.advance_to(100.0)
    assert len(done) == n
    for f in done:
        assert f.finished_at == pytest.approx(n * 1.0)


def test_background_serving_stream_takes_its_share_forever():
    sim = FlowSim(_flat_cluster(4))
    s = sim.start(Flow(FlowKind.SERVING, 3, 2, math.inf), 0.0)
    m = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 2, GB), 0.0)
    assert m.rate == pytest.approx(GB / 2)
    assert sim.advance_to(1.5) == []
    (done,) = sim.advance_to(2.0 + 1e-9)
    assert done is m and m.finished_at == pytest.approx(2.0)
    # the serving stream reclaims the whole ingress and never completes
    assert s.rate == pytest.approx(GB) and not s.done


def test_staggered_arrival_piecewise_rates():
    """A flow arriving halfway re-splits the link: exact piecewise timing."""
    sim = FlowSim(_flat_cluster(4))
    a = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 2, GB), 0.0)
    b = sim.start(Flow(FlowKind.KV_MIGRATION, 1, 2, GB), 0.5)
    # a: 0.5 GB alone, then shares -> 0.5 + 0.5/0.5 = 1.5s total
    done = sim.advance_to(10.0)
    assert [f.finished_at for f in done] == [pytest.approx(1.5), pytest.approx(2.0)]
    assert a.finished_at < b.finished_at


def test_advance_in_small_steps_matches_one_big_step():
    def run(steps):
        sim = FlowSim(_flat_cluster(6, hosts_per_leaf=6))
        fs = [
            sim.start(Flow(FlowKind.KV_MIGRATION, 0, 4, 2 * GB), 0.0),
            sim.start(Flow(FlowKind.KV_MIGRATION, 1, 4, GB), 0.0),
            sim.start(Flow(FlowKind.COLD_START, 2, 5, GB), 0.25),
        ]
        t = 0.0
        for dt in steps:
            t += dt
            sim.advance_to(t)
        sim.advance_to(100.0)
        return [f.finished_at for f in fs]

    assert run([100.0]) == pytest.approx(run([0.1] * 30 + [0.33] * 10))


def test_removing_a_competitor_never_slows_the_survivor():
    """Monotonicity: finish times only improve when a competing flow is
    withdrawn."""
    def finish(survivor_only: bool):
        sim = FlowSim(_flat_cluster(6, hosts_per_leaf=6))
        surv = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 4, 2 * GB), 0.0)
        comp = sim.start(Flow(FlowKind.KV_MIGRATION, 1, 4, 2 * GB), 0.0)
        if survivor_only:
            sim.remove(comp, 0.5, abort=False)
        sim.advance_to(100.0)
        return surv.finished_at

    assert finish(survivor_only=True) <= finish(survivor_only=False) + 1e-9
    # 0.5 s shared (0.25 GB moved) + 1.75 GB alone at full rate
    assert finish(survivor_only=True) == pytest.approx(2.25)
    assert finish(survivor_only=False) == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# Scenario knobs: degraded links, failures, rerouting, oversubscription
# ---------------------------------------------------------------------------


def test_degraded_link_scales_transfer_time():
    sim = FlowSim(_flat_cluster(4))
    sim.degrade_link((DEV_IN, 1), 0.25)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 1, GB), 0.0)
    assert f.rate == pytest.approx(GB / 4)
    sim.advance_to(10.0)
    assert f.finished_at == pytest.approx(4.0)
    sim.degrade_link((DEV_IN, 1), 1.0)  # restores full capacity
    g = sim.start(Flow(FlowKind.COLD_START, 0, 1, GB))
    assert g.rate == pytest.approx(GB)


def test_mid_flight_degrade_is_a_rate_change_event():
    sim = FlowSim(_flat_cluster(4))
    f = sim.start(Flow(FlowKind.COLD_START, 0, 1, GB), 0.0)
    sim.degrade_link((DEV_OUT, 0), 0.5, 0.5)  # halve halfway through
    sim.advance_to(10.0)
    # 0.5 GB at full rate + 0.5 GB at half rate = 0.5 + 1.0
    assert f.finished_at == pytest.approx(1.5)


def test_device_failure_aborts_flows_and_fires_callback():
    sim = FlowSim(_flat_cluster(4))
    hits = []
    f = sim.start(
        Flow(FlowKind.COLD_START, 0, 1, GB, on_abort=lambda fl, t: hits.append(t)), 0.0
    )
    aborted = sim.fail_device(1, 0.5)
    assert aborted == [f] and f.aborted and hits == [0.5]
    assert not sim.device_ok(1) and sim.device_ok(0)
    assert sim.flows == []
    sim.recover_device(1)
    assert sim.device_ok(1)


def test_spine_plane_failure_reroutes_instead_of_aborting():
    topo = _flat_cluster(4, hosts_per_leaf=2)  # 2 leaves
    sim = FlowSim(topo, spine_planes=2)
    f = sim.start(Flow(FlowKind.MULTICAST_HOP, 0, 3, GB), 0.0)  # cross-leaf
    up = next(l.key for l in f.path if l.key[0] == LEAF_UP)
    assert sim.fail_link(up, 0.25) == []  # rerouted, not aborted
    assert not f.aborted
    sim.advance_to(100.0)
    assert f.done
    # single-plane network: the same failure aborts
    sim1 = FlowSim(topo, spine_planes=1)
    g = sim1.start(Flow(FlowKind.MULTICAST_HOP, 0, 3, GB), 0.0)
    up1 = next(l.key for l in g.path if l.key[0] == LEAF_UP)
    assert sim1.fail_link(up1, 0.25) == [g] and g.aborted


def test_oversubscribed_spine_bottlenecks_cross_leaf_flows():
    topo = _flat_cluster(4, hosts_per_leaf=2)  # leaf uplink = 2 NICs
    times = {}
    for name, oversub in (("fair", 1.0), ("over", 4.0)):
        sim = FlowSim(topo, spine_oversub=oversub)
        a = sim.start(Flow(FlowKind.COLD_START, 0, 2, GB), 0.0)
        b = sim.start(Flow(FlowKind.COLD_START, 1, 3, GB), 0.0)
        sim.advance_to(100.0)
        times[name] = (a.finished_at, b.finished_at)
    # non-blocking: both transfers run at NIC speed; 4:1 oversubscribed:
    # two flows share a half-NIC uplink -> 4x slower
    assert times["fair"] == (pytest.approx(1.0), pytest.approx(1.0))
    assert times["over"] == (pytest.approx(4.0), pytest.approx(4.0))


def test_estimate_matches_realized_time_and_is_pure():
    sim = FlowSim(_flat_cluster(6, hosts_per_leaf=6))
    bg = sim.start(Flow(FlowKind.KV_MIGRATION, 1, 4, GB), 0.0)
    est = sim.estimate_transfer_time(0, 4, GB)
    assert len(sim.flows) == 1 and sim.now == 0.0  # untouched
    f = sim.start(Flow(FlowKind.COLD_START, 0, 4, GB), 0.0)
    sim.advance_to(100.0)
    assert f.finished_at == pytest.approx(est)
    assert est == pytest.approx(2.0)  # 1 GB shared with an equal competitor


# ---------------------------------------------------------------------------
# Multicast plan execution through the FlowSim
# ---------------------------------------------------------------------------


def _planned(n_hosts=4, devs=1, bw=8.0):
    topo = tp.add_host_sources(_flat_cluster(n_hosts, bw=bw))
    topo.device(0).model = "m"
    topo.device(0).role = tp.Role.DECODE  # egress free
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, [0], spares, len(spares))
    return topo, plan, spares


def test_multicast_execution_matches_plan_time_on_dedicated_links():
    """Fig. 13a through the FlowSim: with no competing traffic, the chain
    completes in |M| / bottleneck regardless of length."""
    topo, plan, spares = _planned()
    sim = FlowSim(topo)
    done_t = []
    ex = MulticastExecution(plan, int(GB), on_done=lambda e, t: done_t.append(t))
    ex.start(sim, 0.0)
    sim.advance_to(100.0)
    assert ex.done and done_t
    assert ex.done_at == pytest.approx(plan.transfer_seconds(int(GB)))
    assert set().union(*(n.device_ids for n in ex.node_ready_at)) >= set(spares)


def test_multicast_execution_slows_under_contention():
    """The same plan under KV-drain traffic on a shared ingress finishes
    later than on dedicated links — the interaction the unified data plane
    exists to expose."""
    topo, plan, spares = _planned()
    dedicated = FlowSim(topo)
    MulticastExecution(plan, int(GB)).start(dedicated, 0.0)
    dedicated.advance_to(100.0)

    topo2, plan2, spares2 = _planned()
    contended = FlowSim(topo2)
    # a fat KV drain into the first chain target's ingress
    contended.start(Flow(FlowKind.KV_MIGRATION, 0, spares2[0], 2 * GB), 0.0)
    ex2 = MulticastExecution(plan2, int(GB))
    ex2.start(contended, 0.0)
    contended.advance_to(100.0)
    t_dedicated = plan.transfer_seconds(int(GB))
    assert ex2.done_at > t_dedicated * (1 + 1e-6)


def test_multicast_execution_abort_on_failure():
    topo, plan, spares = _planned()
    sim = FlowSim(topo)
    aborts = []
    ex = MulticastExecution(plan, int(GB), on_abort=lambda e, t: aborts.append(t))
    ex.start(sim, 0.0)
    sim.fail_device(spares[0], 0.1)
    assert ex.aborted and aborts == [0.1]
    # every remaining hop was withdrawn — the network is quiet again
    assert all(f.kind is not FlowKind.MULTICAST_HOP for f in sim.flows)


# ---------------------------------------------------------------------------
# Latency model: per-hop propagation + switching composed with max-min shares
# ---------------------------------------------------------------------------


def test_zero_latency_is_the_pure_bandwidth_model():
    """Explicit zero latency terms change nothing versus the default."""
    for kw in ({}, dict(link_latency_s=0.0, switch_latency_s=0.0)):
        sim = FlowSim(_flat_cluster(4), **kw)
        f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
        assert f.active_at is None and f.rate == pytest.approx(GB)
        sim.advance_to(5.0)
        assert f.finished_at == pytest.approx(1.0)


def test_uncontended_finish_is_latency_plus_transfer_exactly():
    """First-byte setup: an uncontended flow takes latency + size/BW."""
    sim = FlowSim(_flat_cluster(4), link_latency_s=0.01, switch_latency_s=0.005)
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)  # intra-leaf
    # while propagating the flow claims no bandwidth at all
    assert f.rate == 0.0 and f.active_at == pytest.approx(0.025)
    g = sim.start(Flow(FlowKind.KV_MIGRATION, 2, 3, GB), 0.0)
    sim.advance_to(10.0)
    # 2 links x 10ms + 1 switch x 5ms = 25ms, then 1 GB at 1 GB/s
    assert f.finished_at == pytest.approx(1.025)
    assert g.finished_at == pytest.approx(1.025)


def test_finish_time_monotone_in_hop_count():
    """A cross-leaf path (4 links, 3 switches) pays strictly more latency
    than an intra-leaf path (2 links, 1 switch) for the same bytes."""
    topo = _flat_cluster(4, hosts_per_leaf=2)
    times = {}
    for name, (src, dst) in (("intra", (0, 1)), ("cross", (0, 3))):
        sim = FlowSim(topo, link_latency_s=0.01, switch_latency_s=0.005)
        f = sim.start(Flow(FlowKind.COLD_START, src, dst, GB), 0.0)
        sim.advance_to(10.0)
        times[name] = f.finished_at
    assert times["intra"] == pytest.approx(1.0 + 2 * 0.01 + 1 * 0.005)
    assert times["cross"] == pytest.approx(1.0 + 4 * 0.01 + 3 * 0.005)
    assert times["cross"] > times["intra"]


def test_finish_time_monotone_in_propagation_delay_and_converges_to_zero():
    """Finish times grow strictly with the propagation term and converge to
    the pure bandwidth model as latency -> 0."""
    finishes = []
    for lat in (0.0, 1e-6, 1e-4, 1e-2, 1.0):
        sim = FlowSim(_flat_cluster(4), link_latency_s=lat)
        f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
        sim.advance_to(100.0)
        finishes.append(f.finished_at)
        assert f.finished_at == pytest.approx(1.0 + 2 * lat)
    assert finishes == sorted(finishes)
    assert all(a < b for a, b in zip(finishes, finishes[1:]))
    assert finishes[1] - finishes[0] < 1e-5  # lat -> 0 converges


def test_maxmin_conservation_holds_with_latency_terms():
    """Once flows activate they share under the same max-min invariants;
    still-propagating flows claim nothing."""
    sim = FlowSim(_flat_cluster(8, hosts_per_leaf=8),
                  link_latency_s=0.05, switch_latency_s=0.01)
    flows = [
        sim.start(Flow(FlowKind.KV_MIGRATION, src, 7, GB), 0.0)
        for src in range(3)
    ]
    late = sim.start(Flow(FlowKind.KV_MIGRATION, 3, 7, GB), 0.2)
    sim.advance_to(0.21)  # first three active, the late one propagating
    assert late.rate == 0.0 and late.active_at is not None
    for f in flows:
        assert f.rate == pytest.approx(GB / 3)
    _check_maxmin_invariants(sim)
    sim.advance_to(0.5)  # all four active now
    assert late.active_at is None
    for f in sim.flows:
        assert f.rate == pytest.approx(GB / 4)
    _check_maxmin_invariants(sim)
    sim.advance_to(100.0)
    assert all(f.done for f in flows) and late.done


def test_estimate_includes_latency_and_matches_realized():
    sim = FlowSim(_flat_cluster(4, hosts_per_leaf=2),
                  link_latency_s=0.01, switch_latency_s=0.005)
    est = sim.estimate_transfer_time(0, 3, GB)
    f = sim.start(Flow(FlowKind.COLD_START, 0, 3, GB), 0.0)
    sim.advance_to(100.0)
    assert f.finished_at == pytest.approx(est) == pytest.approx(1.055)


def test_multicast_chain_pays_cumulative_store_and_forward_latency():
    """Hop k of a pipelined chain cannot deliver byte 0 before the
    latencies of hops 0..k-1 elapsed: chain completion grows with depth."""
    topo, plan, spares = _planned()
    depth = max(len(c.edges) for c in plan.chains)
    assert depth >= 2  # the greedy planner builds a real chain here
    lat = 0.02
    sim = FlowSim(topo, link_latency_s=lat)
    ex = MulticastExecution(plan, int(GB))
    ex.start(sim, 0.0)
    sim.advance_to(100.0)
    t_pure = plan.transfer_seconds(int(GB))
    # at least the full chain's cumulative first-byte latency is added
    assert ex.done_at >= t_pure + depth * 2 * lat - 1e-9
    # and a zero-latency run still matches the analytic plan time
    sim0 = FlowSim(topo)
    ex0 = MulticastExecution(plan, int(GB))
    ex0.start(sim0, 0.0)
    sim0.advance_to(100.0)
    assert ex0.done_at == pytest.approx(t_pure)


# ---------------------------------------------------------------------------
# Heterogeneous per-link profiles (latency / switching / bandwidth overrides)
# ---------------------------------------------------------------------------


def test_link_profiles_per_hop_latency_sums_exactly():
    """Profiles compose as a per-hop sum: each link contributes its own
    propagation delay plus the switching delay of the element entering it."""
    sim = FlowSim(
        _flat_cluster(4),
        link_profiles={
            (DEV_OUT, 0): LinkProfile(latency_s=0.03),
            (DEV_IN, 1): LinkProfile(latency_s=0.01, switch_latency_s=0.02),
        },
    )
    assert sim.has_latency
    # intra-leaf path 0->1: out(0.03) + in(0.01) + one switch into in (0.02)
    assert sim.route_latency(0, 1) == pytest.approx(0.06)
    # the reverse direction is untouched (profiles are per DIRECTED link)
    assert sim.route_latency(1, 0) == 0.0
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
    sim.advance_to(10.0)
    assert f.finished_at == pytest.approx(1.06)


def test_link_profiles_override_uniform_terms_and_bandwidth():
    sim = FlowSim(
        _flat_cluster(4),
        link_latency_s=0.01,
        switch_latency_s=0.005,
        link_profiles={
            (DEV_OUT, 0): LinkProfile(latency_s=0.1),  # slow long-haul egress
            (DEV_IN, 1): LinkProfile(bandwidth_gbps=4.0),  # half-speed NIC gen
        },
    )
    # 0.1 (profiled) + 0.01 (uniform in-link) + 0.005 (uniform switch)
    assert sim.route_latency(0, 1) == pytest.approx(0.115)
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
    sim.advance_to(10.0)
    # bandwidth override binds: 1 GB at 0.5 GB/s after first-byte setup
    assert f.finished_at == pytest.approx(0.115 + 2.0)


def test_uniform_link_profiles_equal_uniform_knobs_bit_for_bit():
    """Expressing the uniform knobs as per-link profiles changes nothing —
    not even the floating point."""
    topo = _flat_cluster(4, hosts_per_leaf=2)
    base = FlowSim(topo, link_latency_s=0.01, switch_latency_s=0.005)
    prof = FlowSim(
        topo,
        link_profiles={
            key: LinkProfile(latency_s=0.01, switch_latency_s=0.005)
            for key in base.net.links
        },
    )
    for src, dst in ((0, 1), (0, 3), (2, 0)):
        assert prof.route_latency(src, dst) == base.route_latency(src, dst)
        assert prof.hop_latency(src, dst) == base.hop_latency(src, dst)
        fa = base.start(Flow(FlowKind.KV_MIGRATION, src, dst, GB))
        fb = prof.start(Flow(FlowKind.KV_MIGRATION, src, dst, GB))
        base.advance_to(base.now + 10.0)
        prof.advance_to(prof.now + 10.0)
        assert fa.finished_at == fb.finished_at  # == exactly, not approx


def test_raising_one_links_latency_never_speeds_any_path():
    """Monotonicity: a single raised link slows exactly the paths crossing
    it and leaves every other path untouched."""
    topo = _flat_cluster(4, hosts_per_leaf=2)
    base = FlowSim(topo, link_latency_s=0.01, switch_latency_s=0.005)
    slow = FlowSim(
        topo,
        link_latency_s=0.01,
        switch_latency_s=0.005,
        link_profiles={(DEV_OUT, 0): LinkProfile(latency_s=0.2)},
    )
    for src in range(4):
        for dst in range(4):
            if src == dst:
                continue
            a = base.net.route_latency(src, dst)
            b = slow.net.route_latency(src, dst)
            assert b >= a - 1e-12
            if src == 0:
                assert b > a  # every path over the raised egress got slower
            else:
                assert b == a
    f_slow = slow.start(Flow(FlowKind.COLD_START, 0, 3, GB), 0.0)
    f_base = base.start(Flow(FlowKind.COLD_START, 0, 3, GB), 0.0)
    slow.advance_to(10.0)
    base.advance_to(10.0)
    assert f_slow.finished_at > f_base.finished_at


def test_link_profiles_planeless_key_and_unknown_key():
    topo = _flat_cluster(4, hosts_per_leaf=2)
    sim = FlowSim(
        topo,
        spine_planes=2,
        link_profiles={(LEAF_UP, 0): LinkProfile(latency_s=0.05)},
    )
    for p in range(2):  # plane-less shorthand hit every plane
        assert sim.net.link((LEAF_UP, 0, p)).prop_delay_s == 0.05
    with pytest.raises(ValueError, match="matches no link"):
        FlowSim(topo, link_profiles={("nope", 7): LinkProfile(latency_s=0.1)})
    with pytest.raises(ValueError, match="must be >= 0"):
        FlowSim(topo, link_profiles={(DEV_OUT, 0): LinkProfile(latency_s=-1.0)})


def test_hop_latency_budgets_worst_live_spine_plane():
    """hop_latency (the planner's + chain-charging view) returns the worst
    live plane; a failed slow plane stops counting."""
    topo = _flat_cluster(4, hosts_per_leaf=2)
    sim = FlowSim(
        topo,
        spine_planes=2,
        link_latency_s=0.01,
        switch_latency_s=0.0,
        link_profiles={(LEAF_UP, 0, 1): LinkProfile(latency_s=0.5)},
    )
    plane0 = 4 * 0.01  # out + up(p0) + down + in
    plane1 = 3 * 0.01 + 0.5
    assert sim.route_latency(0, 3) == pytest.approx(plane0)  # nominal plane 0
    assert sim.hop_latency(0, 3) == pytest.approx(plane1)  # worst live plane
    sim.net.link((LEAF_UP, 0, 1)).failed = True
    assert sim.hop_latency(0, 3) == pytest.approx(plane0)  # slow plane dead
    # every plane dead: fall back to the nominal plane-0 value (the flow
    # will abort anyway — the budget just has to stay finite)
    sim.net.link((LEAF_UP, 0, 0)).failed = True
    assert sim.hop_latency(0, 3) == pytest.approx(plane0)
    # intra-leaf hops are plane-independent
    assert sim.hop_latency(0, 1) == pytest.approx(2 * 0.01)


def test_chain_prefix_budgets_slow_spine_plane_no_causality_drift():
    """Satellite: the store-and-forward prefix charged to downstream hops
    must cover what the FlowSim ACTUALLY charges the upstream sharded
    flows, whichever spine plane they land on.  A background flow pushes
    hop 1 onto the slow plane 1; budgeting plane-0 latency (the old drift)
    would let hop 2 finish before hop 1 — physically impossible for
    store-and-forward.  Property: realized hop-k completion >= hop-(k-1)
    completion + hop-k's own path latency."""
    topo = tp.make_cluster(3, 2, hosts_per_leaf=1, bw_gbps=8.0)
    sim = FlowSim(
        topo,
        spine_planes=2,
        link_latency_s=0.01,
        switch_latency_s=0.005,
        link_profiles={(LEAF_UP, 0, 1): LinkProfile(latency_s=0.5)},
    )
    # background cross-leaf flow loads plane 0 of leaf 0's uplink, so the
    # chain's first hop routes onto slow plane 1 (fewest active flows)
    sim.start(Flow(FlowKind.SERVING, 1, 3, math.inf), 0.0)

    def node(dev, su, leaf):
        return mc.Node(device_ids=(dev,), scaleup=su, leaf=leaf, agg_bw_gbps=8.0)

    n0 = mc.Node(device_ids=(0,), scaleup=0, leaf=0, agg_bw_gbps=8.0, is_source=True)
    n1, n2 = node(2, 1, 1), node(4, 2, 2)
    chain = mc.Chain(
        nodes=[n0, n1, n2],
        edges=[
            mc.Edge(src=n0, dst=n1, bw_gbps=8.0, sharded_ways=1),
            mc.Edge(src=n1, dst=n2, bw_gbps=8.0, sharded_ways=1),
        ],
    )
    plan = mc.MulticastPlan(chains=[chain], covered=[2, 4], gen_seconds=0.0,
                            pruned_sources=[])
    ex = MulticastExecution(plan, int(GB))
    ex.start(sim, 0.0)
    hop1, hop2 = ex.edges[0].flows[0], ex.edges[1].flows[0]
    assert any(l.key == (LEAF_UP, 0, 1) for l in hop1.path)  # on the slow plane
    sim.advance_to(100.0)
    assert ex.done
    lat2 = sim.net.path_latency(hop2.path)
    done1, done2 = ex.edges[0].done_at, ex.edges[1].done_at
    assert done2 >= done1 + lat2 - 1e-9, (done1, done2, lat2)
    # hop 1 really paid the slow plane, and hop 2's budget covered it
    assert hop1.finished_at >= 0.5
    assert hop2.extra_latency_s == pytest.approx(sim.hop_latency(0, 2))


# ---------------------------------------------------------------------------
# Event-subscription API (flow lifecycle + scenario mutations)
# ---------------------------------------------------------------------------


def test_subscription_delivers_flow_lifecycle_events():
    sim = FlowSim(_flat_cluster(4))
    log = sim.subscribe(FlowEventLog())
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB, tag="x"), 0.0)
    sim.advance_to(5.0)
    assert log.count(FLOW_STARTED) == 1
    assert log.count(FLOW_COMPLETED) == 1
    done = [e for e in log.events if e.kind == FLOW_COMPLETED]
    assert done[0].flow is f and done[0].t == pytest.approx(1.0)
    assert "kv_migration[x]" in done[0].render()
    sim.unsubscribe(log)
    sim.start(Flow(FlowKind.KV_MIGRATION, 1, 2, GB), 5.0)
    sim.advance_to(10.0)
    assert len(log.events) == 2  # unsubscribed: nothing new delivered


def test_failure_events_emitted_after_aborts_settle():
    """A subscriber reacting to DEVICE_FAILED/LINK_FAILED must observe the
    post-failure network: the doomed flow's abort arrives FIRST."""
    sim = FlowSim(_flat_cluster(4))
    log = sim.subscribe(FlowEventLog())
    sim.start(Flow(FlowKind.COLD_START, 0, 1, GB), 0.0)
    sim.fail_device(1, 0.5)
    kinds = [e.kind for e in log.events]
    assert kinds.index(FLOW_ABORTED) < kinds.index(DEVICE_FAILED)
    assert log.count(DEVICE_FAILED) == 1
    # lifecycle symmetry: even an unroutable flow (dead destination) logs
    # its start before its abort — starts always pair with ends
    sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.5)
    assert log.count(FLOW_STARTED) == log.count(FLOW_ABORTED) + log.count(
        FLOW_COMPLETED
    )
    up = (DEV_OUT, 2)
    sim.fail_link(up, 0.6)
    assert log.count(LINK_FAILED) == 1
    # subscribers can mutate the sim from inside a failure event
    sim2 = FlowSim(_flat_cluster(4))
    started = []
    def reactor(e):
        if e.kind == DEVICE_FAILED:
            started.append(sim2.start(Flow(FlowKind.COLD_START, 0, 2, GB)))
    sim2.subscribe(reactor)
    sim2.start(Flow(FlowKind.COLD_START, 0, 1, GB), 0.0)
    sim2.fail_device(1, 0.25)
    sim2.advance_to(10.0)
    (g,) = started
    assert g.done and g.finished_at == pytest.approx(1.25)


def test_flow_eta_and_event_log_rendering():
    sim = FlowSim(_flat_cluster(4), link_latency_s=0.25)
    log = sim.subscribe(FlowEventLog())
    f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
    assert f.eta(0.0) == math.inf  # propagating: no rate yet
    sim.advance_to(0.6)
    assert f.eta(0.6) == pytest.approx(1.5)  # 0.5s latency + 1s transfer
    s = sim.start(Flow(FlowKind.SERVING, 2, 3, math.inf), 0.6)
    assert s.eta(0.6) == math.inf  # background streams never finish
    sim.advance_to(3.0)
    assert f.eta(10.0) == f.finished_at == pytest.approx(1.5)
    sim.degrade_link((DEV_IN, 3), 0.5)
    dump = log.dump()
    assert dump.endswith("link_degraded link=dev_in:3\n")
    assert "flow_started serving[-] 2->3 inf" in dump


# ---------------------------------------------------------------------------
# Hypothesis property tests (skipped when hypothesis is absent; the
# deterministic tests above always run)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    import datetime

    # the heavy suites run at full width under --runslow; tier-1 runs the
    # same properties as *_fast variants with few examples and a small
    # per-example deadline, so the default wall-clock stays flat
    FULL = settings(max_examples=30, deadline=None)
    FAST = settings(
        max_examples=6, deadline=datetime.timedelta(milliseconds=500)
    )

    RANDOM_FLOWS_STRATEGY = dict(
        n_devs=st.integers(3, 10),
        hosts_per_leaf=st.integers(1, 3),
        flows=st.lists(
            st.tuples(st.integers(0, 9), st.integers(0, 9), st.floats(0.05, 4.0)),
            min_size=1,
            max_size=12,
        ),
    )

    def _prop_maxmin_invariants_random_flow_sets(n_devs, hosts_per_leaf, flows):
        sim = FlowSim(_flat_cluster(n_devs, hosts_per_leaf=hosts_per_leaf))
        for src, dst, gb in flows:
            src, dst = src % n_devs, dst % n_devs
            if src == dst:
                continue
            sim.start(Flow(FlowKind.KV_MIGRATION, src, dst, gb * GB), 0.0)
        _check_maxmin_invariants(sim)
        # progressing halfway keeps the invariants (rates re-fill on events)
        sim.advance_to(1.0)
        _check_maxmin_invariants(sim)
        n = len(sim.flows) + sim.completed_count
        sim.advance_to(1e4)
        assert sim.completed_count == n  # every finite flow eventually lands

    @pytest.mark.slow
    @FULL
    @given(**RANDOM_FLOWS_STRATEGY)
    def test_maxmin_invariants_hold_for_random_flow_sets(n_devs, hosts_per_leaf, flows):
        _prop_maxmin_invariants_random_flow_sets(n_devs, hosts_per_leaf, flows)

    @FAST
    @given(**RANDOM_FLOWS_STRATEGY)
    def test_maxmin_invariants_random_flow_sets_fast(n_devs, hosts_per_leaf, flows):
        _prop_maxmin_invariants_random_flow_sets(n_devs, hosts_per_leaf, flows)

    REMOVAL_STRATEGY = dict(
        n_devs=st.integers(4, 10),
        flows=st.lists(
            st.tuples(st.integers(0, 9), st.integers(0, 9), st.floats(0.05, 4.0)),
            min_size=2,
            max_size=10,
        ),
        drop=st.integers(0, 9),
    )

    def _prop_removal_keeps_maxmin_invariants(n_devs, flows, drop):
        """Withdrawing any flow re-fills a valid max-min allocation
        (conservation + per-flow bottleneck saturation), and the victim's
        bottleneck link's remaining capacity weakly grows.

        NOTE: neither rates nor finish times are globally monotone under
        removal on multi-link topologies — network max-min is not
        population-monotonic (freeing one link can raise a sibling's share
        on a DIFFERENT link, squeezing a third flow).  The monotone-finish
        property the old incast model had is a single-bottleneck special
        case, tested in test_fanin_finish_times_monotone_under_removal."""
        sim = FlowSim(_flat_cluster(n_devs, hosts_per_leaf=n_devs))
        live = []
        for src, dst, gb in flows:
            src, dst = src % n_devs, dst % n_devs
            if src == dst:
                continue
            live.append(sim.start(Flow(FlowKind.KV_MIGRATION, src, dst, gb * GB), 0.0))
        if len(live) < 2:
            return
        _check_maxmin_invariants(sim)
        victim = live[drop % len(live)]
        used_before = {
            l.key: sum(f.rate for f in sim.flows if l in f.path) for l in victim.path
        }
        sim.remove(victim, 0.0, abort=False)
        _check_maxmin_invariants(sim)
        for l in victim.path:
            used_after = sum(f.rate for f in sim.flows if l in f.path)
            headroom_b = l.rate_cap - used_before[l.key]
            headroom_a = l.rate_cap - used_after
            # the links the victim vacated never end up MORE loaded than the
            # capacity allows (conservation re-checked above); at least one
            # of them regains headroom unless other flows absorbed it all
            assert headroom_a >= -1e-6 and headroom_b >= -1e-6

    @pytest.mark.slow
    @FULL
    @given(**REMOVAL_STRATEGY)
    def test_removal_keeps_maxmin_invariants(n_devs, flows, drop):
        _prop_removal_keeps_maxmin_invariants(n_devs, flows, drop)

    @FAST
    @given(**REMOVAL_STRATEGY)
    def test_removal_keeps_maxmin_invariants_fast(n_devs, flows, drop):
        _prop_removal_keeps_maxmin_invariants(n_devs, flows, drop)

    FANIN_STRATEGY = dict(
        sizes=st.lists(st.floats(0.05, 4.0), min_size=2, max_size=8),
        drop=st.integers(0, 7),
    )

    def _prop_fanin_finish_times_monotone_under_removal(sizes, drop):
        """Single shared bottleneck (the incast fan-in): removing any one
        competing flow never delays any survivor's finish time."""
        n = len(sizes)

        def build():
            sim = FlowSim(_flat_cluster(n + 1, hosts_per_leaf=n + 1))
            return sim, [
                sim.start(Flow(FlowKind.KV_MIGRATION, i, n, gb * GB), 0.0)
                for i, gb in enumerate(sizes)
            ]

        sim_a, flows_a = build()
        sim_a.advance_to(1e5)
        sim_b, flows_b = build()
        victim = flows_b[drop % n]
        sim_b.remove(victim, 0.0, abort=False)
        sim_b.advance_to(1e5)
        for fa, fb in zip(flows_a, flows_b):
            if fb is victim:
                continue
            assert fb.finished_at <= fa.finished_at + 1e-6

    @pytest.mark.slow
    @FULL
    @given(**FANIN_STRATEGY)
    def test_fanin_finish_times_monotone_under_removal(sizes, drop):
        _prop_fanin_finish_times_monotone_under_removal(sizes, drop)

    @FAST
    @given(**FANIN_STRATEGY)
    def test_fanin_finish_times_monotone_under_removal_fast(sizes, drop):
        _prop_fanin_finish_times_monotone_under_removal(sizes, drop)

    INCAST_STRATEGY = dict(n=st.integers(1, 8), gb=st.floats(0.1, 4.0))

    def _prop_incast_regression_any_fan_in(n, gb):
        """n equal flows into one ingress: each gets BW/n, all finish at
        n * |M| / BW — the old KVMigrationChannel fair-share result."""
        sim = FlowSim(_flat_cluster(n + 1, hosts_per_leaf=n + 1))
        fs = [
            sim.start(Flow(FlowKind.KV_MIGRATION, i, n, gb * GB), 0.0)
            for i in range(n)
        ]
        for f in fs:
            assert f.rate == pytest.approx(GB / n)
        sim.advance_to(1e5)
        for f in fs:
            assert f.finished_at == pytest.approx(n * gb, rel=1e-6)

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(**INCAST_STRATEGY)
    def test_incast_regression_any_fan_in(n, gb):
        _prop_incast_regression_any_fan_in(n, gb)

    @FAST
    @given(**INCAST_STRATEGY)
    def test_incast_regression_any_fan_in_fast(n, gb):
        _prop_incast_regression_any_fan_in(n, gb)

    LATENCY_STRATEGY = dict(
        link_lat=st.floats(0.0, 0.5),
        switch_lat=st.floats(0.0, 0.2),
        gb=st.floats(0.05, 4.0),
        cross_leaf=st.booleans(),
    )

    def _prop_latency_model_exact_and_monotone(link_lat, switch_lat, gb, cross_leaf):
        """Dedicated-link finish time is EXACTLY path latency + size/BW;
        doubling either latency term never speeds a transfer up; and the
        latency->0 limit is the pure bandwidth model."""
        topo = _flat_cluster(4, hosts_per_leaf=2)
        dst = 3 if cross_leaf else 1
        n_links, n_switch = (4, 3) if cross_leaf else (2, 1)

        def finish(ll, sl):
            sim = FlowSim(topo, link_latency_s=ll, switch_latency_s=sl)
            f = sim.start(Flow(FlowKind.KV_MIGRATION, 0, dst, gb * GB), 0.0)
            sim.advance_to(1e5)
            return f.finished_at

        t = finish(link_lat, switch_lat)
        expect = gb + n_links * link_lat + n_switch * switch_lat
        assert t == pytest.approx(expect, rel=1e-9)
        assert finish(2 * link_lat, switch_lat) >= t - 1e-9
        assert finish(link_lat, 2 * switch_lat) >= t - 1e-9
        assert finish(0.0, 0.0) == pytest.approx(gb, rel=1e-9)

    @pytest.mark.slow
    @FULL
    @given(**LATENCY_STRATEGY)
    def test_latency_model_exact_and_monotone(link_lat, switch_lat, gb, cross_leaf):
        _prop_latency_model_exact_and_monotone(link_lat, switch_lat, gb, cross_leaf)

    @FAST
    @given(**LATENCY_STRATEGY)
    def test_latency_model_exact_and_monotone_fast(link_lat, switch_lat, gb, cross_leaf):
        _prop_latency_model_exact_and_monotone(link_lat, switch_lat, gb, cross_leaf)

    SAF_STRATEGY = dict(
        lats=st.lists(st.floats(0.0, 0.3), min_size=4, max_size=8),
        gb=st.floats(0.05, 2.0),
    )

    def _prop_store_and_forward_arrivals_monotone(lats, gb):
        """Uncontended deep chain over heterogeneous per-link latency
        profiles: realized hop-k completion >= hop-(k-1) completion plus
        hop-k's own path latency — downstream first bytes stay causally
        behind their upstream store-and-forward stages."""
        n = len(lats)
        topo = _flat_cluster(n + 1, hosts_per_leaf=n + 1)
        profiles = {
            (DEV_IN, i + 1): LinkProfile(latency_s=lats[i]) for i in range(n)
        }
        sim = FlowSim(topo, link_latency_s=0.002, switch_latency_s=0.001,
                      link_profiles=profiles)
        nodes = [mc.Node(device_ids=(0,), scaleup=0, leaf=0,
                         agg_bw_gbps=8.0, is_source=True)]
        edges = []
        for i in range(n):
            nodes.append(mc.Node(device_ids=(i + 1,), scaleup=i + 1, leaf=0,
                                 agg_bw_gbps=8.0))
            edges.append(mc.Edge(src=nodes[-2], dst=nodes[-1], bw_gbps=8.0,
                                 sharded_ways=1))
        plan = mc.MulticastPlan(
            chains=[mc.Chain(nodes=nodes, edges=edges)],
            covered=list(range(1, n + 1)), gen_seconds=0.0, pruned_sources=[],
        )
        ex = MulticastExecution(plan, gb * GB)
        ex.start(sim, 0.0)
        sim.advance_to(1e6)
        assert ex.done
        for prev, cur in zip(ex.edges, ex.edges[1:]):
            lat = sim.net.path_latency(cur.flows[0].path)
            assert cur.done_at >= prev.done_at + lat - 1e-9

    @pytest.mark.slow
    @FULL
    @given(**SAF_STRATEGY)
    def test_store_and_forward_arrivals_monotone(lats, gb):
        _prop_store_and_forward_arrivals_monotone(lats, gb)

    @FAST
    @given(**SAF_STRATEGY)
    def test_store_and_forward_arrivals_monotone_fast(lats, gb):
        _prop_store_and_forward_arrivals_monotone(lats, gb)
