"""MaaS control plane: fleet arbitration, scale-to-zero, cold start,
idle-model preemption, and the FlowSim failure subscription — N models
sharing one topology + one O(1) pool."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving import traces
from repro.serving.disagg import pools as P
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.maas import (
    ACTIVE,
    LATENCY,
    THROUGHPUT,
    FleetPolicy,
    FleetScheduler,
    ZERO,
)

CFG = get_config("granite-8b", reduced=True)
PARAMS = TF.init_params(jax.random.PRNGKey(0), CFG)
# same architecture under two MaaS identities: the pool, the fleet and the
# routers key on the model *name*; sharing params keeps the test light
CFG_A = CFG.replace(name="maas-a")
CFG_B = CFG.replace(name="maas-b")


def _fleet(n_hosts=2, devs=4, fleet_policy=None):
    topo = tp.add_host_sources(tp.make_cluster(n_hosts, devs, bw_gbps=100.0))
    fleet = FleetScheduler(topo, policy=fleet_policy or FleetPolicy(idle_to_zero_s=0.5))
    for cfg in (CFG_A, CFG_B):
        fleet.add_model(
            cfg,
            PARAMS,
            n_prefill=1,
            n_decode=1,
            n_slots=2,
            max_seq=48,
            model_bytes=int(50e6),
            prefill_capacity_tps=200.0,
            decode_capacity_tps=50.0,
            policy=PolicyConfig(max_instances=3, kv_upper=0.5, scale_down_timeout_s=0.4),
        )
    return topo, fleet


def _drain(fleet, t, *, tick=0.01, max_ticks=2000):
    for _ in range(max_ticks):
        if fleet.n_outstanding == 0:
            return t
        t += tick
        fleet.tick(t)
        assert fleet.param_pool.invariant_ok()
    raise AssertionError(f"{fleet.n_outstanding} requests still outstanding")


def test_fleet_lifecycle_serve_zero_cold_start():
    """One fleet, full serverless cycle: two models serve correct tokens on
    shared devices; idling parks BOTH at zero (O(1) host copy only, every
    accelerator free); a late request cold-starts via multicast from the
    host copy and still decodes bit-identically."""
    topo, fleet = _fleet()
    rng = np.random.default_rng(3)
    prompts_b = [rng.integers(0, CFG.vocab_size, size=7).astype(np.int32) for _ in range(2)]

    t = 0.0
    for _ in range(4):
        fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=7).astype(np.int32), 5, t)
    rids_b = [fleet.submit("maas-b", p, 5, t) for p in prompts_b]
    t = _drain(fleet, t)

    # tokens through the shared fleet == a lone colocated engine
    ref = InstanceEngine(CFG, PARAMS, n_slots=1, max_seq=48)
    rt_b = fleet.tenants["maas-b"].runtime
    for rid, prompt in zip(rids_b, prompts_b):
        ref.submit(ServeRequest(100 + rid, prompt, 5))
        (r,) = ref.run_until_done()
        assert rt_b.completed[rid].out_tokens == r.out_tokens

    # idle past the timeout -> every model drains to zero
    for _ in range(300):
        t += 0.05
        fleet.tick(t)
        assert fleet.param_pool.invariant_ok()
        if all(x.state == ZERO for x in fleet.tenants.values()):
            break
    assert all(x.state == ZERO for x in fleet.tenants.values())
    assert all(x.runtime.n_engines == 0 for x in fleet.tenants.values())
    # all 8 accelerators free; exactly one host copy per model survives
    assert len(topo.spares()) == 8
    usage = fleet.param_pool.host_cache_bytes()
    assert sum(usage.values()) == 2 * int(50e6)
    assert fleet.stats.scale_to_zero_events >= 2

    # late request -> multicast cold start from the O(1) host copy
    prompt = prompts_b[0]
    rid = fleet.submit("maas-b", prompt, 5, t)
    t = _drain(fleet, t)
    tb = fleet.tenants["maas-b"]
    assert tb.state == ACTIVE
    assert tb.runtime.stats.cold_starts >= 1
    assert tb.runtime.stats.cold_starts_from_host >= 1
    assert fleet.stats.cold_starts >= 1
    ref2 = InstanceEngine(CFG, PARAMS, n_slots=1, max_seq=48)
    ref2.submit(ServeRequest(999, prompt, 5))
    (r,) = ref2.run_until_done()
    assert tb.runtime.completed[rid].out_tokens == r.out_tokens
    # no request anywhere dropped or token-gapped
    for x in fleet.tenants.values():
        _, gapped = x.runtime.router.handoff_report()
        assert gapped == 0


def test_starved_model_preempts_idle_one():
    """Fleet full, one model bursting, the other idle: arbitration drains
    the idle model (priority ~0) and hands its devices to the starved one."""
    policy = FleetPolicy(idle_to_zero_s=1e9)  # only preemption may drain
    topo, fleet = _fleet(n_hosts=1, devs=4, fleet_policy=policy)
    assert fleet.free_devices() == []  # 2 models x (1P+1D) fill the host

    rng = np.random.default_rng(5)
    t = 0.0
    for _ in range(10):
        fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=16).astype(np.int32), 6, t)
    max_engines_a = 0
    for _ in range(2000):
        if fleet.n_outstanding == 0:
            break
        t += 0.01
        fleet.tick(t)
        assert fleet.param_pool.invariant_ok()
        max_engines_a = max(max_engines_a, fleet.tenants["maas-a"].runtime.n_engines)
    assert fleet.n_outstanding == 0
    assert fleet.stats.preemptions >= 1
    assert fleet.tenants["maas-b"].stats.preempted >= 1
    # the victim gave up everything; the hot model actually grew past its seat
    assert fleet.tenants["maas-b"].runtime.n_engines == 0
    assert max_engines_a > 2


def test_half_seated_cold_start_recovers():
    """A cold start that finds only ONE free device seats just a prefill
    engine; once a second device frees up, arbitration must grant the
    missing decode seat (zero decode load reads zero pressure, so this
    needs the explicit empty-phase demand) and the request completes."""
    topo = tp.add_host_sources(tp.make_cluster(1, 3, bw_gbps=100.0))
    fleet = FleetScheduler(topo, policy=FleetPolicy(idle_to_zero_s=0.3))
    fleet.add_model(
        CFG_A, PARAMS, n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
        model_bytes=int(50e6), prefill_capacity_tps=200.0, decode_capacity_tps=50.0,
        policy=PolicyConfig(max_instances=2, kv_upper=0.5, scale_down_timeout_s=0.4),
    )
    rng = np.random.default_rng(9)
    t = 0.0
    fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=7).astype(np.int32), 4, t)
    t = _drain(fleet, t)
    while fleet.tenants["maas-a"].state != ZERO:
        t += 0.05
        fleet.tick(t)

    # a foreign workload takes two of the three devices
    taken = [d.id for d in topo.spares()][1:]
    for i in taken:
        topo.device(i).role = tp.Role.PREFILL
    rid = fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=7).astype(np.int32), 4, t)
    for _ in range(20):
        t += 0.01
        fleet.tick(t)
    rt = fleet.tenants["maas-a"].runtime
    assert rt.n_engines == 1  # half-seated: prefill only
    assert fleet.n_outstanding == 1  # and the request cannot flow yet

    for i in taken:  # the foreign workload leaves
        topo.device(i).role = tp.Role.FREE
    t = _drain(fleet, t)
    assert rt.completed[rid].out_tokens  # decode seat arrived, request served
    assert rt.pool.n_provisioned("decode") >= 1


def test_zipf_mixer_skew_and_order():
    w = traces.zipf_weights(4, alpha=1.2)
    assert w[0] > w[1] > w[2] > w[3] and np.isclose(w.sum(), 1.0)
    mix = traces.multi_model_mix(["a", "b", "c"], duration=60.0, total_rate=3.0, seed=1)
    ts = [t for t, *_ in mix]
    assert ts == sorted(ts) and all(0 <= x < 60.0 for x in ts)
    counts = {m: 0 for m in "abc"}
    for _, m, p, o in mix:
        counts[m] += 1
        assert p > 0 and o > 0
    assert counts["a"] > counts["b"] > counts["c"]  # popularity skew


def test_slo_class_weights_arbitration_priority():
    """At equal load, the latency tier outranks the throughput tier; among
    cold-starters (both inf) the ranked sort tie-breaks on class weight."""
    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    fleet = FleetScheduler(topo)
    kw = dict(n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
              model_bytes=int(50e6), prefill_capacity_tps=200.0,
              decode_capacity_tps=50.0)
    t_lat = fleet.add_model(CFG_A, PARAMS, slo_class=LATENCY, **kw)
    t_thr = fleet.add_model(CFG_B, PARAMS, slo_class=THROUGHPUT, **kw)
    assert t_lat.class_weight > t_thr.class_weight
    rng = np.random.default_rng(2)
    for m in ("maas-a", "maas-b"):  # identical offered load
        for _ in range(3):
            fleet.submit(m, rng.integers(0, CFG.vocab_size, size=8).astype(np.int32), 4, 0.0)
    fleet.tick(0.05)  # arms the monitor clocks
    fleet.tick(0.10)  # dt > 0: offered load lands in the monitors
    assert t_lat.runtime.slo_pressure() > 0
    assert t_lat.priority() > t_thr.priority()


def test_mixed_tier_trace_kinds():
    """multi_model_mix drives each tier with its own trace shape in one
    merged, time-ordered trace."""
    mix = traces.multi_model_mix(
        ["chat", "batch"],
        duration=120.0,
        total_rate=3.0,
        kind={"chat": "burstgpt", "batch": "azure_conv"},
        seed=3,
    )
    ts = [t for t, *_ in mix]
    assert ts == sorted(ts)
    models = {m for _, m, _, _ in mix}
    assert models == {"chat", "batch"}
    # azure_conv prompts average ~1024 tokens vs burstgpt's ~512
    p_chat = np.mean([p for _, m, p, _ in mix if m == "chat"])
    p_batch = np.mean([p for _, m, p, _ in mix if m == "batch"])
    assert p_batch > p_chat


def test_admission_control_sheds_lowest_class_when_saturated():
    """Fleet-wide saturation: the throughput-class queue is bounded by
    explicit rejections instead of growing without limit; every rejected
    request carries the rejection status and stops counting outstanding."""
    topo = tp.add_host_sources(tp.make_cluster(1, 2, bw_gbps=100.0))
    fleet = FleetScheduler(
        topo,
        policy=FleetPolicy(
            idle_to_zero_s=1e9,
            saturation_pressure=0.0,  # saturation = no grantable device
            shed_queue_depth=2,
        ),
    )
    fleet.add_model(
        CFG_A, PARAMS, slo_class=THROUGHPUT, n_prefill=1, n_decode=1,
        n_slots=2, max_seq=48, model_bytes=int(50e6),
        prefill_capacity_tps=200.0, decode_capacity_tps=50.0,
        policy=PolicyConfig(max_instances=1, kv_upper=0.5),
    )
    assert fleet.free_devices() == []  # both devices seated -> saturated
    rng = np.random.default_rng(7)
    t = 0.0
    n = 10
    rids = [
        fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=8).astype(np.int32), 4, t)
        for _ in range(n)
    ]
    t = _drain(fleet, t)
    rt = fleet.tenants["maas-a"].runtime
    assert fleet.stats.rejections >= 1
    assert rt.stats.rejected == fleet.stats.rejections
    assert fleet.tenants["maas-a"].stats.rejected == fleet.stats.rejections
    served = sum(1 for r in rids if r in rt.completed)
    shed = sum(1 for r in rids if r in rt.rejected)
    assert served + shed == n  # nothing lost, nothing double-counted
    for r in rids:
        rec = rt.router.records[r]
        if r in rt.rejected:
            assert rec.rejected and rec.rejected_at is not None and rec.ttft is None
        else:
            assert not rec.rejected


def test_placement_affinity_prefers_leaves_with_gpu_copies():
    """Grants go to the leaf holding a surviving GPU copy first (multicast
    stays intra-leaf); within a leaf, FlowSim transfer-time estimates break
    ties (a degraded NIC ranks last)."""
    # 2 leaves x 2 devices: leaf 0 = devs {0,1}, leaf 1 = devs {2,3}
    topo = tp.add_host_sources(tp.make_cluster(2, 2, hosts_per_leaf=1, bw_gbps=100.0))
    fleet = FleetScheduler(topo)
    t = fleet.add_model(
        CFG_A, PARAMS, n_prefill=1, n_decode=0, n_slots=2, max_seq=48,
        model_bytes=int(50e6), prefill_capacity_tps=200.0, decode_capacity_tps=50.0,
    )
    # GPU copy lives on dev 0 (leaf 0); free: 1 (leaf 0), 2 and 3 (leaf 1)
    assert sorted(fleet.free_devices()) == [1, 2, 3]
    ranked = fleet._rank_free_for(t, set(fleet.free_devices()))
    assert ranked[0] == 1  # same-leaf device wins
    # degrade dev 2's ingress: within leaf 1 the clean NIC now ranks first
    fleet.net.degrade_link(("dev_in", 2), 0.1)
    ranked = fleet._rank_free_for(t, set(fleet.free_devices()))
    assert ranked == [1, 3, 2]


def _fleet_with_inflight_scale(seed=1):
    """3 leaves x 2 devices; the model is seated on leaf 0, so a burst makes
    arbitration grant leaf-1/2 devices and live-scale onto them — returns
    the fleet mid-flight with at least one LOADING engine off leaf 0."""
    topo = tp.add_host_sources(tp.make_cluster(3, 2, hosts_per_leaf=1, bw_gbps=100.0))
    fleet = FleetScheduler(topo, policy=FleetPolicy(idle_to_zero_s=1e9))
    fleet.add_model(
        CFG_A, PARAMS, n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
        model_bytes=int(2e9),  # ~0.16 s on 100 Gbps: many ticks in flight
        prefill_capacity_tps=50.0, decode_capacity_tps=20.0,
        policy=PolicyConfig(max_instances=3, kv_upper=0.5),
    )
    rt = fleet.tenants["maas-a"].runtime
    rng = np.random.default_rng(seed)
    now = 0.0
    for _ in range(12):
        fleet.submit("maas-a", rng.integers(0, CFG.vocab_size, size=16).astype(np.int32), 6, now)
    loading = []
    for _ in range(400):
        now += 0.02
        fleet.tick(now)
        loading = [pe for pe in rt.pool.all() if pe.state == P.LOADING]
        if loading:
            break
    assert loading, "no live-scale ever started"
    assert all(topo.leaf_of(pe.device_id) != 0 for pe in loading)
    return topo, fleet, rt, loading, now


def test_leaf_failure_mid_cold_start_regrants_within_one_tick():
    """Fail a leaf while parameters are streaming onto it: the scheduler's
    failure subscription — not the victim runtime's drain path — must
    cancel the doomed grant, re-rank affinity against the post-failure
    network, and re-grant on a surviving leaf IMMEDIATELY (inside the
    fail_leaf call, before any further tick)."""
    topo, fleet, rt, loading, now = _fleet_with_inflight_scale()
    n_doomed = len(loading)
    doomed_devs = {pe.device_id for pe in loading}
    dead_leaf = topo.leaf_of(loading[0].device_id)

    fleet.net.fail_leaf(dead_leaf, now)

    # grant cancelled: doomed engines are gone and dead devices revoked
    assert rt.stats.cancelled_scales == n_doomed
    assert not doomed_devs & {pe.device_id for pe in rt.pool.all()}
    assert not doomed_devs & (rt.allowed_devices or set())
    # re-granted elsewhere, within the same event — zero ticks elapsed
    regrants = [pe for pe in rt.pool.all() if pe.state == P.LOADING]
    assert len(regrants) == n_doomed == fleet.stats.failure_regrants
    assert all(topo.leaf_of(pe.device_id) != dead_leaf for pe in regrants)
    # affinity re-ranked: the replacement multicast sources are all alive
    assert all(fleet.net.device_ok(pe.device_id) for pe in regrants)

    # the fleet still drains every request to completion, token-faithfully
    for _ in range(6000):
        if fleet.n_outstanding == 0:
            break
        now += 0.02
        fleet.tick(now)
    assert fleet.n_outstanding == 0
    _, gapped = rt.router.handoff_report()
    assert gapped == 0


def test_failure_not_double_handled_by_drain_and_subscription():
    """The runtime's abort→drain path and the scheduler's subscription see
    the SAME failure: exactly one abort, one cancellation and one re-grant
    per doomed engine — no duplicate re-plans, no drain-path retirement of
    an engine the subscription already tore down, and a repeated failure
    event for the same devices is a no-op."""
    topo, fleet, rt, loading, now = _fleet_with_inflight_scale(seed=2)
    n_doomed = len(loading)
    doomed_devs = {pe.device_id for pe in loading}
    dead_leaf = topo.leaf_of(loading[0].device_id)
    scales_before = rt.stats.live_scaled_prefill + rt.stats.direct_decode_scales
    retired_before = rt.stats.retired

    fleet.net.fail_leaf(dead_leaf, now)

    # each doomed engine: ONE abort (runtime callback), ONE cancellation
    # (subscription), ONE replacement live-scale (subscription re-grant)
    assert rt.stats.aborted_param_streams == n_doomed
    assert rt.stats.cancelled_scales == n_doomed
    assert fleet.stats.failure_regrants == n_doomed
    assert (rt.stats.live_scaled_prefill + rt.stats.direct_decode_scales
            == scales_before + n_doomed)
    # not ALSO retired via the drain path — the subscription removed them
    assert rt.stats.retired == retired_before

    # a couple of ticks later the drain path must not rediscover the dead
    # engines (they are no longer in the pool) nor re-plan a second time
    for _ in range(3):
        now += 0.02
        fleet.tick(now)
    assert rt.stats.cancelled_scales == n_doomed
    assert fleet.stats.failure_regrants == n_doomed
    assert not doomed_devs & {pe.device_id for pe in rt.pool.all()}

    # replaying the failure for an already-dead device changes nothing
    before = (fleet.stats.failure_regrants, rt.stats.cancelled_scales,
              rt.stats.aborted_param_streams)
    fleet.net.fail_device(next(iter(doomed_devs)), now)
    assert (fleet.stats.failure_regrants, rt.stats.cancelled_scales,
            rt.stats.aborted_param_streams) == before


def test_fleet_rejects_overcommitted_seating():
    topo = tp.add_host_sources(tp.make_cluster(1, 2, bw_gbps=100.0))
    fleet = FleetScheduler(topo)
    fleet.add_model(CFG_A, PARAMS, n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
                    model_bytes=int(50e6), prefill_capacity_tps=200.0,
                    decode_capacity_tps=50.0)
    with pytest.raises(ValueError, match="free"):
        fleet.add_model(CFG_B, PARAMS, n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
                        model_bytes=int(50e6), prefill_capacity_tps=200.0,
                        decode_capacity_tps=50.0)
