"""Live scaling: cooperative execution correctness + session state machine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.live_scaling import LiveSession, Phase, cooperative_forward, select_live_pairs
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.models import transformer as TF

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-8b", "olmoe-1b-7b", "mamba2-370m"])
def test_cooperative_forward_equals_monolithic(arch):
    """The correctness contract of live scaling (§5.2): target [0,k) +
    source [k,L) == single-instance forward, for every split point."""
    cfg = get_config(arch, reduced=True)
    params = TF.init_params(KEY, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits_full, _ = TF.train_forward(cfg, params, tokens)
    for k in [0, 1, cfg.n_layers // 2, cfg.n_layers]:
        logits_coop = cooperative_forward(cfg, params, tokens, k)
        np.testing.assert_allclose(
            logits_coop.astype(jnp.float32),
            logits_full.astype(jnp.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_cooperative_forward_traced_k_single_compile():
    """k is a traced value: the same jitted function serves every split
    (no per-k recompilation during loading — the TPU analogue of the CUDA
    context pool)."""
    cfg = get_config("granite-8b", reduced=True)
    params = TF.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    calls = {"n": 0}

    @jax.jit
    def coop(p, t, k):
        calls["n"] += 1
        return cooperative_forward(cfg, p, t, k)

    outs = [coop(params, tokens, jnp.int32(k)) for k in range(cfg.n_layers + 1)]
    assert calls["n"] == 1  # traced once
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-2, rtol=1e-2)


def test_live_session_phases_and_ramp():
    sess = LiveSession(n_layers=8, layer_bytes=100, link_bytes_per_s=100.0, started_at=0.0)
    assert sess.layers_loaded(0.0) == 0
    assert sess.throughput_multiplier(0.0) == 1.0
    assert sess.phase is Phase.REDIRECT
    m_half = sess.throughput_multiplier(4.0)  # 4 layers loaded
    assert m_half == 2.0
    assert sess.phase is Phase.COOPERATIVE
    assert sess.throughput_multiplier(8.0) == 2.0
    assert sess.phase is Phase.REBALANCED
    assert sess.done_at() == pytest.approx(8.0)


def test_select_live_pairs_uses_chain_tails():
    topo = tp.add_host_sources(tp.make_cluster(3, 4))
    topo.device(0).model = "m"
    topo.device(0).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, [0], spares, len(spares))
    pairs = select_live_pairs(plan, overloaded=[0])
    assert pairs
    tails = {n.device_ids[0] for n in plan.live_scale_nodes}
    for src, tgt in pairs:
        assert src == 0 and tgt in tails
    assert select_live_pairs(plan, [0], slo_requires_live=False) == []
