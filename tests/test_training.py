"""Training substrate: convergence, microbatch equivalence, checkpoint
fault tolerance (kill/restart determinism), optimizer math."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokenPipeline, make_batch
from repro.models import transformer as TF
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.training.train_step import build_train_step

CFG = get_config("granite-8b", reduced=True)
OPT = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=200)


def _batch(step, b=8, s=64):
    return {k: jnp.asarray(v) for k, v in make_batch(CFG, b, s, step=step).items()}


@pytest.mark.slow
def test_loss_decreases_over_training():
    params = TF.init_params(jax.random.PRNGKey(0), CFG)
    opt = adamw_init(params, OPT)
    step_fn = jax.jit(build_train_step(CFG, OPT, microbatches=1))
    losses = []
    for i in range(25):
        params, opt, m = step_fn(params, opt, _batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.15
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_microbatched_grads_match_full_batch():
    """Gradient accumulation over n microbatches == one full-batch step."""
    params = TF.init_params(jax.random.PRNGKey(1), CFG)
    opt = adamw_init(params, OPT)
    b = _batch(0)
    p1, o1, m1 = jax.jit(build_train_step(CFG, OPT, microbatches=1))(params, opt, b)
    p4, o4, m4 = jax.jit(build_train_step(CFG, OPT, microbatches=4))(params, opt, b)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), atol=5e-3, rtol=5e-2
        )


def test_adamw_against_manual_reference():
    """One AdamW step vs a hand-written numpy implementation."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip=1e9, warmup_steps=0, total_steps=10, min_lr_frac=1.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    opt = adamw_init(p, cfg)
    new_p, new_opt, _ = adamw_update(p, g, opt, cfg)

    gn = np.linalg.norm([0.1, 0.2, -0.3])
    clip = min(1.0, 1e9 / gn)
    gval = np.array([0.1, 0.2, -0.3]) * clip
    m = 0.1 * gval
    v = 0.01 * gval**2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.array([1.0, -2.0, 3.0]) - 0.1 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * np.array([1.0, -2.0, 3.0])
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_opt["step"]) == 1


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=0.5, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, min_lr_frac=1.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    opt = adamw_init(p, cfg)
    _, _, metrics = adamw_update(p, g, opt, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-2)
    mid = float(lr_at(cfg, jnp.int32(55)))
    assert 0.1 < mid < 1.0


@pytest.mark.slow
def test_checkpoint_restart_reproduces_trajectory():
    """Fault tolerance: train 10 steps with a checkpoint at 5, kill, restore,
    re-run 5..10 — final params must be IDENTICAL (deterministic pipeline +
    full optimizer state in the checkpoint)."""
    step_fn = jax.jit(build_train_step(CFG, OPT, microbatches=1))

    params = TF.init_params(jax.random.PRNGKey(2), CFG)
    opt = adamw_init(params, OPT)
    with tempfile.TemporaryDirectory() as d:
        for i in range(10):
            params, opt, _ = step_fn(params, opt, _batch(i))
            if i == 4:
                save_checkpoint(d, 5, {"params": params, "opt": opt})
        final_a = jax.tree.leaves(params)

        # "crash" and restart from the checkpoint
        tmpl = {
            "params": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            "opt": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt),
        }
        state, step = restore_checkpoint(d, tmpl)
        assert step == 5
        p2, o2 = state["params"], state["opt"]
        for i in range(5, 10):
            p2, o2, _ = step_fn(p2, o2, _batch(i))
        for a, b in zip(final_a, jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_pruning():
    with tempfile.TemporaryDirectory() as d:
        tree = {"x": jnp.arange(5.0)}
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, tree, keep=2)
        assert latest_step(d) == 4
        steps = sorted(int(n[5:]) for n in os.listdir(d) if n.startswith("step_"))
        assert steps == [3, 4]  # pruned
        # a stray .tmp dir must never be picked up
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert latest_step(d) == 4


def test_pipeline_determinism_and_sharding():
    pipe = SyntheticTokenPipeline(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    a = pipe.batch(3)
    b = pipe.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    c = pipe.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])  # step-dependent
    # labels are next-token shifted
    full_a = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], a["labels"])
    # host slices are deterministic per (step, host)
    s0 = pipe.host_slice(3, 0, 2)
    s0b = pipe.host_slice(3, 0, 2)
    np.testing.assert_array_equal(s0["tokens"], s0b["tokens"])
    assert s0["tokens"].shape[0] == 4
