"""Streaming SLO monitor: P² quantile accuracy, burn-rate windows,
fleet_health shape, span ingestion.

The P² estimator is validated against numpy's exact percentile on seeded
samples (it's an approximation — tolerances are distribution-scale
relative, tight enough to catch a broken marker update, loose enough not
to flake on estimator variance)."""

import numpy as np
import pytest

from repro.obs.slo import DEFAULT_WINDOWS_S, P2Quantile, SLOMonitor


# ---------------------------------------------------------------------------
# P² quantile estimator
# ---------------------------------------------------------------------------


def test_p2_rejects_degenerate_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


def test_p2_empty_and_small_counts():
    q = P2Quantile(0.5)
    assert q.value() is None
    q.observe(3.0)
    assert q.value() == 3.0  # nearest rank of a single sample
    q.observe(1.0)
    q.observe(2.0)
    assert q.value() in (1.0, 2.0, 3.0)
    assert q.count == 3


@pytest.mark.parametrize("qq", [0.5, 0.9, 0.99])
@pytest.mark.parametrize("dist,seed", [("uniform", 0), ("exp", 1), ("lognorm", 2)])
def test_p2_tracks_numpy_percentile(qq, dist, seed):
    rng = np.random.default_rng(seed)
    n = 5000
    xs = {
        "uniform": rng.uniform(0, 10, n),
        "exp": rng.exponential(2.0, n),
        "lognorm": rng.lognormal(0.0, 1.0, n),
    }[dist]
    est = P2Quantile(qq)
    for x in xs:
        est.observe(float(x))
    exact = float(np.percentile(xs, qq * 100))
    scale = float(np.percentile(xs, 99)) or 1.0
    # within 10% of the distribution's tail scale — catches any broken
    # marker arithmetic while leaving room for estimator variance
    assert abs(est.value() - exact) < 0.10 * scale, (est.value(), exact)


def test_p2_monotone_input_is_exactish():
    est = P2Quantile(0.5)
    for i in range(1, 1001):
        est.observe(float(i))
    assert est.value() == pytest.approx(500.0, rel=0.05)


def test_p2_is_deterministic():
    def run():
        e = P2Quantile(0.99)
        rng = np.random.default_rng(42)
        for x in rng.exponential(1.0, 500):
            e.observe(float(x))
        return e.value()

    assert run() == run()


# ---------------------------------------------------------------------------
# burn-rate windows + status
# ---------------------------------------------------------------------------


def test_burn_rate_windows_and_status():
    mon = SLOMonitor(ttft_slo_s=1.0, windows_s=(10.0, 100.0), target=0.9)
    # 10% violations == exactly the 10% error budget -> burn 1.0 -> warn
    for i in range(100):
        mon.observe_ttft("t", float(i) * 0.1, 2.0 if i % 10 == 0 else 0.5)
    th = mon.tenant_health("t")
    assert th["burn_rate"]["100s"] == pytest.approx(1.0)
    assert th["status"] == "warn"
    # a later burst of pure violations fills the fast window -> page
    for i in range(50):
        mon.observe_ttft("t", 30.0 + i * 0.01, 5.0)
    th = mon.tenant_health("t")
    assert th["burn_rate"]["10s"] >= 10.0
    assert th["status"] == "page"
    # quiet recovery: the fast window drains first (sliding expiry)
    for i in range(200):
        mon.observe_ttft("t", 45.0 + i * 0.1, 0.1)
    th = mon.tenant_health("t")
    assert th["burn_rate"]["10s"] == 0.0
    assert th["burn_rate"]["100s"] > 0.0  # slow window still remembers


def test_no_slo_means_no_violations():
    mon = SLOMonitor()  # no SLOs configured anywhere
    mon.observe_ttft("t", 0.0, 1e9)
    th = mon.tenant_health("t")
    assert th["status"] == "ok" and th["ttft_attainment"] == 1.0


def test_per_tenant_slo_override():
    mon = SLOMonitor(ttft_slo_s=1.0, tbt_slo_s=None)
    mon.set_slo("strict", ttft_slo_s=0.1)
    mon.observe_ttft("strict", 0.0, 0.5)  # violates 0.1, fine vs default 1.0
    mon.observe_ttft("lax", 0.0, 0.5)
    assert mon.tenant_health("strict")["ttft_attainment"] == 0.0
    assert mon.tenant_health("lax")["ttft_attainment"] == 1.0


def test_tbt_stream_feeds_the_same_surface():
    mon = SLOMonitor(tbt_slo_s=0.05)
    for i in range(20):
        mon.observe_tbt("t", i * 0.01, 0.01 if i % 2 else 0.1)
    th = mon.tenant_health("t")
    assert th["tbt_attainment"] == pytest.approx(0.5)
    assert th["tbt_p99_s"] is not None
    assert th["ttft_p99_s"] is None  # no TTFT observed


# ---------------------------------------------------------------------------
# fleet_health
# ---------------------------------------------------------------------------


def test_fleet_health_shape_and_worst_status():
    mon = SLOMonitor(ttft_slo_s=1.0, target=0.99)
    mon.observe_ttft("good", 0.0, 0.1)
    for i in range(30):
        mon.observe_ttft("bad", i * 0.1, 9.0)
    fh = mon.fleet_health()
    assert fh["target"] == 0.99
    assert fh["windows_s"] == list(DEFAULT_WINDOWS_S)
    assert sorted(fh["tenants"]) == ["bad", "good"]
    assert fh["tenants"]["good"]["status"] == "ok"
    assert fh["tenants"]["bad"]["status"] == "page"
    assert fh["status"] == "page"  # worst tenant wins
    import json

    json.dumps(fh)  # JSON-ready, no NaN/inf


def test_fleet_health_empty_monitor():
    fh = SLOMonitor().fleet_health()
    assert fh["tenants"] == {} and fh["status"] == "ok"


# ---------------------------------------------------------------------------
# span ingestion (tracer -> monitor)
# ---------------------------------------------------------------------------


def test_ingest_spans_from_traced_sim():
    from repro.obs.report import run_traced_sim

    tracer, result = run_traced_sim(duration=8.0, rate=4.0, seed=0)
    mon = SLOMonitor(ttft_slo_s=5.0)
    n = mon.ingest_spans(list(tracer.spans))
    finished = [r for r in result.requests if r.ttft is not None]
    assert n == len(finished) > 0
    th = mon.tenant_health("default")
    assert th["requests"] == n
    # streamed P99 close to the exact post-hoc percentile
    exact = result.p99_ttft()
    assert th["ttft_p99_s"] == pytest.approx(exact, rel=0.5, abs=0.05)


def test_simulator_slo_hook_matches_span_ingestion():
    """Feeding the monitor live (slo_monitor=) sees the same request
    population as post-hoc span ingestion."""
    import repro.core.simulator as sim
    from repro.serving import traces

    mon = SLOMonitor(ttft_slo_s=5.0)
    s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=0, slo_monitor=mon)
    res = s.run(traces.burstgpt(duration=8.0, base_rate=4.0, seed=11))
    finished = [r for r in res.requests if r.ttft is not None]
    th = mon.tenant_health("sim")
    assert th["requests"] == len(finished) > 0
    assert mon._state("sim").tbt_n > 0  # completions streamed TBTs too
