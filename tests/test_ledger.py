"""Fleet utilization ledgers: exact device-second conservation + link-time
attribution bounds.

The load-bearing properties:

  * **conservation is exact, not within-epsilon**: the simulator defines
    ``gpu_time_s`` as ``ledger.total()``, and ``total()`` sums the per-state
    floats in the same fixed order as ``sum(breakdown().values())`` — so the
    invariant holds bit-for-bit across systems, seeds and scenarios;
  * the link ledger's capacity-normalized busy-seconds can never exceed the
    elapsed horizon per link (max-min conserves capacity), and attributed
    bytes can never exceed ``cap_seen x horizon``;
  * attaching either ledger changes NOTHING about the simulation — the
    flow-event stream and all results stay bit-for-bit;
  * the disagg runtime and the MaaS fleet accrue owner-attributed states
    covering the full engine lifecycle (grant -> load -> serve -> drain).
"""

import math

import pytest

from repro.net import Flow, FlowEventLog, FlowKind, FlowSim
from repro.obs import DEVICE_STATES, DeviceTimeLedger, LinkLedger
from repro.obs.ledger import FLOW_GROUPS


# ---------------------------------------------------------------------------
# DeviceTimeLedger unit behaviour
# ---------------------------------------------------------------------------


def test_ledger_accrue_and_views():
    led = DeviceTimeLedger()
    led.accrue("serving_prefill", 1.5, owner="a")
    led.accrue("serving_decode", 2.5, owner="a")
    led.accrue("loading_params", 1.0, owner="b")
    led.accrue("allocated_idle", 0.0)  # no-op
    led.accrue("draining", -1.0)  # no-op
    assert led.total() == 5.0
    bd = led.breakdown()
    assert list(bd) == list(DEVICE_STATES)  # every state, fixed order
    assert bd["serving_prefill"] == 1.5 and bd["stalled_waiting_layers"] == 0.0
    assert led.owners() == ["a", "b"]
    assert led.owner_breakdown("a")["serving_decode"] == 2.5
    assert led.owner_breakdown("missing")["draining"] == 0.0
    assert led.utilization() == pytest.approx(4.0 / 5.0)
    m = led.as_metrics()
    assert m["gpu_s.loading_params"] == 1.0 and len(m) == len(DEVICE_STATES)


def test_ledger_rejects_unknown_state():
    with pytest.raises(ValueError, match="unknown ledger state"):
        DeviceTimeLedger().accrue("busy", 1.0)


def test_empty_ledger_conserves_trivially():
    led = DeviceTimeLedger()
    assert led.total() == 0.0 == sum(led.breakdown().values())
    assert led.utilization() == 0.0


# ---------------------------------------------------------------------------
# simulator conservation: sum(device_seconds) == gpu_time_s, bit-for-bit
# ---------------------------------------------------------------------------


def _run(system, *, seed=0, duration=12.0, rate=4.0, **kw):
    import repro.core.simulator as sim
    from repro.serving import traces

    cfg = {
        "blitz": sim.BLITZ,
        "sllm": sim.SLLM,
        "fixed": sim.fixed_system("fixed", 2, 2),
    }[system]
    s = sim.Simulator(cfg, sim.profile_for("8b"), seed=seed, **kw)
    return s.run(traces.burstgpt(duration=duration, base_rate=rate, seed=seed + 11))


@pytest.mark.parametrize("system", ["blitz", "sllm", "fixed"])
@pytest.mark.parametrize("seed", [0, 3])
def test_device_seconds_sum_exactly_to_gpu_time(system, seed):
    r = _run(system, seed=seed)
    assert r.gpu_time_s > 0
    assert set(r.device_seconds) == set(DEVICE_STATES)
    # EXACT equality: both sides sum the same floats in the same order
    assert sum(r.device_seconds.values()) == r.gpu_time_s
    assert all(v >= 0.0 for v in r.device_seconds.values())


def test_autoscaling_attributes_loading_time_fixed_does_not():
    blitz = _run("blitz")
    fixed = _run("fixed")
    assert blitz.device_seconds["loading_params"] > 0  # live scales happened
    assert fixed.device_seconds["loading_params"] == 0.0  # nothing ever scales
    assert fixed.device_seconds["stalled_waiting_layers"] == 0.0
    # serving time exists on both
    assert blitz.device_seconds["serving_decode"] > 0
    assert fixed.device_seconds["serving_decode"] > 0


def test_ledger_is_observation_only():
    """Attaching the link ledger + slo monitor changes nothing about the
    simulation: flow events and results are bit-for-bit the plain run."""
    import repro.core.simulator as sim
    from repro.obs.slo import SLOMonitor
    from repro.serving import traces

    def lines(**kw):
        s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=0, **kw)
        log = FlowEventLog()
        s.flowsim.subscribe(log)
        res = s.run(traces.burstgpt(duration=10.0, base_rate=4.0, seed=7))
        return log.lines(), res.p99_ttft(), res.gpu_time_s

    off = lines()
    on = lines(link_ledger=LinkLedger(), slo_monitor=SLOMonitor(ttft_slo_s=1.0))
    assert off == on


# ---------------------------------------------------------------------------
# LinkLedger: flow-kind attribution + capacity bounds
# ---------------------------------------------------------------------------

GB = 1e9


def _flat_cluster(n_devs, *, hosts_per_leaf=2, bw=8.0):
    from repro.core import topology as tp

    return tp.make_cluster(n_devs, 1, hosts_per_leaf=hosts_per_leaf, bw_gbps=bw)


def _check_link_bounds(led: LinkLedger):
    horizon = led.horizon
    for link_key in led.links():
        busy = led.link_busy(link_key)
        assert busy <= horizon * (1 + 1e-9) + 1e-6, (link_key, busy, horizon)
        cap = led.cap_seen.get(link_key, 0.0)
        link_bytes = sum(v for (k, _), v in led.bytes.items() if k == link_key)
        assert link_bytes <= cap * horizon * (1 + 1e-9) + 1e-6


def test_link_ledger_attributes_flow_kinds():
    sim = FlowSim(_flat_cluster(4))
    led = sim.attach_ledger(LinkLedger())
    sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, GB), 0.0)
    sim.start(Flow(FlowKind.COLD_START, 2, 3, GB), 0.0)
    sim.advance_to(2.0)
    assert led.horizon == 2.0
    by_group = led.bytes_by_group()
    assert by_group["kv"] > 0 and by_group["cold_start"] > 0
    # full GB crossed every hop of each path
    assert by_group["kv"] == pytest.approx(GB * 4, rel=0.5)
    _check_link_bounds(led)


def test_link_ledger_contended_link_busy_bounded_by_horizon():
    """Two kinds sharing one ingress: per-link busy time sums across groups
    yet never exceeds elapsed time (max-min conserves capacity)."""
    sim = FlowSim(_flat_cluster(8, hosts_per_leaf=8))
    led = sim.attach_ledger(LinkLedger())
    for src, kind in ((0, FlowKind.KV_MIGRATION), (1, FlowKind.MULTICAST_HOP),
                      (2, FlowKind.COLD_START), (3, FlowKind.KV_MIGRATION)):
        sim.start(Flow(kind, src, 7, GB), 0.0)
    sim.advance_to(10.0)
    _check_link_bounds(led)
    # the shared ingress was saturated for ~4s; attribution splits it
    ingress = [k for k in led.links() if led.link_busy(k) > 3.5]
    assert ingress, "no saturated link found"
    bd = led.link_breakdown(ingress[0])
    assert set(bd) >= {"kv", "multicast", "cold_start"}


def test_link_ledger_background_serving_stream_accrues():
    sim = FlowSim(_flat_cluster(4))
    led = sim.attach_ledger(LinkLedger())
    sim.start(Flow(FlowKind.SERVING, 3, 2, math.inf), 0.0)
    sim.start(Flow(FlowKind.KV_MIGRATION, 0, 2, GB), 0.0)
    sim.advance_to(3.0)
    by_group = led.busy_by_group()
    assert by_group["serving"] > 0 and by_group["kv"] > 0
    _check_link_bounds(led)


def test_link_ledger_survives_degraded_links():
    """cap_seen keeps the max capacity ever observed, so the bytes bound
    holds across a mid-run degrade."""
    sim = FlowSim(_flat_cluster(4))
    led = sim.attach_ledger(LinkLedger())
    from repro.net import DEV_IN

    sim.start(Flow(FlowKind.KV_MIGRATION, 0, 1, 4 * GB), 0.0)
    sim.advance_to(1.0)
    sim.degrade_link((DEV_IN, 1), 0.25, 1.0)
    sim.advance_to(8.0)
    _check_link_bounds(led)


def test_simulator_link_ledger_end_to_end():
    r_led = LinkLedger()
    r = _run("blitz", link_ledger=r_led)
    assert r.gpu_time_s > 0
    assert r_led.horizon > 0
    groups = r_led.groups()
    assert "multicast" in groups  # live scales moved parameter bytes
    _check_link_bounds(r_led)
    assert r_led.busiest(3)  # non-empty, sorted hot-link view


def test_flow_groups_cover_every_flow_kind():
    assert set(FLOW_GROUPS) == set(FlowKind)


# ---------------------------------------------------------------------------
# disagg runtime + MaaS fleet accrual (owner attribution)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet_ledger_run():
    jax = pytest.importorskip("jax")
    import numpy as np

    from repro.configs import get_config
    from repro.core import topology as tp
    from repro.core.autoscaler import PolicyConfig
    from repro.models import transformer as TF
    from repro.serving.maas import FleetPolicy, FleetScheduler
    from repro.obs.slo import SLOMonitor

    cfg = get_config("granite-8b", reduced=True)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    led = DeviceTimeLedger()
    slo = SLOMonitor(ttft_slo_s=2.0, tbt_slo_s=1.0)
    fleet = FleetScheduler(topo, policy=FleetPolicy(idle_to_zero_s=0.5),
                           ledger=led, slo_monitor=slo)
    for name in ("led-a", "led-b"):
        fleet.add_model(
            cfg.replace(name=name), params, n_prefill=1, n_decode=1,
            n_slots=2, max_seq=48, model_bytes=int(50e6),
            prefill_capacity_tps=200.0, decode_capacity_tps=50.0,
            policy=PolicyConfig(max_instances=3, kv_upper=0.5,
                                scale_down_timeout_s=0.4),
        )
    rng = np.random.default_rng(3)
    t = 0.0
    for _ in range(4):
        fleet.submit("led-a", rng.integers(0, cfg.vocab_size, size=7).astype(np.int32), 5, t)
    fleet.submit("led-b", rng.integers(0, cfg.vocab_size, size=7).astype(np.int32), 5, t)
    for _ in range(2000):
        if fleet.n_outstanding == 0:
            break
        t += 0.01
        fleet.tick(t)
    assert fleet.n_outstanding == 0
    # idle past the timeout so draining time accrues too
    for _ in range(100):
        t += 0.05
        fleet.tick(t)
    return fleet, led, slo


def test_fleet_ledger_owner_attribution(fleet_ledger_run):
    fleet, led, _ = fleet_ledger_run
    assert led.owners() == ["led-a", "led-b"]
    assert led.total() == sum(led.breakdown().values())  # exact, fleet too
    for owner in led.owners():
        bd = led.owner_breakdown(owner)
        assert bd["serving_decode"] > 0  # tokens were produced
        assert sum(bd.values()) > 0
    # scale-to-zero drained engines: drain time was accounted somewhere
    assert led.breakdown()["draining"] > 0
    # owner splits sum to the fleet-wide totals (every accrual is owner-keyed)
    for s in DEVICE_STATES:
        per_owner = sum(led.owner_breakdown(o)[s] for o in led.owners())
        assert per_owner == pytest.approx(led.breakdown()[s])


def test_fleet_health_surface(fleet_ledger_run):
    fleet, _, slo = fleet_ledger_run
    fh = fleet.fleet_health()
    assert fh["status"] in ("ok", "warn", "page")
    assert set(fh["tenants"]) == {"led-a", "led-b"}
    th = fh["tenants"]["led-a"]
    assert th["requests"] >= 4
    assert th["ttft_p99_s"] is not None and th["ttft_p99_s"] >= 0
    assert 0.0 <= th["ttft_attainment"] <= 1.0
    assert set(th["burn_rate"]) == {f"{w:g}s" for w in slo.windows_s}
    # an unmonitored fleet reports an empty surface, never raises
    from repro.serving.maas import FleetScheduler as FS
    from repro.core import topology as tp

    bare = FS(tp.add_host_sources(tp.make_cluster(1, 2, bw_gbps=100.0)))
    assert bare.fleet_health() == {}


# ---------------------------------------------------------------------------
# property test (hypothesis optional, like the rest of the repo)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(DEVICE_STATES),
                              st.floats(min_value=0.0, max_value=1e6)),
                    max_size=100))
    def test_ledger_conservation_is_exact_for_any_accrual_order(entries):
        led = DeviceTimeLedger()
        for state, v in entries:
            led.accrue(state, v, owner="t")
        # bit-for-bit: total() and the breakdown sum add the same floats in
        # the same DEVICE_STATES order
        assert led.total() == sum(led.breakdown().values())
        assert led.total() == sum(led.owner_breakdown("t").values())
