"""Serving engine (continuous batching) + router + paged KV cache."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models.kvcache import PagedKVCache
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.router import Router

CFG = get_config("granite-8b", reduced=True)


def _engine(n_slots=3, max_seq=64):
    params = TF.init_params(jax.random.PRNGKey(0), CFG)
    return InstanceEngine(CFG, params, n_slots=n_slots, max_seq=max_seq)


def test_continuous_batching_completes_all_requests():
    eng = _engine(n_slots=3)
    rng = np.random.default_rng(0)
    reqs = [
        ServeRequest(i, rng.integers(0, CFG.vocab_size, size=8).astype(np.int32),
                     max_new_tokens=4 + (i % 3))
        for i in range(7)  # more requests than slots -> queueing + reuse
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert len(done) == 7
    for r in done:
        assert len(r.out_tokens) >= r.max_new_tokens
        assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)


def test_engine_batched_equals_sequential():
    """Slot interleaving must not change any request's tokens."""
    prompts = [np.arange(5, dtype=np.int32) + i for i in range(3)]
    eng_b = _engine(n_slots=3)
    for i, p in enumerate(prompts):
        eng_b.submit(ServeRequest(i, p, 5))
    batched = {r.rid: r.out_tokens for r in eng_b.run_until_done()}

    for i, p in enumerate(prompts):
        eng_s = _engine(n_slots=1)
        eng_s.submit(ServeRequest(i, p, 5))
        (r,) = eng_s.run_until_done()
        assert batched[i] == r.out_tokens


def test_live_scaling_gate():
    eng = _engine()
    assert eng.can_serve_alone()
    eng.set_loaded_layers(1)
    assert not eng.can_serve_alone()
    eng.set_loaded_layers(CFG.n_layers)
    assert eng.can_serve_alone()


def test_router_fcfs_and_slo():
    router = Router()
    r1 = router.submit(10, 5, now=0.0)
    r2 = router.submit(10, 5, now=0.1)
    eng = _engine()
    dispatched = router.dispatch([eng])
    assert [rec.rid for rec, _ in dispatched] == [r1, r2]  # FCFS order
    router.note_first_token(r1, 0.5)
    router.note_first_token(r2, 0.7)
    for t in (0.6, 0.7, 0.8):
        router.note_token(r1, t)
    rep = router.slo_report()
    assert rep.n == 2
    assert rep.mean_ttft == pytest.approx((0.5 + 0.6) / 2)
    assert 0 <= rep.attainment <= 1


def test_router_skips_partially_loaded_engines():
    router = Router()
    router.submit(10, 5, now=0.0)
    loading = _engine()
    loading.set_loaded_layers(1)
    assert router.dispatch([loading]) == []  # work arrives cooperatively
    ready = _engine()
    assert len(router.dispatch([loading, ready])) == 1


def test_paged_cache_matches_contiguous():
    cache = PagedKVCache(n_blocks=16, block_size=4, n_kv=2, head_dim=8, dtype=np.float32)
    rng = np.random.default_rng(1)
    k = rng.standard_normal((11, 2, 8)).astype(np.float32)
    v = rng.standard_normal((11, 2, 8)).astype(np.float32)
    cache.allocate(0)
    cache.append(0, k[:6], v[:6])
    cache.append(0, k[6:], v[6:])
    kg, vg, length = cache.gather(0, max_seq=16)
    assert length == 11
    np.testing.assert_array_equal(kg[:11], k)
    np.testing.assert_array_equal(vg[:11], v)
    np.testing.assert_array_equal(kg[11:], 0)
    free_before = cache.n_free_blocks
    cache.release(0)
    assert cache.n_free_blocks == free_before + 3  # ceil(11/4) blocks back


def test_paged_cache_oom():
    cache = PagedKVCache(n_blocks=2, block_size=2, n_kv=1, head_dim=4, dtype=np.float32)
    cache.allocate(0)
    with pytest.raises(MemoryError):
        cache.append(0, np.zeros((5, 1, 4), np.float32), np.zeros((5, 1, 4), np.float32))
