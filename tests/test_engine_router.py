"""Serving engine (continuous batching) + router + paged KV cache."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models.kvcache import PagedKVCache
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.router import Router

CFG = get_config("granite-8b", reduced=True)


def _engine(n_slots=3, max_seq=64):
    params = TF.init_params(jax.random.PRNGKey(0), CFG)
    return InstanceEngine(CFG, params, n_slots=n_slots, max_seq=max_seq)


@pytest.mark.slow
def test_continuous_batching_completes_all_requests():
    eng = _engine(n_slots=3)
    rng = np.random.default_rng(0)
    reqs = [
        ServeRequest(i, rng.integers(0, CFG.vocab_size, size=8).astype(np.int32),
                     max_new_tokens=4 + (i % 3))
        for i in range(7)  # more requests than slots -> queueing + reuse
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert len(done) == 7
    for r in done:
        assert len(r.out_tokens) >= r.max_new_tokens
        assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)


@pytest.mark.slow
def test_engine_batched_equals_sequential():
    """Slot interleaving must not change any request's tokens."""
    prompts = [np.arange(5, dtype=np.int32) + i for i in range(3)]
    eng_b = _engine(n_slots=3)
    for i, p in enumerate(prompts):
        eng_b.submit(ServeRequest(i, p, 5))
    batched = {r.rid: r.out_tokens for r in eng_b.run_until_done()}

    for i, p in enumerate(prompts):
        eng_s = _engine(n_slots=1)
        eng_s.submit(ServeRequest(i, p, 5))
        (r,) = eng_s.run_until_done()
        assert batched[i] == r.out_tokens


def test_live_scaling_gate():
    eng = _engine()
    assert eng.can_serve_alone()
    eng.set_loaded_layers(1)
    assert not eng.can_serve_alone()
    eng.set_loaded_layers(CFG.n_layers)
    assert eng.can_serve_alone()


def test_router_fcfs_and_slo():
    router = Router()
    r1 = router.submit(10, 5, now=0.0)
    r2 = router.submit(10, 5, now=0.1)
    eng = _engine()
    dispatched = router.dispatch([eng])
    assert [rec.rid for rec, _ in dispatched] == [r1, r2]  # FCFS order
    router.note_first_token(r1, 0.5)
    router.note_first_token(r2, 0.7)
    for t in (0.6, 0.7, 0.8):
        router.note_token(r1, t)
    rep = router.slo_report()
    assert rep.n == 2
    assert rep.mean_ttft == pytest.approx((0.5 + 0.6) / 2)
    assert 0 <= rep.attainment <= 1


def test_router_skips_partially_loaded_engines():
    router = Router()
    router.submit(10, 5, now=0.0)
    loading = _engine()
    loading.set_loaded_layers(1)
    assert router.dispatch([loading]) == []  # work arrives cooperatively
    ready = _engine()
    assert len(router.dispatch([loading, ready])) == 1


def test_slo_five_x_average_ttft_rule():
    """§6.2: a request violates when its TTFT exceeds 5x the workload mean."""
    router = Router()
    rids = [router.submit(10, 5, now=0.0) for _ in range(10)]
    for rid in rids[:9]:
        router.note_first_token(rid, 0.1)
    router.note_first_token(rids[9], 10.0)
    rep = router.slo_report()
    # mean TTFT = (9*0.1 + 10)/10 = 1.09s; 5x = 5.45s -> only the straggler fails
    assert rep.mean_ttft == pytest.approx(1.09)
    assert rep.attainment == pytest.approx(0.9)


def test_slo_five_x_average_tbt_rule():
    """A single decode stall beyond 5x the mean TBT fails that request."""
    router = Router()
    a = router.submit(10, 5, now=0.0)
    b = router.submit(10, 5, now=0.0)
    router.note_first_token(a, 0.1)
    for i in range(1, 20):  # steady 0.1s TBTs
        router.note_token(a, 0.1 + 0.1 * i)
    router.note_first_token(b, 0.1)
    router.note_token(b, 0.2)
    router.note_token(b, 10.2)  # 10s stall >> 5x mean
    rep = router.slo_report()
    assert rep.attainment == pytest.approx(0.5)


def test_handoff_three_steps_and_gap_detection():
    router = Router()
    rid = router.submit(16, 4, now=0.0)
    router.note_first_token(rid, 0.1)
    router.begin_handoff(rid, src=0, dst=1, tokens_frozen=1, now=0.1)
    assert router.pinned(rid) and not router.in_transit(rid)  # step 1: frozen
    router.mark_migrating(rid)
    assert router.in_transit(rid)  # step 2: pages on the wire
    assert router.complete_handoff(rid, tokens_resumed=1, now=0.2)
    assert not router.in_transit(rid) and not router.pinned(rid)  # step 3
    assert router.handoff_report() == (1, 0)
    # a mismatched resume position is a dropped/replayed token
    rid2 = router.submit(16, 4, now=0.3)
    router.begin_handoff(rid2, src=0, dst=1, tokens_frozen=1, now=0.4)
    router.mark_migrating(rid2)
    assert not router.complete_handoff(rid2, tokens_resumed=0, now=0.5)
    assert router.handoff_report() == (2, 1)


def test_dispatch_never_hands_out_pinned_requests():
    router = Router()
    pinned = router.submit(16, 4, now=0.0)
    free = router.submit(16, 4, now=0.1)
    router.begin_handoff(pinned, src=0, dst=1, tokens_frozen=1, now=0.2)
    eng = _engine()
    dispatched = router.dispatch([eng])
    assert [rec.rid for rec, _ in dispatched] == [free]
    assert [r.rid for r in router.queue] == [pinned]  # still queued, untouched


def test_paged_cache_matches_contiguous():
    cache = PagedKVCache(n_blocks=16, block_size=4, n_kv=2, head_dim=8, dtype=np.float32)
    rng = np.random.default_rng(1)
    k = rng.standard_normal((11, 2, 8)).astype(np.float32)
    v = rng.standard_normal((11, 2, 8)).astype(np.float32)
    cache.allocate(0)
    cache.append(0, k[:6], v[:6])
    cache.append(0, k[6:], v[6:])
    kg, vg, length = cache.gather(0, max_seq=16)
    assert length == 11
    np.testing.assert_array_equal(kg[:11], k)
    np.testing.assert_array_equal(vg[:11], v)
    np.testing.assert_array_equal(kg[11:], 0)
    free_before = cache.n_free_blocks
    cache.release(0)
    assert cache.n_free_blocks == free_before + 3  # ceil(11/4) blocks back


def test_paged_cache_oom():
    cache = PagedKVCache(n_blocks=2, block_size=2, n_kv=1, head_dim=4, dtype=np.float32)
    cache.allocate(0)
    with pytest.raises(MemoryError):
        cache.append(0, np.zeros((5, 1, 4), np.float32), np.zeros((5, 1, 4), np.float32))
