"""Scale-operation critical-path attribution (repro.obs.critical_path).

The load-bearing properties:

  * every closed ``scale_op`` span's makespan is partitioned into
    plan/queue/transfer/stall/cutover with >= 95% coverage (the CI-gated
    acceptance mirror of the TTFT-attribution gate);
  * conservation is EXACT, not within-epsilon: the rational-arithmetic
    segment sums telescope to the span window bit-for-bit, for every op,
    across seeds (hypothesis property when available + a deterministic
    seed sweep that always runs);
  * the float view is self-consistent: ``sum(breakdown().values()) ==
    attributed_s`` exactly (fixed summation order);
  * the formatted report for the smoke scenario is golden-pinned
    (``REGEN_GOLDEN=1`` to accept deliberate changes);
  * bottleneck hops are classified latency/contention/bandwidth from the
    span attrs the NetEventBridge stamps.
"""

import os
import pathlib
from fractions import Fraction

import pytest

from repro.obs.critical_path import (
    SCALE_SEGMENTS,
    analyze_scale_ops,
    format_scale_report,
    summarize_scale_ops,
)
from repro.obs.report import run_traced_sim
from repro.obs.trace import Tracer

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


@pytest.fixture(scope="module")
def traced_run():
    return run_traced_sim(duration=10.0, rate=4.0, seed=0)


@pytest.fixture(scope="module")
def reports(traced_run):
    tracer, _ = traced_run
    return analyze_scale_ops(list(tracer.spans))


# ---------------------------------------------------------------------------
# the acceptance headline: >= 95% of every scale op's makespan attributed
# ---------------------------------------------------------------------------


def test_every_scale_op_is_attributed(reports):
    assert reports, "smoke scenario produced no scale ops"
    for r in reports:
        assert set(r.segments_exact) == set(SCALE_SEGMENTS)
        assert all(v >= 0 for v in r.segments_exact.values())
        assert r.coverage >= 0.95, (
            f"op {r.sid}: only {r.coverage:.1%} of makespan attributed "
            f"({r.breakdown()})"
        )


def test_conservation_is_exact_not_approximate(reports):
    """Rational arithmetic: segments telescope to the window bit-for-bit."""
    for r in reports:
        assert r.conserved()
        total = sum((r.segments_exact[s] for s in SCALE_SEGMENTS), Fraction(0))
        assert total == Fraction(r.t1) - Fraction(r.t0)


def test_float_view_matches_exact_sum(reports):
    """breakdown() and attributed_s sum the same floats in the same fixed
    order, so equality is exact — no tolerance."""
    for r in reports:
        assert sum(r.breakdown().values()) == r.attributed_s
        assert list(r.breakdown()) == list(SCALE_SEGMENTS)


def test_network_ops_show_transfer_and_cutover(reports):
    net_ops = [r for r in reports if r.n_flows > 0]
    assert net_ops, "no scale op had pinned parameter flows"
    for r in net_ops:
        b = r.breakdown()
        assert b["transfer"] > 0.0
        assert r.bottleneck is not None
        assert r.bottleneck.cause in ("latency", "contention", "bandwidth")
        assert r.bottleneck.duration > 0.0


def test_simple_plane_ops_carve_control_tail():
    """Flowless data planes (SSD) still partition: the recorded control
    window is cutover, the rest of the load is transfer."""
    tracer, _ = run_traced_sim(system="ssd", duration=10.0, rate=4.0, seed=0)
    reports = analyze_scale_ops(list(tracer.spans))
    assert reports
    for r in reports:
        assert r.n_flows == 0
        assert r.conserved() and r.coverage >= 0.95
        b = r.breakdown()
        assert b["transfer"] > 0.0
        assert abs(b["cutover"] - 0.05) < 1e-9  # control_plane_s default


# ---------------------------------------------------------------------------
# cross-seed conservation (always runs; hypothesis widens it when present)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_conservation_across_seeds(seed):
    # some seeds never trip the autoscaler at this rate — an empty report
    # list is fine (seed 0's non-emptiness is pinned by the fixtures above);
    # what must hold for EVERY op that does exist is exact conservation
    tracer, _ = run_traced_sim(duration=8.0, rate=3.0, seed=seed)
    for r in analyze_scale_ops(list(tracer.spans)):
        assert r.conserved(), f"seed {seed} op {r.sid} not conserved"
        assert r.coverage >= 0.95


# ---------------------------------------------------------------------------
# synthetic span trees: classification + partition edge cases
# ---------------------------------------------------------------------------


def _synthetic_op(tracer, t0, t1, flow_windows, *, control_s=0.0, lat=None):
    """One scale_op with hop flows at the given (a, b, size) windows."""
    op = tracer.begin("scale_op", t0, cat="scale", track="scale",
                      phase="prefill", plane="network_multicast",
                      control_s=control_s)
    for i, (a, b, size) in enumerate(flow_windows):
        kw = dict(cat="network", parent=op, kind="multicast_hop",
                  src=0, dst=i + 1, size=size, tag=f"chain0.hop{i}",
                  chain=0, hop=i)
        if lat is not None and i == len(flow_windows) - 1:
            kw["lat"] = lat
        tracer.span(f"flow:multicast_hop", a, b, **kw)
    tracer.end(op, t1)
    return op


def test_synthetic_partition_labels_every_segment():
    tr = Tracer()
    # plan instant at 1.0, first flow 2.0-4.0, gap, second flow 5.0-6.0,
    # op closes 7.0 with a 0.5 control window -> every segment non-zero
    op = tr.begin("scale_op", 0.0, cat="scale", phase="prefill",
                  plane="network_multicast", control_s=0.5)
    tr.instant("plan", 1.0, cat="scale", parent=op)
    tr.span("flow:multicast_hop", 2.0, 4.0, cat="network", parent=op,
            kind="multicast_hop", src=0, dst=1, size=1e9, tag="chain0.hop0")
    tr.span("flow:multicast_hop", 5.0, 6.0, cat="network", parent=op,
            kind="multicast_hop", src=1, dst=2, size=1e9, tag="chain0.hop1")
    tr.end(op, 7.0)
    (r,) = analyze_scale_ops(tr.spans)
    b = r.breakdown()
    assert b["plan"] == 1.0      # [0, plan]
    assert b["queue"] == 1.0     # [plan, first flow]
    assert b["transfer"] == 3.0  # the two flow windows
    assert b["stall"] == 1.5     # [4, 5] inter-hop gap + [6, 6.5] pre-control
    assert b["cutover"] == 0.5   # the recorded control window
    assert r.conserved() and r.coverage == 1.0


def test_bottleneck_latency_classification():
    tr = Tracer()
    # the long hop's duration is mostly store-and-forward prefix
    _synthetic_op(tr, 0.0, 3.0,
                  [(0.0, 1.0, 1e9), (0.0, 2.5, 1e9)], lat=2.0)
    (r,) = analyze_scale_ops(tr.spans)
    assert r.bottleneck.cause == "latency"
    assert r.bottleneck.latency_s == 2.0


def test_bottleneck_contention_classification():
    tr = Tracer()
    # same latency-free hops, same size: the slow one runs at 1/5 the best
    # sibling rate -> its share was squeezed by competing traffic
    _synthetic_op(tr, 0.0, 6.0, [(0.0, 1.0, 1e9), (0.0, 5.0, 1e9)])
    (r,) = analyze_scale_ops(tr.spans)
    assert r.bottleneck.cause == "contention"


def test_bottleneck_bandwidth_classification():
    tr = Tracer()
    # both hops at the same rate: the worst hop is simply link-rate bound
    _synthetic_op(tr, 0.0, 2.2, [(0.0, 1.0, 1e9), (1.0, 2.0, 1e9)])
    (r,) = analyze_scale_ops(tr.spans)
    assert r.bottleneck.cause == "bandwidth"


# ---------------------------------------------------------------------------
# golden report + CLI gate
# ---------------------------------------------------------------------------


def test_scale_report_matches_golden(reports):
    got = format_scale_report(reports)
    path = GOLDEN_DIR / "critical_path.txt"
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(got + "\n")
    want = path.read_text().rstrip("\n")
    assert got == want, (
        "critical-path report drifted from golden (REGEN_GOLDEN=1 to accept)"
    )


def test_summary_shape(reports):
    s = summarize_scale_ops(reports)
    assert s["n_ops"] == len(reports) > 0
    assert 0.95 <= s["min_coverage"] <= 1.0
    assert set(s["segment_totals_s"]) == set(SCALE_SEGMENTS)
    assert abs(sum(s["segment_shares"].values()) - 1.0) < 1e-9
    assert all(c in ("latency", "contention", "bandwidth")
               for c in s["bottleneck_causes"])


def test_report_cli_scale_ops_gate():
    from repro.obs import report as report_mod

    summary = report_mod.main(
        ["--sim", "--duration", "8", "--rate", "3", "--scale-ops",
         "--min-makespan-attribution", "0.95"]
    )
    assert summary["n_ops"] > 0


def test_analysis_roundtrips_through_chrome_export(traced_run):
    """Coverage survives export -> load (report CLI's on-disk path)."""
    from repro.obs.export import chrome_trace, load_chrome

    tracer, _ = traced_run
    loaded = load_chrome(chrome_trace(list(tracer.spans)))
    direct = analyze_scale_ops(list(tracer.spans))
    again = analyze_scale_ops(loaded)
    assert [r.sid for r in again] == [r.sid for r in direct]
    for a, d in zip(again, direct):
        assert a.coverage >= 0.95
        assert a.n_flows == d.n_flows


# ---------------------------------------------------------------------------
# property tests (hypothesis optional, like the rest of the repo)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=100, deadline=None)
    @given(
        st.floats(min_value=0.0, max_value=100.0),
        st.floats(min_value=1e-6, max_value=50.0),
        st.lists(
            st.tuples(st.floats(min_value=0.0, max_value=1.0),
                      st.floats(min_value=0.0, max_value=1.0)),
            max_size=12,
        ),
        st.floats(min_value=0.0, max_value=1.0),
    )
    def test_segments_sum_to_window_bit_for_bit(t0, width, rel_flows, ctl):
        """Arbitrary flow windows (overlapping, clipped, degenerate) inside
        an arbitrary scale_op window: the rational segment sums ALWAYS
        telescope to Fraction(t1) - Fraction(t0), exactly."""
        t1 = t0 + width
        tr = Tracer()
        op = tr.begin("scale_op", t0, cat="scale", phase="prefill",
                      plane="network_multicast", control_s=ctl * width)
        for i, (a, b) in enumerate(rel_flows):
            fa, fb = t0 + a * width, t0 + b * width
            if fb < fa:
                fa, fb = fb, fa
            tr.span("flow:multicast_hop", fa, fb, cat="network", parent=op,
                    kind="multicast_hop", src=0, dst=i + 1, size=1e9,
                    tag=f"chain0.hop{i}")
        tr.end(op, t1)
        (r,) = analyze_scale_ops(tr.spans)
        total = sum((r.segments_exact[s] for s in SCALE_SEGMENTS),
                    Fraction(0))
        assert total == Fraction(r.t1) - Fraction(r.t0)
        assert all(v >= 0 for v in r.segments_exact.values())
