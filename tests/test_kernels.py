"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import fused_rmsnorm

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,d",
    [
        (2, 64, 64, 4, 2, 32),   # GQA 2:1
        (1, 100, 100, 8, 8, 64),  # MHA, non-multiple seq
        (2, 128, 256, 4, 1, 16),  # MQA, cross lengths
        (1, 48, 32, 6, 3, 128),   # uneven blocks, mxu-width head
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(b, sq, sk, h, kv, d, causal, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, kv, d), dtype)
    v = jax.random.normal(k3, (b, sk, kv, d), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,d",
    [(2, 128, 8, 2, 32), (3, 100, 4, 4, 64), (1, 256, 16, 8, 16), (2, 96, 8, 1, 128)],
)
def test_decode_attention_matches_oracle(b, s, h, kv, d, dtype):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (b, h, d), dtype)
    kc = jax.random.normal(k2, (b, kv, s, d), dtype)
    vc = jax.random.normal(k3, (b, kv, s, d), dtype)
    lengths = jax.random.randint(k4, (b,), 1, s + 1)
    got = decode_attention(q, kc, vc, lengths, block_s=32, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_decode_attention_respects_lengths():
    """Tokens beyond `lengths` must not affect the output."""
    b, s, h, kv, d = 2, 64, 4, 2, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, d))
    kc = jax.random.normal(k2, (b, kv, s, d))
    vc = jax.random.normal(k3, (b, kv, s, d))
    lengths = jnp.array([10, 20])
    out1 = decode_attention(q, kc, vc, lengths, block_s=16, interpret=True)
    # scramble the invalid region
    mask = jnp.arange(s)[None, None, :, None] >= lengths[:, None, None, None]
    kc2 = jnp.where(mask, 99.0, kc)
    vc2 = jnp.where(mask, -99.0, vc)
    out2 = decode_attention(q, kc2, vc2, lengths, block_s=16, interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 7, 64), (130, 256), (1, 32), (3, 5, 7, 16)])
def test_rmsnorm_matches_oracle(shape, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, (shape[-1],), dtype)
    got = fused_rmsnorm(x, w, block_n=16, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops

    b, s, h, kv, d = 1, 16, 4, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(KEY, (b, s, kv, d))
    v = jax.random.normal(KEY, (b, s, kv, d))
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
