"""Loop-aware HLO analyzer: trip counts, dot flops, collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, tokenize

X = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_scan_trip_count_exact():
    def scanned(x, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]

    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(X, ws).compile()
    rep = analyze(c.as_text())
    assert rep.dot_flops == pytest.approx(2 * 128**3 * 10)
    # XLA's own cost_analysis counts the body once — our whole reason to exist
    # (older jax returns a one-element list of dicts)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < rep.dot_flops / 5


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(x, w):
            def inner(x, _):
                return x @ w, None
            return jax.lax.scan(inner, x, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(nested).lower(X, ws).compile()
    assert analyze(c.as_text()).dot_flops == pytest.approx(2 * 128**3 * 30)


def test_single_dot_flops_and_bytes():
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    c = jax.jit(lambda x, w: x @ w).lower(X, w).compile()
    rep = analyze(c.as_text())
    assert rep.dot_flops == pytest.approx(2 * 128 * 128 * 256)
    io = (128 * 128 + 128 * 256 + 128 * 256) * 4
    assert rep.hbm_bytes == pytest.approx(io, rel=0.3)


def test_bf16_convert_not_counted():
    """CPU legalizes bf16 dots via f32 converts; the proxy must count bf16."""
    xb = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    wb = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    c = jax.jit(lambda x, w: x @ w).lower(xb, wb).compile()
    rep = analyze(c.as_text())
    bf16_io = (128 * 128) * 3 * 2
    # within 2x of pure-bf16 IO (the f32 result write may remain)
    assert rep.hbm_bytes <= bf16_io * 2.5


def test_collectives_counted_with_trip_multiplier():
    import subprocess, sys, os, textwrap

    code = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ('data', 'model'))

        def f(x, ws):
            def body(x, w):
                y = x @ w
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P('data', None))), None
            return jax.lax.scan(body, x, ws)[0]

        xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P('data', None)),
                                     NamedSharding(mesh, P(None, None, 'model')))).lower(xs, ws).compile()
        rep = analyze(c.as_text())
        # one activation all-gather per scan iteration over the model axis
        total = sum(rep.coll_count.values())
        assert total >= 5, rep.coll_count
        print('ok', rep.coll_count)
    """ % os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]


def test_tokenizer_finds_entry():
    c = jax.jit(lambda x: x * 2).lower(X).compile()
    comps, entry = tokenize(c.as_text())
    assert entry is not None and entry in comps
