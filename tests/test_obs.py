"""Observability subsystem: metric registry, span tracer, exporters, report.

The load-bearing properties:

  * a seeded, traced simulator run exports a byte-identical Chrome trace
    every time (golden-pinned, like the flow-event log);
  * enabling the tracer changes NOTHING about the simulation itself — the
    flow-event stream is bit-for-bit the untraced one;
  * every request's TTFT is fully attributed to named spans
    (load_wait/queue/prefill partition the window exactly);
  * span trees are well-formed: every span closed, children inside their
    parent's interval;
  * the stats dataclasses (RuntimeStats/FleetStats/TenantStats) share the
    StatBlock surface and mirror into a bound MetricRegistry.

Regenerate the chrome golden with ``REGEN_GOLDEN=1 pytest tests/test_obs.py``.
"""

import json
import os
import pathlib

import pytest

from repro.net import FlowEventLog
from repro.net.events import FLOW_COMPLETED, FLOW_STARTED, NetEvent
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_S,
    MetricRegistry,
    NULL_TRACER,
    NullTracer,
    StatBlock,
    Tracer,
    chrome_trace,
    load_chrome,
    text_trace,
)
from repro.obs.report import attribute_requests, run_traced_sim, summarize

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    reg = MetricRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2.5)
    reg.gauge("g").set(7)
    h = reg.histogram("lat")
    for v in (0.0005, 0.003, 0.003, 2.0, 1e9):
        h.observe(v)
    assert reg.counter("a").value == 3.5
    assert reg.gauge("g").value == 7.0
    assert h.count == 5 and h.counts[-1] == 1  # 1e9 -> overflow bucket
    assert h.counts[0] == 1  # 0.0005 <= first bound
    assert abs(h.mean - (0.0005 + 0.003 + 0.003 + 2.0 + 1e9) / 5) < 1e-6

    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3.5}
    assert snap["gauges"] == {"g": 7.0}
    assert snap["histograms"]["lat"]["count"] == 5
    flat = reg.flat()
    assert flat["a"] == 3.5 and flat["lat.count"] == 5.0
    json.dumps(snap)  # must be JSON-serializable as-is

    reg.snap(1.5)
    reg.snap(2.5)
    assert [t for t, _ in reg.series] == [1.5, 2.5]


def test_histogram_boundary_values_stay_in_their_bucket():
    """Regression: an observation exactly equal to bounds[i] belongs in
    bucket i ("at or below bounds[i]") — bisect_right pushed every boundary
    value one bucket too high, so e.g. observe(bounds[0]) landed in bucket 1
    and a 1.0 s observation against a 1.0 s bucket read as over it."""
    from repro.obs.metrics import Histogram

    bounds = (0.5, 1.0, 5.0)
    h = Histogram("h", bounds)
    for b in bounds:
        h.observe(b)
    assert h.counts == [1, 1, 1, 0]
    # strictly-above goes one bucket up; strictly-below stays down
    h2 = Histogram("h2", bounds)
    h2.observe(0.4999)
    h2.observe(0.5001)
    h2.observe(5.0001)
    assert h2.counts == [1, 1, 0, 1]


def test_registry_series_ring_buffer():
    reg = MetricRegistry(series_maxlen=2)
    for t in (1.0, 2.0, 3.0, 4.0):
        reg.snap(t)
    assert [t for t, _ in reg.series] == [3.0, 4.0]  # newest retained
    assert reg.series_dropped == 2
    # unbounded default: nothing dropped
    free = MetricRegistry()
    for t in (1.0, 2.0, 3.0):
        free.snap(t)
    assert len(free.series) == 3 and free.series_dropped == 0
    assert free.series_maxlen is None


def test_registry_cells_are_get_or_create():
    reg = MetricRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.histogram("h") is reg.histogram("h")
    assert reg.histogram("h").bounds == tuple(sorted(DEFAULT_LATENCY_BUCKETS_S))


def test_statblock_unifies_stats_dataclasses():
    from repro.serving.disagg.runtime import RuntimeStats
    from repro.serving.maas.fleet import FleetStats
    from repro.serving.maas.tenant import TenantStats

    for cls in (RuntimeStats, FleetStats, TenantStats):
        assert issubclass(cls, StatBlock)
        d = cls().as_dict()
        assert d and all(isinstance(v, (int, float)) for v in d.values())

    reg = MetricRegistry()
    st = RuntimeStats().bind(reg, "runtime.m")
    st.migrations += 3
    st.migrated_bytes += 1024
    assert reg.counter("runtime.m.migrations").value == 3.0
    assert reg.counter("runtime.m.migrated_bytes").value == 1024.0
    # unbound blocks stay plain dataclasses
    plain = RuntimeStats()
    plain.migrations += 1
    assert plain.as_dict()["migrations"] == 1


# ---------------------------------------------------------------------------
# flow event log ring buffer
# ---------------------------------------------------------------------------


def _mk_event(kind, t):
    return NetEvent(kind=kind, t=t)


def test_flow_event_log_ring_buffer():
    log = FlowEventLog(maxlen=3)
    for i in range(5):
        log(_mk_event(FLOW_STARTED, float(i)))
    assert len(log) == 3 and log.dropped == 2
    assert [e.t for e in log.events] == [2.0, 3.0, 4.0]  # newest retained
    # unbounded default: nothing dropped
    full = FlowEventLog()
    for i in range(5):
        full(_mk_event(FLOW_STARTED, float(i)))
    assert len(full) == 5 and full.dropped == 0 and full.maxlen is None


def test_flow_event_log_iter_kinds():
    log = FlowEventLog()
    log(_mk_event(FLOW_STARTED, 0.0))
    log(_mk_event(FLOW_COMPLETED, 1.0))
    log(_mk_event(FLOW_STARTED, 2.0))
    assert [e.t for e in log.iter_kinds(FLOW_STARTED)] == [0.0, 2.0]
    assert [e.t for e in log.iter_kinds(FLOW_COMPLETED)] == [1.0]
    assert list(log.iter_kinds("nope")) == []


# ---------------------------------------------------------------------------
# tracer primitives
# ---------------------------------------------------------------------------


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    s = NULL_TRACER.begin("x", 1.0, cat="c")
    NULL_TRACER.end(s, 2.0)
    assert NULL_TRACER.instant("y", 1.0).sid == -1
    assert NULL_TRACER.close_open(5.0) == 0
    assert isinstance(NULL_TRACER, NullTracer)


def test_tracer_spans_and_parenting():
    tr = Tracer()
    root = tr.begin("root", 1.0, cat="r", track="lane")
    child = tr.begin("kid", 2.0, parent=root)
    assert child.parent == root.sid
    assert child.track == "lane"  # inherited from parent
    tr.end(child, 3.0)
    tr.end(root, 4.0)
    tr.end(root, 9.0)  # re-close is a no-op
    assert root.t1 == 4.0
    inst = tr.instant("mark", 5.0)
    assert inst.t0 == inst.t1 == 5.0
    closed = tr.span("late", 6.0, 7.0, cat="x")
    assert closed.duration == 1.0
    assert [s.sid for s in tr.spans] == [0, 1, 2, 3]  # emission-ordered ids
    assert tr.by_name("kid") == [child]
    assert tr.children_of(root) == [child]


def test_close_open_sweeps_dangling_spans():
    tr = Tracer()
    tr.begin("a", 0.0)
    b = tr.begin("b", 1.0)
    tr.end(b, 2.0)
    assert len(tr.open_spans()) == 1
    assert tr.close_open(5.0) == 1
    assert tr.open_spans() == [] and tr.spans[0].t1 == 5.0


def test_end_clamps_backwards_time():
    tr = Tracer()
    s = tr.begin("s", 10.0)
    tr.end(s, 9.0)
    assert s.t1 == 10.0


# ---------------------------------------------------------------------------
# traced simulator run: determinism, neutrality, well-formedness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_run():
    tracer, result = run_traced_sim(duration=10.0, rate=4.0, seed=0)
    return tracer, result


def test_traced_run_spans_are_well_formed(traced_run):
    tracer, _ = traced_run
    spans = tracer.spans
    assert spans, "traced run produced no spans"
    by_sid = {s.sid: s for s in spans}
    assert len(by_sid) == len(spans)  # unique ids
    for s in spans:
        assert s.closed, f"span {s.sid} ({s.name}) left open"
        assert s.t1 >= s.t0
        if s.parent is not None:
            p = by_sid[s.parent]
            assert p.t0 - 1e-9 <= s.t0 and s.t1 <= p.t1 + 1e-9, (
                f"span {s.sid} ({s.name}) escapes parent {p.sid} ({p.name})"
            )
    # the instrumented layers all show up
    names = {s.name for s in spans}
    assert {"request", "prefill", "decode", "scale_op", "plan",
            "layer_arrival", "serving"} <= names
    assert any(s.name.startswith("flow:") or s.name == "kv_transfer"
               for s in spans)


def test_chrome_trace_is_byte_deterministic(traced_run):
    tracer, _ = traced_run
    again, _ = run_traced_sim(duration=10.0, rate=4.0, seed=0)
    a = chrome_trace(list(tracer.spans))
    b = chrome_trace(list(again.spans))
    assert a == b
    assert text_trace(list(tracer.spans)) == text_trace(list(again.spans))


def test_chrome_trace_matches_golden(traced_run):
    tracer, _ = traced_run
    got = chrome_trace(list(tracer.spans))
    path = GOLDEN_DIR / "chrome_trace.json"
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(got + "\n")
    want = path.read_text().rstrip("\n")
    assert got == want, "chrome trace drifted from golden (REGEN_GOLDEN=1 to accept)"


def test_tracing_does_not_change_the_simulation():
    import repro.core.simulator as sim
    from repro.serving import traces

    def lines(tracer):
        s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=0,
                          tracer=tracer)
        log = FlowEventLog()
        s.flowsim.subscribe(log)
        res = s.run(traces.burstgpt(duration=10.0, base_rate=4.0, seed=7))
        return log.lines(), res.p99_ttft()

    (off_lines, off_p99) = lines(None)
    (on_lines, on_p99) = lines(Tracer())
    assert off_lines == on_lines
    assert off_p99 == on_p99


def test_default_simulator_has_null_tracer():
    import repro.core.simulator as sim

    s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=0)
    assert s.tracer is NULL_TRACER
    assert s._bridge is None  # no subscriber registered when disabled


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_roundtrip(traced_run):
    tracer, _ = traced_run
    spans = list(tracer.spans)
    loaded = load_chrome(chrome_trace(spans))
    assert len(loaded) == len(spans)
    by_sid = {s.sid: s for s in loaded}
    for s in spans:
        l = by_sid[s.sid]
        assert l.name == s.name and l.cat == (s.cat or "default")
        assert l.parent == s.parent
        assert abs(l.t0 - s.t0) < 1e-6 and abs((l.t1 or l.t0) - s.t1) < 1e-6
    # attribution computed from the exported file matches in-process
    assert len(attribute_requests(loaded)) == len(attribute_requests(spans))


def test_chrome_trace_is_valid_perfetto_shape(traced_run):
    tracer, _ = traced_run
    doc = json.loads(chrome_trace(list(tracer.spans)))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert all(e["ph"] in ("M", "X", "i") for e in evs)
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] > 0 and e["ts"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"


# ---------------------------------------------------------------------------
# TTFT attribution (the acceptance headline)
# ---------------------------------------------------------------------------


def test_ttft_fully_attributed_for_every_request(traced_run):
    tracer, result = traced_run
    reqs = attribute_requests(list(tracer.spans))
    finished = [r for r in result.requests if r.ttft is not None]
    assert len(reqs) == len(finished) > 0
    for r in reqs:
        assert r.frac >= 0.95, (
            f"rid {r.rid}: only {r.frac:.1%} of TTFT attributed "
            f"({r.by_cause})"
        )


def test_attribution_summary_shape(traced_run):
    tracer, _ = traced_run
    summary = summarize(attribute_requests(list(tracer.spans)))
    assert summary["n_requests"] > 0
    assert summary["ttft_p99_s"] >= summary["ttft_p50_s"] > 0
    assert summary["min_attribution_frac"] >= 0.95
    assert set(summary["tail_by_cause_s"]) == {"queue", "load", "compute"}
    assert summary["tail_dominant_cause"] in ("queue", "load", "compute")
    shares = summary["tail_share_by_cause"]
    assert abs(sum(shares.values()) - 1.0) < 1e-6


def test_report_cli_gate():
    from repro.obs import report as report_mod

    summary = report_mod.main(
        ["--sim", "--duration", "8", "--rate", "3",
         "--min-attribution", "0.95"]
    )
    assert summary["n_requests"] > 0


# ---------------------------------------------------------------------------
# migration + runtime instrumentation
# ---------------------------------------------------------------------------


def test_kv_migration_channel_emits_spans():
    from repro.core import topology as tp
    from repro.net import FlowSim
    from repro.serving.disagg.kv_migration import KVMigrationChannel, MigrationPayload

    topo = tp.make_cluster(2, 4)
    net = FlowSim(topo)
    tr = Tracer()
    ch = KVMigrationChannel(net=net, tracer=tr)
    p = MigrationPayload(
        rid=1, request=None, first_token=0, cache_one=None, prompt_len=8,
        total_bytes=10**9, n_pages=1, src_dev=0, dst_dev=4,
        tokens_at_freeze=[0],
    )
    ch.start(p, 0.0)
    net.advance_to(10.0)
    assert ch.poll(10.0) == [p]
    (span,) = tr.by_name("kv_migration")
    assert span.cat == "migration" and span.closed
    assert span.attrs["rid"] == 1 and span.duration > 0


# ---------------------------------------------------------------------------
# property tests (hypothesis optional, like the rest of the repo)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=0.0, max_value=100.0), max_size=60))
    def test_histogram_counts_partition_observations(values):
        from repro.obs.metrics import Histogram

        h = Histogram("h", (0.5, 1.0, 5.0, 20.0))
        for v in values:
            h.observe(v)
        assert sum(h.counts) == h.count == len(values)
        assert abs(h.total - sum(values)) < 1e-6

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.floats(min_value=0.0, max_value=10.0),
                              st.floats(min_value=0.0, max_value=10.0)),
                    min_size=1, max_size=30))
    def test_span_trees_always_close_and_nest(intervals):
        """Arbitrary nested begin/end sequences: after close_open, every
        span is closed and children lie inside their parents."""
        tr = Tracer()
        stack = []
        t = 0.0
        for a, b in intervals:
            t += a
            parent = stack[-1] if stack else None
            stack.append(tr.begin("s", t, parent=parent))
            if b < 5.0 and stack:  # sometimes close the innermost
                t += b
                tr.end(stack.pop(), t)
        tr.close_open(t + 1.0)
        by_sid = {s.sid: s for s in tr.spans}
        for s in tr.spans:
            assert s.closed
            if s.parent is not None:
                p = by_sid[s.parent]
                assert p.t0 <= s.t0 and s.t1 <= p.t1
