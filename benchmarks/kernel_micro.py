"""Kernel microbenchmarks: Pallas (interpret) correctness + analytic
VMEM/roofline characteristics per BlockSpec configuration.

On this CPU container wall-clock numbers reflect the interpreter, not the
MXU; the meaningful outputs are (a) max |err| vs the oracle per shape and
(b) the analytic VMEM working set + arithmetic intensity per block config,
which determine TPU performance."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import markdown_table, smoke, write_csv
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import fused_rmsnorm


def flash_rows():
    rows = []
    key = jax.random.PRNGKey(0)
    cases = [
        ((1, 256, 4, 2, 64), (64, 128)),
        ((1, 256, 4, 2, 64), (128, 256)),
        ((2, 128, 8, 8, 128), (64, 64)),
    ]
    for (b, s, h, kv, d), (bq, bk) in (cases[:1] if smoke() else cases):
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(key, (b, s, kv, d), jnp.float32)
        v = jax.random.normal(key, (b, s, kv, d), jnp.float32)
        t0 = time.perf_counter()
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v, causal=True))))
        vmem = (bq * d + 2 * bk * d) * 2 + bq * bk * 4 + bq * d * 4  # bytes
        flops = 4.0 * s * s * h * d / 2  # causal half
        rows.append([f"{b}x{s}x{h}x{d}", f"{bq}/{bk}", round(vmem / 1024, 1),
                     f"{err:.2e}", round(dt * 1e3, 1), f"{flops/1e6:.1f}M"])
    return rows


def decode_rows():
    rows = []
    key = jax.random.PRNGKey(1)
    dec_cases = [((4, 1024, 8, 2, 64), 256), ((4, 1024, 8, 2, 64), 512)]
    for (b, s, h, kv, d), bs in (dec_cases[:1] if smoke() else dec_cases):
        q = jax.random.normal(key, (b, h, d), jnp.float32)
        kc = jax.random.normal(key, (b, kv, s, d), jnp.float32)
        vc = jax.random.normal(key, (b, kv, s, d), jnp.float32)
        lengths = jnp.full((b,), s, jnp.int32)
        t0 = time.perf_counter()
        out = decode_attention(q, kc, vc, lengths, block_s=bs, interpret=True)
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref.decode_attention_ref(q, kc, vc, lengths))))
        vmem = 2 * bs * d * 2 + (h // kv) * bs * 4
        ai = (2.0 * h * d) / (2 * d * 2)  # flops per cache byte ~ n_rep/1
        rows.append([f"{b}x{s}x{h}x{d}", bs, round(vmem / 1024, 1),
                     f"{err:.2e}", round(dt * 1e3, 1), round(ai, 2)])
    return rows


def rmsnorm_rows():
    rows = []
    key = jax.random.PRNGKey(2)
    rn_cases = [((512, 1024), 128), ((512, 1024), 256)]
    for shape, bn in (rn_cases[:1] if smoke() else rn_cases):
        x = jax.random.normal(key, shape, jnp.float32)
        w = jax.random.normal(key, (shape[-1],), jnp.float32)
        t0 = time.perf_counter()
        out = fused_rmsnorm(x, w, block_n=bn, interpret=True)
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref.rmsnorm_ref(x, w))))
        rows.append([f"{shape}", bn, f"{err:.2e}", round(dt * 1e3, 1)])
    return rows


def main():
    fr = flash_rows()
    write_csv("kernel_flash.csv",
              ["shape", "block_q/k", "vmem_kib", "max_err", "interp_ms", "flops"], fr)
    print(markdown_table(["flash shape", "blocks", "VMEM KiB", "max|err|", "ms", "flops"], fr))
    dr = decode_rows()
    write_csv("kernel_decode.csv",
              ["shape", "block_s", "vmem_kib", "max_err", "interp_ms", "arith_int"], dr)
    print(markdown_table(["decode shape", "block_s", "VMEM KiB", "max|err|", "ms", "AI"], dr))
    rr = rmsnorm_rows()
    write_csv("kernel_rmsnorm.csv", ["shape", "block_n", "max_err", "interp_ms"], rr)
    print(markdown_table(["rmsnorm shape", "block_n", "max|err|", "ms"], rr))
    assert all(float(r[3]) < 3e-5 for r in fr)
    assert all(float(r[3]) < 3e-5 for r in dr)
    assert all(float(r[2]) < 1e-5 for r in rr)


if __name__ == "__main__":
    main()
