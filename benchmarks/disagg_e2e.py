"""Disaggregated vs colocated serving on the paper's trace shapes.

For each workload shape in ``repro.serving.traces`` (compressed to run on
CPU in seconds), serve the identical request sequence two ways on real
jitted engines:

  * **colocated** — one FCFS router over N monolithic engines; prefill and
    decode interleave on the same instances (the DistServe-motivating
    baseline);
  * **disagg**   — the PD-disaggregated :class:`ClusterRuntime` with KV
    migration, decode pre-scaling and prefill→decode mutation (§5.4).

Reports TTFT / TBT / SLO attainment per system, plus the disagg runtime's
scaling counters (mutations move zero parameter bytes).

    PYTHONPATH=src python benchmarks/disagg_e2e.py --requests 16
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import markdown_table, smoke, write_csv
from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving import traces
from repro.serving.disagg import ClusterRuntime
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.router import Router

PROMPT, GEN = 24, 8
TRACE_SECONDS = 6.0


def _workload(kind: str, n: int, cfg, seed: int):
    """(arrival_time, prompt) pairs following the trace's temporal shape."""
    tr = traces.TRACES[kind](duration=60.0, base_rate=0.6, seed=seed)
    times = sorted(t * TRACE_SECONDS / 60.0 for t, _, _ in tr)[:n]
    rng = np.random.default_rng(seed)
    return [
        (t, rng.integers(0, cfg.vocab_size, size=PROMPT).astype(np.int32))
        for t in times
    ]


def run_colocated(cfg, params, workload, *, n_engines: int, n_slots: int):
    engines = [
        InstanceEngine(cfg, params, n_slots=n_slots, max_seq=PROMPT + GEN + 8)
        for _ in range(n_engines)
    ]
    router = Router()
    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    pending = list(workload)
    sreqs: dict[int, ServeRequest] = {}
    outstanding = len(pending)
    noted: dict[int, int] = {}  # rid -> tokens already accounted

    def account(reqs, now):
        for r in reqs:
            for j in range(noted.get(r.rid, 0), len(r.out_tokens)):
                if j == 0:
                    router.note_first_token(r.rid, now)
                else:
                    router.note_token(r.rid, now)
            noted[r.rid] = len(r.out_tokens)

    for _ in range(100_000):
        if not pending and not outstanding:
            break
        now = clock()
        while pending and pending[0][0] <= now:
            _, prompt = pending.pop(0)
            rid = router.submit(len(prompt), GEN, now)
            sreqs[rid] = ServeRequest(rid, prompt, GEN)
        for rec, eng in router.dispatch(engines):
            eng.submit(sreqs[rec.rid])
        for eng in engines:
            done = eng.step()
            # stamp with the tick-start clock, matching ClusterRuntime.tick's
            # single-`now` accounting — both systems measure at tick
            # granularity, keeping TTFT/TBT comparable
            account(list(eng.active.values()) + done, now)
            for r in done:
                router.note_done(r.rid)
                outstanding -= 1
    else:
        raise RuntimeError(f"tick budget exhausted with {outstanding} outstanding")
    return router.slo_report(), clock()


def run_disagg(cfg, params, workload, *, n_slots: int, model_bytes: int):
    topo = tp.add_host_sources(tp.make_cluster(2, 4, bw_gbps=100.0))
    rt = ClusterRuntime(
        cfg,
        params,
        topo=topo,
        policy=PolicyConfig(max_instances=4, kv_upper=0.5, scale_down_timeout_s=0.5),
        n_prefill=2,
        n_decode=1,
        n_slots=n_slots,
        max_seq=PROMPT + GEN + 8,
        model_bytes=model_bytes,
        prefill_capacity_tps=2000.0,
        decode_capacity_tps=200.0,
    )
    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    pending = list(workload)
    for _ in range(100_000):
        if not pending and rt.n_outstanding == 0:
            break
        now = clock()
        while pending and pending[0][0] <= now:
            _, prompt = pending.pop(0)
            rt.submit(prompt, GEN, now)
        rt.tick(now)
    else:
        raise RuntimeError(f"tick budget exhausted with {rt.n_outstanding} outstanding")
    return rt.router.slo_report(), clock(), rt.stats, rt.router.handoff_report()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=4 if smoke() else 16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    # tolerate orchestrator flags (--only/--smoke) when run via benchmarks.run
    args, _ = ap.parse_known_args()

    cfg = get_config(args.arch, reduced=True)
    params = TF.init_params(jax.random.PRNGKey(args.seed), cfg)
    model_bytes = get_config(args.arch).approx_params() * 2

    header = ["trace", "system", "n", "mean_ttft_ms", "p99_ttft_ms",
              "mean_tbt_ms", "attainment", "wall_s"]
    rows = []
    kinds = list(traces.TRACES)[:1] if smoke() else list(traces.TRACES)
    for kind in kinds:
        workload = _workload(kind, args.requests, cfg, args.seed)
        rep, wall = run_colocated(
            cfg, params, workload, n_engines=3, n_slots=args.n_slots
        )
        rows.append([kind, "colocated", rep.n, f"{rep.mean_ttft*1e3:.0f}",
                     f"{rep.p99_ttft*1e3:.0f}", f"{rep.mean_tbt*1e3:.1f}",
                     f"{rep.attainment:.0%}", f"{wall:.1f}"])
        rep, wall, stats, (handoffs, gapped) = run_disagg(
            cfg, params, workload, n_slots=args.n_slots, model_bytes=model_bytes
        )
        rows.append([kind, "disagg", rep.n, f"{rep.mean_ttft*1e3:.0f}",
                     f"{rep.p99_ttft*1e3:.0f}", f"{rep.mean_tbt*1e3:.1f}",
                     f"{rep.attainment:.0%}", f"{wall:.1f}"])
        print(
            f"[{kind}] disagg: {stats.migrations} migrations, "
            f"{stats.mutations} mutations (0 param bytes), "
            f"{handoffs} handoffs, {gapped} gapped"
        )

    print()
    print(markdown_table(header, rows))
    path = write_csv("disagg_e2e.csv", header, rows)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
