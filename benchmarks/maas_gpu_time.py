"""MaaS fleet sharing vs static per-model allocation — the paper's Fig. 18
claim (~49% less GPU time at equal SLO), applied to a MULTI-model fleet.

Both systems serve the same Zipf-skewed, burst-staggered 3-model trace on
real JAX engines over a 16-device topology:

  * **static** — every model owns a fixed partition sized for its own burst
    peak (DistServe-style over-provisioning, per model).  Devices are held
    for the whole run whether used or not.
  * **maas** — the fleet control plane arbitrates one shared pool: hot
    models grow through it, idle models scale to ZERO devices (O(1) host
    copy only) and cold-start back via multicast when their burst returns.
  * **maas-slo** — same fleet, plus a streaming SLOMonitor whose burn-rate
    status feeds arbitration as a priority tie-break (a paging tenant
    outranks a warning one at equal pressure) — fleet_health() closing the
    loop, compared head-to-head against the pressure-only policy above.

GPU time = device-seconds actually occupied by engines.  SLO attainment is
measured against the same *absolute* TTFT/TBT bounds for both systems
(equal SLO), so the GPU-time gap is the real cost of static allocation.

    PYTHONPATH=src python benchmarks/maas_gpu_time.py
"""

from __future__ import annotations

import os
import sys
import time
from collections import deque

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import markdown_table, smoke, write_csv
from repro.configs import get_config
from repro.core import topology as tp
from repro.core.autoscaler import PolicyConfig
from repro.models import transformer as TF
from repro.serving import traces
from repro.obs import SLOMonitor
from repro.serving.maas import FleetPolicy, FleetScheduler

ARCHS = (
    ["granite-8b", "qwen1.5-4b"] if smoke()
    else ["granite-8b", "qwen1.5-4b", "minicpm3-4b"]
)
PROMPT, GEN = 12, 4
TICK = 0.02  # virtual seconds per fleet tick
DURATION = 8.0 if smoke() else 24.0  # trace horizon (virtual seconds)
MODEL_BYTES = int(2e9)  # ~160 ms modelled multicast per cold start @100 Gbps
TTFT_SLO, TBT_SLO = 0.5, 0.25  # absolute bounds (virtual s) for BOTH systems

# static partition per Zipf rank: sized so each model alone absorbs its own
# burst peak (the per-model over-provisioning MaaS exists to avoid)
STATIC_SIZES = [(3, 2), (2, 1), (1, 1)]


def build_fleet(shared: bool, slo_aware: bool = False):
    topo = tp.add_host_sources(tp.make_cluster(2, 8, bw_gbps=100.0))
    policy = (
        FleetPolicy(idle_to_zero_s=1.0, slo_aware_arbitration=slo_aware)
        if shared
        else FleetPolicy(arbitration=False, scale_to_zero=False)
    )
    monitor = None
    if slo_aware:
        # short burn windows so status reacts within one burst; the SLO
        # bounds are the same absolute ones attainment is judged against
        monitor = SLOMonitor(ttft_slo_s=TTFT_SLO, tbt_slo_s=TBT_SLO,
                             windows_s=(2.0, 10.0))
    fleet = FleetScheduler(topo, policy=policy, slo_monitor=monitor)
    cfgs = {}
    for i, arch in enumerate(ARCHS):
        cfg = get_config(arch, reduced=True)
        cfgs[cfg.name] = cfg
        n_pre, n_dec = (1, 1) if shared else STATIC_SIZES[i]
        t = fleet.add_model(
            cfg,
            TF.init_params(jax.random.PRNGKey(i), cfg),
            n_prefill=n_pre,
            n_decode=n_dec,
            n_slots=4,
            max_seq=PROMPT + GEN + 8,
            model_bytes=MODEL_BYTES,
            prefill_capacity_tps=300.0,
            decode_capacity_tps=60.0,
            policy=PolicyConfig(max_instances=3, kv_upper=0.5, scale_down_timeout_s=0.5),
        )
        if not shared:
            t.runtime.frozen = True  # static: no scaling of any kind
    return fleet, cfgs


def drive(fleet, cfgs, arrivals):
    rng = np.random.default_rng(7)
    pending = deque(arrivals)
    t = 0.0
    while pending or fleet.n_outstanding:
        while pending and pending[0][0] <= t:
            _, model = pending.popleft()
            prompt = rng.integers(0, cfgs[model].vocab_size, size=PROMPT)
            fleet.submit(model, prompt.astype(np.int32), GEN, t)
        fleet.tick(t)
        assert fleet.param_pool.invariant_ok(), "O(1) invariant broken mid-run"
        t += TICK
        if t > 50 * DURATION:
            raise RuntimeError(f"stalled with {fleet.n_outstanding} outstanding")
    return t


def run():
    # arrivals are (t, model-config-name) — same trace for both systems
    names = [get_config(a, reduced=True).name for a in ARCHS]
    mix = traces.multi_model_mix(
        names, duration=DURATION, total_rate=1.0, alpha=1.2, seed=11
    )
    arrivals = [(t, m) for t, m, _, _ in mix]

    rows = []
    stats = {}
    for system in ("static", "maas", "maas-slo"):
        fleet, cfgs = build_fleet(shared=system != "static",
                                  slo_aware=system == "maas-slo")
        wall0 = time.perf_counter()
        t_end = drive(fleet, cfgs, arrivals)
        n = sum(len(x.runtime.completed) for x in fleet.tenants.values())
        rows.append([
            system,
            n,
            round(fleet.stats.gpu_seconds, 1),
            round(fleet.attainment(TTFT_SLO, TBT_SLO), 4),
            fleet.stats.cold_starts,
            fleet.stats.scale_to_zero_events,
            fleet.stats.preemptions,
            round(t_end, 1),
            round(time.perf_counter() - wall0, 1),
        ])
        stats[system] = fleet
    return rows, stats


def main():
    rows, stats = run()
    header = ["system", "served", "gpu_time_s", "slo_attainment", "cold_starts",
              "scale_to_zero", "preemptions", "virtual_s", "wall_s"]
    write_csv("maas_gpu_time.csv", header, rows)
    print(markdown_table(header, rows))
    by = {r[0]: r for r in rows}
    saving = 1.0 - by["maas"][2] / by["static"][2]
    print(f"\nfleet-shared MaaS uses {saving:.0%} less GPU time at equal SLO "
          f"(paper Fig. 18: ~49%)")
    print(f"SLO-aware arbitration vs pressure-only: attainment "
          f"{by['maas-slo'][3]:.4f} vs {by['maas'][3]:.4f}, GPU time "
          f"{by['maas-slo'][2]:.1f}s vs {by['maas'][2]:.1f}s")

    if smoke():
        return rows
    # the SLO tie-break must not cost accuracy or meaningful GPU time
    assert by["maas-slo"][1] == by["maas"][1], "served counts diverged"
    assert by["maas-slo"][3] >= by["maas"][3] - 0.05, (
        by["maas-slo"][3], by["maas"][3])
    # headline: measurably less GPU time ...
    assert by["maas"][2] < 0.85 * by["static"][2], (by["maas"][2], by["static"][2])
    # ... at equal SLO attainment (same absolute bounds for both systems)
    assert by["maas"][3] >= by["static"][3] - 0.05, (by["maas"][3], by["static"][3])
    # the serverless path was actually exercised end-to-end
    assert by["maas"][4] >= 1, "no cold start happened"
    assert by["maas"][5] >= 1, "no model ever scaled to zero"
    assert by["maas"][1] == by["static"][1], "systems served different request counts"
    return rows


if __name__ == "__main__":
    main()
