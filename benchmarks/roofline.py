"""§Roofline table generator: reads the dry-run JSONs and emits the
per-(arch x shape x mesh) three-term roofline analysis (deliverable g).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single]

Terms (seconds/step/chip, TPU v5e):
  compute    = loop-corrected dot FLOPs / 197 TFLOP/s
  memory     = loop-corrected HBM-traffic proxy / 819 GB/s
  collective = collective operand bytes / 50 GB/s per link
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.common import markdown_table, result_path, write_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(mesh: str = "single") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _suggestion(rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = rec["bottleneck"]
    shape = rec["shape"]
    coll = rec.get("coll_by_op", {})
    if b == "collective":
        top = max(coll, key=coll.get) if coll else "?"
        return f"dominant {top}: overlap/reshard (seq-parallel or EP a2a fusion)"
    if b == "memory":
        if shape in ("prefill_32k", "train_4k"):
            return "fuse attention score traffic into VMEM (Pallas flash kernel)"
        return "KV-cache read is the floor; shrink via head-sharding/quantized KV"
    return "compute-bound: raise MXU utilization (larger microbatch tiles)"


def table(recs: list[dict]) -> tuple[list[str], list[list]]:
    header = ["arch", "shape", "mesh", "t_comp(ms)", "t_mem(ms)", "t_coll(ms)",
              "bottleneck", "useful_flops", "args/dev(GiB)", "suggestion"]
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         "FAILED", "-", "-", r.get("error", "")[:40]])
            continue
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            round(r["t_compute"] * 1e3, 2),
            round(r["t_memory"] * 1e3, 2),
            round(r["t_collective"] * 1e3, 2),
            r["bottleneck"],
            round(r["useful_flop_frac"], 3),
            round(r["argument_size_in_bytes"] / 2**30, 2),
            _suggestion(r),
        ])
    return header, rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    # tolerate orchestrator flags (--only/--smoke) when run via benchmarks.run
    args, _ = ap.parse_known_args()
    recs = load_records(args.mesh)
    if not recs:
        print(f"no dry-run records under {DRYRUN_DIR}; run "
              "`python -m repro.launch.dryrun` first")
        return
    header, rows = table(recs)
    write_csv(f"roofline_{args.mesh}.csv", header, rows)
    print(markdown_table(header, rows))
    n_ok = sum(1 for r in recs if r.get("ok"))
    print(f"\n{n_ok}/{len(recs)} cells ok on mesh={args.mesh}")


if __name__ == "__main__":
    main()
