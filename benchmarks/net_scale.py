"""Fleet-scale FlowSim throughput: incremental engine vs full re-solve.

The fleet-scale refactor claims two things: (1) the incremental engine
(per-component max-min re-solve + event calendar) is bit-for-bit identical
to the reference full-solve engine, and (2) it is the difference between a
data plane that tops out around a few hundred devices and one that drives
a 10k-device fleet.  Correctness is property-tested in
tests/test_net_incremental.py; THIS benchmark measures the speed claim:

  * a fleet-size sweep (64 -> 10k devices) of a randomized KV-migration
    workload on the incremental engine, reporting flow events/second
    (starts + completions + aborts per wall second);
  * a request-volume sweep at a fixed fleet, showing throughput holds as
    the concurrent flow population grows;
  * a head-to-head against ``incremental=False`` at the largest size the
    full engine can stomach, asserting the >=10x headline (>=1.5x in
    smoke, where sizes are tiny and constant factors dominate) and that
    both engines settle the SAME number of completions.

    PYTHONPATH=src python -m benchmarks.net_scale [--smoke]
"""

from __future__ import annotations

import random
import time

from benchmarks.common import bench_record, markdown_table, smoke, write_csv
from repro.core import topology as tp
from repro.net import Flow, FlowKind, FlowSim

GB = 1e9
DEVS_PER_HOST = 8
HOSTS_PER_LEAF = 4


def _fleet_sizes():
    if smoke():
        return [64, 256]
    return [64, 256, 1024, 4096, 10240]


def _compare_size():
    """Largest fleet the full-solve engine is run at (it is O(flows x links)
    per event — past ~1k devices a single sweep takes minutes)."""
    return 256 if smoke() else 1024


def _volumes():
    # flows per device for the request-volume sweep at the compare size
    return [0.5, 1.0] if smoke() else [0.5, 1.0, 2.0, 4.0]


def build_fleet(n_devs: int):
    assert n_devs % DEVS_PER_HOST == 0
    return tp.make_cluster(
        n_devs // DEVS_PER_HOST, DEVS_PER_HOST,
        hosts_per_leaf=HOSTS_PER_LEAF, bw_gbps=100.0,
    )


def population(n_devs: int) -> int:
    """Steady-state concurrent flow population: scales with the fleet, the
    way a busy serving fleet's migration/scale traffic does."""
    return max(16, n_devs // 8)


def _pick_pair(rng: random.Random, n_devs: int):
    """A src->dst pair with serving-fleet locality: most KV migrations and
    multicast hops run between co-placed instances (same leaf, often same
    host), a minority crosses the spine.  Locality is what keeps bottleneck
    components local — uniformly random cross-leaf traffic would couple
    every leaf through the spine into one global component (and indeed the
    incremental engine degrades toward the full solve there, by design:
    the allocations really are globally coupled)."""
    devs_per_leaf = DEVS_PER_HOST * HOSTS_PER_LEAF
    src = rng.randrange(n_devs)
    r = rng.random()
    if r < 0.9 and n_devs > devs_per_leaf:  # intra-leaf (cross-host NICs)
        leaf0 = (src // devs_per_leaf) * devs_per_leaf
        dst = leaf0 + rng.randrange(devs_per_leaf)
    else:  # cross-leaf: rides the spine
        dst = rng.randrange(n_devs)
    while dst == src:
        dst = (src + 1 + rng.randrange(n_devs - 1)) % n_devs
    return src, dst


def drive(n_devs: int, n_flows: int, *, incremental: bool, seed: int = 0,
          pop: int | None = None):
    """Run a seeded KV-migration workload holding the concurrent population
    at ``population(n_devs)`` and return (events_per_s, wall_s, completed,
    aborted).  Completed flows are replaced at the completion instant, so
    every event lands on a fleet-proportional live population — the regime
    the incremental engine exists for.  The flow sequence is a pure
    function of the seed, so an incremental/full comparison runs the
    IDENTICAL workload."""
    topo = build_fleet(n_devs)
    sim = FlowSim(topo, incremental=incremental)
    rng = random.Random(seed)
    if pop is None:
        pop = population(n_devs)
    t0 = time.perf_counter()
    now = 0.0
    started = 0
    for _ in range(8 * n_flows + 100):  # safety bound, never hit in practice
        deficit = min(pop - len(sim.flows), n_flows - started)
        if deficit > 0:
            batch = []
            for _ in range(deficit):
                src, dst = _pick_pair(rng, n_devs)
                batch.append(
                    Flow(FlowKind.KV_MIGRATION, src, dst,
                         rng.uniform(0.2, 0.6) * GB)
                )
            sim.start_many(batch, now)
            started += len(batch)
        if not sim.flows and started >= n_flows:
            break
        nxt = sim.next_event_time()
        assert nxt is not None, "live flows but no next event"
        # overshoot by > _EPS: an event within the engine's epsilon of the
        # current instant would otherwise make advance_to a no-op forever
        # (events still settle at their exact times inside advance_to)
        now = max(now, nxt) + 1e-8
        sim.advance_to(now)
    wall = time.perf_counter() - t0
    assert started >= n_flows and not sim.flows, "workload did not drain"
    events = started + sim.completed_count + sim.aborted_count
    return events / wall, wall, sim.completed_count, sim.aborted_count


def main():
    sizes = _fleet_sizes()
    rows = []
    metrics = {}

    # -- fleet-size sweep (incremental engine) ------------------------------
    for n in sizes:
        n_flows = 4 * population(n)
        eps, wall, done, _ = drive(n, n_flows, incremental=True)
        rows.append([f"{n} devs", n_flows, f"{eps:,.0f}", round(wall, 2)])
        metrics[f"incremental.n{n}.events_per_s"] = eps
        metrics[f"incremental.n{n}.wall_s"] = wall
        assert done > 0

    # -- request-volume sweep: growing concurrent population, fixed fleet ---
    vol_n = _compare_size()
    for v in _volumes():
        pop = max(16, int(population(vol_n) * v))
        eps, wall, _, _ = drive(vol_n, 4 * pop, incremental=True, pop=pop)
        rows.append([f"{vol_n} devs x{v:g} vol", 4 * pop,
                     f"{eps:,.0f}", round(wall, 2)])
        metrics[f"volume.x{v:g}.events_per_s"] = eps

    # -- head-to-head vs the full-solve reference engine --------------------
    # fewer total flows than the sweep (the reference engine pays a full
    # re-solve per event), but the SAME steady-state population — events/s
    # is a steady-state rate, so the comparison is apples-to-apples
    cmp_n = _compare_size()
    cmp_flows = 2 * population(cmp_n)
    inc_eps, inc_wall, inc_done, inc_ab = drive(cmp_n, cmp_flows, incremental=True)
    ref_eps, ref_wall, ref_done, ref_ab = drive(cmp_n, cmp_flows, incremental=False)
    assert (inc_done, inc_ab) == (ref_done, ref_ab), (
        "engines disagree on settled flows",
        (inc_done, inc_ab), (ref_done, ref_ab),
    )
    speedup = inc_eps / ref_eps
    rows.append([f"{cmp_n} devs FULL solve", cmp_flows,
                 f"{ref_eps:,.0f}", round(ref_wall, 2)])
    rows.append([f"{cmp_n} devs speedup", "-", f"{speedup:.1f}x", "-"])
    metrics["reference.events_per_s"] = ref_eps
    metrics["reference.wall_s"] = ref_wall
    metrics["speedup_vs_full"] = speedup

    print(markdown_table(["config", "flows", "events/s", "wall (s)"], rows))
    write_csv("net_scale.csv", ["config", "flows", "events_per_s", "wall_s"],
              rows)
    bench_record("net_scale", metrics, seed=0)

    floor = 1.2 if smoke() else 10.0
    assert speedup >= floor, (
        f"incremental engine only {speedup:.1f}x over full solve at "
        f"{cmp_n} devices (need >={floor}x)"
    )
    print(f"\nincremental engine: {speedup:.1f}x flow-event throughput over "
          f"the full-solve engine at {cmp_n} devices "
          f"({inc_eps:,.0f} vs {ref_eps:,.0f} events/s), identical settled "
          f"state; largest sweep {sizes[-1]} devices at "
          f"{metrics['incremental.n%d.events_per_s' % sizes[-1]]:,.0f} events/s")


if __name__ == "__main__":
    main()
