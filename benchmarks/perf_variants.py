"""§Perf beyond-paper variants: measure optimized configurations against the
committed defaults and write `dryrun/<arch>__<shape>__<mesh>__<variant>.json`.

Variants are knobs the registered configs do NOT enable by default, so the
§Roofline table stays the (already hillclimbed) mainline and this file holds
the opt-in deltas:

  kvq   — int8 KV cache with per-token absmax scales (§Perf C3)
"""

from __future__ import annotations

import json
import os

from benchmarks.common import markdown_table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

VARIANTS = [
    ("granite-8b", "decode_32k", {"kv_quant": True}, "kvq"),
    ("nemotron-4-340b", "decode_32k", {"kv_quant": True}, "kvq"),
    ("pixtral-12b", "decode_32k", {"kv_quant": True}, "kvq"),
]


def main():
    # run in a subprocess so the 512-device flag is set before jax init
    import subprocess
    import sys
    import textwrap

    rows = []
    for arch, shape, over, tag in VARIANTS:
        out_path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__single__{tag}.json")
        if not os.path.exists(out_path):
            code = textwrap.dedent(f"""
                import os
                os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
                import json
                import jax
                from repro.launch.mesh import make_production_mesh
                from repro.launch.steps import build_cell
                from repro.launch.hlo_analysis import analyze
                mesh = make_production_mesh()
                art = build_cell({arch!r}, {shape!r}, mesh, cfg_overrides={over!r})
                with mesh:
                    c = jax.jit(art.fn, in_shardings=art.in_shardings,
                                out_shardings=art.out_shardings,
                                donate_argnums=art.donate).lower(*art.args).compile()
                rep = analyze(c.as_text())
                mem = c.memory_analysis()
                rec = {{
                    'arch': {arch!r}, 'shape': {shape!r}, 'variant': {tag!r},
                    't_compute': rep.dot_flops / 197e12,
                    't_memory': rep.hbm_bytes / 819e9,
                    't_collective': rep.collective_bytes / 50e9,
                    'argument_size_in_bytes': int(mem.argument_size_in_bytes),
                    'ok': True,
                }}
                json.dump(rec, open({out_path!r}, 'w'), indent=1)
            """)
            env = dict(os.environ)
            env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
            r = subprocess.run([sys.executable, "-c", code], env=env,
                               capture_output=True, text=True, timeout=590)
            if r.returncode != 0:
                print(f"{arch} {shape} {tag} FAILED: {r.stderr[-500:]}")
                continue
        with open(out_path) as f:
            rec = json.load(f)
        base_path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__single.json")
        base = json.load(open(base_path)) if os.path.exists(base_path) else {}
        rows.append([
            arch, shape, tag,
            round(base.get("t_memory", 0) * 1e3, 2),
            round(rec["t_memory"] * 1e3, 2),
            round(base.get("argument_size_in_bytes", 0) / 2**30, 2),
            round(rec["argument_size_in_bytes"] / 2**30, 2),
        ])
    print(markdown_table(
        ["arch", "shape", "variant", "t_mem base(ms)", "t_mem opt(ms)",
         "args base(GiB)", "args opt(GiB)"], rows))
    return rows


if __name__ == "__main__":
    main()
