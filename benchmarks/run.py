"""Benchmark orchestrator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig17] [--smoke]

Each module prints a markdown table, writes CSV/JSON under
benchmarks/results/, and asserts its paper-headline property.  ``--smoke``
(also ``BLITZ_SMOKE=1``) runs every suite on a tiny config with headline
assertions relaxed — the CI job that keeps benchmark scripts from rotting."""

from __future__ import annotations

import argparse
import os
import time
import traceback

SUITES = [
    ("fig3_slo_vs_speed", "Fig.3 SLO attainment vs scaling-stop duration"),
    ("fig17_e2e_traces", "Fig.17 TTFT/TBT: blitz vs S-LLM vs AllCache"),
    ("fig18_gpu_time", "Fig.18 GPU time vs DistServe full/half"),
    ("fig19_cache_usage", "Fig.19 O(1) host cache vs S-LLM"),
    ("fig20_ablation", "Fig.20 +Network/+Multicast/+ZigZag ablation"),
    ("fig21_live_timeline", "Fig.21 live-scale throughput timeline"),
    ("net_contention", "Flow-level data plane: contended/degraded links"),
    ("net_scale", "Fleet-scale FlowSim: incremental engine vs full solve"),
    ("plan_generation", "§5.1/5.2 plan-gen + ZigZag solver latency"),
    ("kernel_micro", "App.A kernel micro (Pallas vs oracle)"),
    ("roofline", "§Roofline table from dry-run artifacts"),
    ("disagg_e2e", "disagg vs colocated on real engines"),
    ("maas_gpu_time", "MaaS fleet sharing vs static (Fig.18 claim)"),
    ("obs_overhead", "tracing overhead + recorded sim perf baseline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single suite by name")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, relaxed assertions (CI smoke job)")
    args = ap.parse_args()
    if args.smoke:
        os.environ["BLITZ_SMOKE"] = "1"  # read by benchmarks.common.smoke()

    failures = []
    suite_wall: dict[str, float] = {}
    for name, desc in SUITES:
        if args.only and args.only not in name:
            continue
        print(f"\n{'='*78}\n== {name}: {desc}\n{'='*78}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            suite_wall[name] = time.perf_counter() - t0
            print(f"-- {name} ok in {suite_wall[name]:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"-- {name} FAILED", flush=True)

    print(f"\n{'='*78}")
    if suite_wall and not args.only:
        # per-suite wall seconds are themselves a tracked perf surface
        from benchmarks.common import bench_record

        bench_record(
            "suite_times",
            {f"{k}.wall_s": v for k, v in suite_wall.items()},
        )
    if failures:
        print(f"{len(failures)} suite(s) failed: {failures}")
        raise SystemExit(1)
    print("all benchmark suites passed")


if __name__ == "__main__":
    main()
