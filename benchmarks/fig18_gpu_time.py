"""Fig. 18: latency + GPU time — BlitzScale vs DistServe(full/half) vs S-LLM.

Paper headline: BlitzScale matches over-provisioned DistServe's SLO while
using ~50% less GPU time; DistServe(half) collapses under bursts."""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_trace, markdown_table, smoke, write_csv
from repro.core import simulator as sim
from repro.obs.ledger import DEVICE_STATES


def run(duration=None):
    duration = duration or (40.0 if smoke() else 150.0)
    prof = sim.profile_for("24b")
    tr = calibrated_trace("azure_conv", prof, duration=duration, seed=3)
    n_devs = 4 * 8
    max_inst = n_devs // prof.devices_per_instance  # 16 instances of 2 GPUs
    systems = {
        "blitz": sim.BLITZ,
        "sllm": sim.SLLM,
        "distserve-full": sim.fixed_system("distserve-full", max_inst // 2, max_inst // 2),
        "distserve-half": sim.fixed_system("distserve-half", max_inst // 4, max_inst // 4),
    }
    rows = []
    for name, cfg in systems.items():
        r = sim.run_system(cfg, prof, tr)
        rows.append([
            name,
            round(r.mean_ttft(), 4), round(r.p99_ttft(), 4),
            round(r.mean_tbt(), 5), round(r.p99_tbt(), 5),
            round(r.gpu_time_s, 1), round(r.slo_attainment(prof), 4),
            r.scale_events,
            # per-state GPU-time attribution (appended AFTER scale_events so
            # the positional assertions below keep their indices)
            *(round(r.device_seconds.get(s, 0.0), 1) for s in DEVICE_STATES),
        ])
    return rows


def main():
    rows = run()
    write_csv("fig18_gpu_time.csv",
              ["system", "mean_ttft", "p99_ttft", "mean_tbt", "p99_tbt",
               "gpu_time_s", "slo_attainment", "scale_events",
               *(f"gpu_{s}_s" for s in DEVICE_STATES)], rows)
    # stacked per-state view: one row per (system, state) with its share of
    # the system's total — the plot-ready form of the utilization ledger
    stacked = []
    for r in rows:
        total = r[5] or 1.0
        for i, s in enumerate(DEVICE_STATES):
            stacked.append([r[0], s, r[8 + i], round(r[8 + i] / total, 4)])
    write_csv("fig18_gpu_state_breakdown.csv",
              ["system", "state", "device_seconds", "frac"], stacked)
    print(markdown_table(
        ["system", "mean TTFT", "p99 TTFT", "mean TBT", "p99 TBT",
         "GPU-time(s)", "SLO", "scales",
         *(s.replace("_", " ") for s in DEVICE_STATES)], rows))
    if smoke():
        return rows
    by = {r[0]: r for r in rows}
    # headline: blitz uses less GPU time than the full-provisioned setup ...
    assert by["blitz"][5] < by["distserve-full"][5]
    # ... and has far better latency than half-provisioning
    assert by["blitz"][1] <= by["distserve-half"][1]
    return rows


if __name__ == "__main__":
    main()
