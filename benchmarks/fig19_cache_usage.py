"""Fig. 19: host cache usage — O(1) (BlitzScale) vs O(hosts) (S-LLM TTL).

The global parameter pool keeps exactly one host copy per model; S-LLM's
keepalive cache replicates each model onto every host that ever scaled it."""

from __future__ import annotations

from benchmarks.common import calibrated_trace, markdown_table, smoke, write_csv
from repro.core import simulator as sim
from repro.core.parameter_pool import ParameterPool
from repro.core import topology as tp


def run(duration=None):
    duration = duration or (40.0 if smoke() else 150.0)
    pairs = [("burstgpt", "8b"), ("azure_code", "24b"), ("azure_conv", "24b")]
    rows = []
    for trace_name, size in (pairs[:1] if smoke() else pairs):
        prof = sim.profile_for(size)
        tr = calibrated_trace(trace_name, prof, duration=duration, seed=4)
        for name, cfg in [("blitz", sim.BLITZ), ("sllm", sim.SLLM)]:
            r = sim.run_system(cfg, prof, tr)
            rows.append([
                trace_name, name,
                round(r.host_cache_total() / prof.param_bytes, 3),  # in model-copies
                r.scale_events,
            ])
    return rows


def multi_model_pool_growth(n_models=64, n_hosts=16):
    """The MAAS-wide view: pool usage grows O(models), one copy each, spread
    evenly — aggregated host DRAM suffices for ALL models (paper §1)."""
    topo = tp.make_cluster(n_hosts, 8)
    pool = ParameterPool(topo)
    for i in range(n_models):
        pool.register(f"model-{i}", 16 << 30)
    usage = pool.host_cache_bytes()
    per_host_copies = [v / (16 << 30) for v in usage.values()]
    return max(per_host_copies), n_models / n_hosts


def model_count_sweep(max_models=8, n_hosts=4, devs_per_host=8, alpha=1.2):
    """Sweep fleet size 1→N models: total host-cache copies under O(1)
    pooling vs per-host TTL caching (S-LLM keeps a copy on EVERY host a
    model ever scaled onto).  Popularity is Zipf-skewed, so hot models touch
    many hosts — exactly where per-host caching explodes."""
    from repro.serving.traces import zipf_weights

    rows = []
    for n in range(1, max_models + 1):
        topo = tp.make_cluster(n_hosts, devs_per_host)
        pool = ParameterPool(topo)
        all_ids = [d.id for d in topo.devices]
        ws = zipf_weights(n, alpha)
        sllm_copies = 0
        for i, w in enumerate(ws):
            name = f"m{i}"
            pool.register(name, 16 << 30)
            # the rank-i model bursts ∝ its popularity; each burst lands on
            # whatever devices happen to be free (placement churn), so over
            # time a hot model touches many distinct hosts — and TTL caching
            # keeps a host copy on EVERY one of them
            n_dev = max(1, round(float(w) * n_hosts * devs_per_host / 2))
            episodes = max(1, round(float(w) * n * 2))
            hosts_touched: set[int] = set()
            for e in range(episodes):
                start = ((i * 3 + e) * devs_per_host) % len(all_ids)
                devs = [all_ids[(start + j) % len(all_ids)] for j in range(n_dev)]
                pool.deploy(name, devs)
                hosts_touched |= {topo.device(d).host for d in devs}
                pool.reclaim(name, devs)  # burst over: back to zero GPU copies
            sllm_copies += len(hosts_touched)
        blitz_copies = sum(pool.host_cache_bytes().values()) // (16 << 30)
        per_host_max = max(pool.host_cache_bytes().values()) // (16 << 30)
        rows.append([n, int(blitz_copies), int(sllm_copies), int(per_host_max)])
        assert pool.invariant_ok()
    return rows


def main():
    rows = run()
    write_csv("fig19_cache_usage.csv",
              ["trace", "system", "host_cache_model_copies", "scale_events"], rows)
    print(markdown_table(["trace", "system", "cache (model-copies)", "scales"], rows))
    for trace_name in {r[0] for r in rows}:
        sub = {r[1]: r[2] for r in rows if r[0] == trace_name}
        assert sub["blitz"] <= 1.0 + 1e-9  # O(1)
        assert sub["sllm"] >= sub["blitz"]
    mx, ideal = multi_model_pool_growth(*((8, 4) if smoke() else (64, 16)))
    print(f"\nmulti-model pool: max copies/host = {mx} (ideal {ideal})")
    assert mx <= ideal + 1

    sweep = model_count_sweep(max_models=3 if smoke() else 8)
    write_csv("fig19_model_sweep.csv",
              ["n_models", "blitz_copies", "sllm_copies", "blitz_max_per_host"], sweep)
    print("\nmulti-model fleet sweep (host-cache copies, blitz O(1)/model vs "
          "S-LLM per-host TTL):")
    print(markdown_table(["models", "blitz", "sllm", "blitz max/host"], sweep))
    for n, blitz, sllm, _ in sweep:
        assert blitz == n  # exactly one copy per model, fleet-wide
        assert sllm >= blitz
    # the gap must WIDEN with fleet size (hot models touch many hosts)
    if not smoke():
        assert sweep[-1][2] - sweep[-1][1] > sweep[0][2] - sweep[0][1]
    return rows


if __name__ == "__main__":
    main()
