"""Fig. 19: host cache usage — O(1) (BlitzScale) vs O(hosts) (S-LLM TTL).

The global parameter pool keeps exactly one host copy per model; S-LLM's
keepalive cache replicates each model onto every host that ever scaled it."""

from __future__ import annotations

from benchmarks.common import calibrated_trace, markdown_table, write_csv
from repro.core import simulator as sim
from repro.core.parameter_pool import ParameterPool
from repro.core import topology as tp


def run(duration=150.0):
    rows = []
    for trace_name, size in [("burstgpt", "8b"), ("azure_code", "24b"), ("azure_conv", "24b")]:
        prof = sim.profile_for(size)
        tr = calibrated_trace(trace_name, prof, duration=duration, seed=4)
        for name, cfg in [("blitz", sim.BLITZ), ("sllm", sim.SLLM)]:
            r = sim.run_system(cfg, prof, tr)
            rows.append([
                trace_name, name,
                round(r.host_cache_total() / prof.param_bytes, 3),  # in model-copies
                r.scale_events,
            ])
    return rows


def multi_model_pool_growth(n_models=64, n_hosts=16):
    """The MAAS-wide view: pool usage grows O(models), one copy each, spread
    evenly — aggregated host DRAM suffices for ALL models (paper §1)."""
    topo = tp.make_cluster(n_hosts, 8)
    pool = ParameterPool(topo)
    for i in range(n_models):
        pool.register(f"model-{i}", 16 << 30)
    usage = pool.host_cache_bytes()
    per_host_copies = [v / (16 << 30) for v in usage.values()]
    return max(per_host_copies), n_models / n_hosts


def main():
    rows = run()
    write_csv("fig19_cache_usage.csv",
              ["trace", "system", "host_cache_model_copies", "scale_events"], rows)
    print(markdown_table(["trace", "system", "cache (model-copies)", "scales"], rows))
    for trace_name in {r[0] for r in rows}:
        sub = {r[1]: r[2] for r in rows if r[0] == trace_name}
        assert sub["blitz"] <= 1.0 + 1e-9  # O(1)
        assert sub["sllm"] >= sub["blitz"]
    mx, ideal = multi_model_pool_growth()
    print(f"\n64 models on 16 hosts: max copies/host = {mx} (ideal {ideal})")
    assert mx <= ideal + 1
    return rows


if __name__ == "__main__":
    main()
