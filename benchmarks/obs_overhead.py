"""Tracing overhead + the first recorded simulator perf baseline.

Two questions:

  1. what does enabling the span tracer cost?  (It must be cheap enough to
     leave on for any investigation — and literally free when disabled,
     which the golden-trace tests already pin behaviourally; this measures
     the wall-clock side.)
  2. what IS the seeded simulator's performance?  Until now the repo had
     no recorded perf numbers at all; this writes ``BENCH_sim_baseline.json``
     with the seeded run's TTFT/SLO/scale metrics so future PRs can diff.

Run: ``PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]``
"""

from __future__ import annotations

import time

from benchmarks.common import bench_record, markdown_table, smoke

import repro.core.simulator as sim
from repro.obs import MetricRegistry, Tracer, chrome_trace
from repro.serving import traces

SEED = 0


def _run(duration: float, *, tracer=None, metrics=None):
    s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=SEED,
                      tracer=tracer, metrics=metrics)
    tr = traces.burstgpt(duration=duration, base_rate=4.0, seed=SEED + 11)
    t0 = time.perf_counter()
    res = s.run(tr)
    return time.perf_counter() - t0, res


def main() -> dict:
    duration = 20.0 if smoke() else 60.0

    _run(5.0)  # warm imports/JIT-free paths so the timed runs compare fairly
    wall_off, res_off = _run(duration)
    tracer = Tracer()
    metrics = MetricRegistry()
    wall_on, res_on = _run(duration, tracer=tracer, metrics=metrics)

    assert res_on.p99_ttft() == res_off.p99_ttft(), (
        "tracing must not change simulation results"
    )
    export = chrome_trace(list(tracer.spans))
    overhead = (wall_on - wall_off) / wall_off if wall_off > 0 else 0.0

    m = {
        "wall_s_untraced": wall_off,
        "wall_s_traced": wall_on,
        "overhead_frac": overhead,
        "spans": float(len(tracer.spans)),
        "chrome_export_bytes": float(len(export)),
        "requests": float(len(res_off.requests)),
        "sim_duration_s": duration,
    }
    bench_record("obs_overhead", m, seed=SEED)

    base = {
        "ttft_p99_s": res_off.p99_ttft(),
        "ttft_mean_s": res_off.mean_ttft(),
        "tbt_p99_s": res_off.p99_tbt(),
        "slo_attainment": res_off.slo_attainment(sim.profile_for("8b")),
        "scale_events": float(res_off.scale_events),
        "net_scale_bytes": res_off.net_scale_bytes,
        "kv_stream_bytes": res_off.kv_stream_bytes,
        "gpu_time_s": res_off.gpu_time_s,
        "requests": float(len(res_off.requests)),
        "sim_duration_s": duration,
    }
    # the ledger's exclusive-state split of gpu_time_s: a regression in any
    # single state (e.g. loading_params growing) gates even when the total
    # happens to cancel out
    base.update({f"gpu_s.{k}": v for k, v in res_off.device_seconds.items()})
    base.update({f"registry.{k}": v for k, v in metrics.flat().items()})
    bench_record("sim_baseline", base, seed=SEED)

    print(markdown_table(
        ["metric", "value"],
        [["untraced wall (s)", f"{wall_off:.3f}"],
         ["traced wall (s)", f"{wall_on:.3f}"],
         ["overhead", f"{overhead * 100:.1f}%"],
         ["spans", len(tracer.spans)],
         ["p99 TTFT (s)", f"{res_off.p99_ttft():.4f}"]],
    ))
    return m


if __name__ == "__main__":
    main()
