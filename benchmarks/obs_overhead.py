"""Tracing overhead + the first recorded simulator perf baseline.

Three questions:

  1. what does enabling the span tracer cost?  (It must be cheap enough to
     leave on for any investigation — and literally free when disabled,
     which the golden-trace tests already pin behaviourally; this measures
     the wall-clock side.)  Each configuration is timed as the **min of
     repeats**: wall-clock minima converge to the true cost while means
     absorb scheduler noise, so ``overhead_frac`` is stable enough to gate
     in perfdiff (lower-better, wide per-rule tolerance).
  2. what IS the seeded simulator's performance?  ``BENCH_sim_baseline.json``
     records the seeded run's TTFT/SLO/scale metrics so future PRs diff.
  3. does the anomaly path work end-to-end?  A final traced run injects a
     device failure so the :class:`~repro.obs.flightrec.FlightRecorder`
     dumps a deterministic incident bundle under ``incidents/`` — the CI
     smoke job uploads it as an artifact, so every CI run leaves behind an
     openable (ui.perfetto.dev) example incident.

Run: ``PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]``
"""

from __future__ import annotations

import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import bench_record, markdown_table, smoke

import repro.core.simulator as sim
from repro.obs import FlightRecorder, MetricRegistry, Tracer, chrome_trace
from repro.serving import traces

SEED = 0
REPEATS = 3  # min-of-N wall clock per configuration


def _run(duration: float, *, tracer=None, metrics=None,
         flight_recorder=None, fail_dev_at=None):
    s = sim.Simulator(sim.BLITZ, sim.profile_for("8b"), seed=SEED,
                      tracer=tracer, metrics=metrics,
                      flight_recorder=flight_recorder)
    if fail_dev_at is not None:
        s.schedule(fail_dev_at, lambda sm: sm.flowsim.fail_device(3, sm.now))
    tr = traces.burstgpt(duration=duration, base_rate=4.0, seed=SEED + 11)
    t0 = time.perf_counter()
    res = s.run(tr)
    return time.perf_counter() - t0, res


def _best_of(duration: float, *, traced: bool):
    """Min-of-REPEATS wall clock; returns (best_wall, tracer, metrics,
    result) from the last repeat (seeded runs are identical, so which
    repeat's artifacts we keep is immaterial)."""
    best = math.inf
    tracer = metrics = res = None
    for _ in range(REPEATS):
        tracer = Tracer() if traced else None
        metrics = MetricRegistry() if traced else None
        wall, res = _run(duration, tracer=tracer, metrics=metrics)
        best = min(best, wall)
    return best, tracer, metrics, res


def main() -> dict:
    duration = 20.0 if smoke() else 60.0

    _run(5.0)  # warm imports/JIT-free paths so the timed runs compare fairly
    wall_off, _, _, res_off = _best_of(duration, traced=False)
    wall_on, tracer, metrics, res_on = _best_of(duration, traced=True)

    assert res_on.p99_ttft() == res_off.p99_ttft(), (
        "tracing must not change simulation results"
    )
    export = chrome_trace(list(tracer.spans))
    overhead = (wall_on - wall_off) / wall_off if wall_off > 0 else 0.0

    # anomaly path: same seeded scenario + a device failure at t=6 -> the
    # flight recorder dumps a deterministic Perfetto-loadable incident
    # bundle (CI uploads incidents/ as an artifact)
    fr_tracer = Tracer()
    recorder = FlightRecorder(fr_tracer, out_dir="incidents")
    _run(duration, tracer=fr_tracer, flight_recorder=recorder, fail_dev_at=6.0)
    assert recorder.dumps, "device failure must have triggered an incident dump"

    m = {
        "wall_s_untraced": wall_off,
        "wall_s_traced": wall_on,
        "overhead_frac": overhead,
        "spans": float(len(tracer.spans)),
        "chrome_export_bytes": float(len(export)),
        "requests": float(len(res_off.requests)),
        "incident_bundles": float(len(recorder.dumps)),
        "sim_duration_s": duration,
    }
    bench_record("obs_overhead", m, seed=SEED)

    base = {
        "ttft_p99_s": res_off.p99_ttft(),
        "ttft_mean_s": res_off.mean_ttft(),
        "tbt_p99_s": res_off.p99_tbt(),
        "slo_attainment": res_off.slo_attainment(sim.profile_for("8b")),
        "scale_events": float(res_off.scale_events),
        "net_scale_bytes": res_off.net_scale_bytes,
        "kv_stream_bytes": res_off.kv_stream_bytes,
        "gpu_time_s": res_off.gpu_time_s,
        "requests": float(len(res_off.requests)),
        "sim_duration_s": duration,
    }
    # the ledger's exclusive-state split of gpu_time_s: a regression in any
    # single state (e.g. loading_params growing) gates even when the total
    # happens to cancel out
    base.update({f"gpu_s.{k}": v for k, v in res_off.device_seconds.items()})
    base.update({f"registry.{k}": v for k, v in metrics.flat().items()})
    bench_record("sim_baseline", base, seed=SEED)

    print(markdown_table(
        ["metric", "value"],
        [["untraced wall (s)", f"{wall_off:.3f}"],
         ["traced wall (s)", f"{wall_on:.3f}"],
         ["overhead", f"{overhead * 100:.1f}%"],
         ["spans", len(tracer.spans)],
         ["incident bundles", len(recorder.dumps)],
         ["p99 TTFT (s)", f"{res_off.p99_ttft():.4f}"]],
    ))
    return m


if __name__ == "__main__":
    main()
