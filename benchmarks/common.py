"""Shared benchmark helpers: result sinks, trace calibration, tables."""

from __future__ import annotations

import csv
import json
import os
import subprocess
import sys
from typing import Iterable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def smoke() -> bool:
    """True when benchmarks run in smoke mode (``--smoke`` on the command
    line or ``BLITZ_SMOKE=1``): tiny configs, paper-headline assertions
    skipped.  The CI smoke job uses this so benchmark scripts can't silently
    rot without burning CI minutes on full paper-scale runs."""
    return "--smoke" in sys.argv or os.environ.get("BLITZ_SMOKE", "") not in ("", "0")


def result_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


def write_csv(name: str, header: list[str], rows: Iterable[Iterable]) -> str:
    path = result_path(name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def write_json(name: str, obj) -> str:
    path = result_path(name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def git_sha() -> str:
    """Current commit SHA, or "unknown" outside a git checkout — stamped
    into every perf record so a regression can be bisected to a commit."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def bench_record(name: str, metrics: dict, *, seed: int | None = None,
                 extra: dict | None = None) -> str:
    """Write a ``BENCH_<name>.json`` perf record at the repo root.

    The recorded baseline: a flat name->number metrics dict (a
    ``MetricRegistry.flat()`` snapshot or hand-built numbers), stamped with
    the commit SHA, the seed, and whether this was a smoke run — enough for
    a later run to diff against.  Committed records ARE the perf baseline;
    CI uploads fresh ones as artifacts for comparison."""
    rec = {
        "bench": name,
        "schema": 1,
        "git_sha": git_sha(),
        "seed": seed,
        "smoke": smoke(),
        "metrics": {k: metrics[k] for k in sorted(metrics)},
    }
    if extra:
        rec.update(extra)
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def markdown_table(header: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(str(h) for h in header) + " |",
           "|" + "---|" * len(header)]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def calibrated_trace(kind: str, prof, *, n_hosts=4, devs_per_host=8,
                     duration=180.0, seed=0, frac=0.10):
    """The paper's §6 calibration, adapted: TraceUpscaler-style rescale so
    the *burst peak* (~5x the average) fits the cluster's prefill capacity
    while the average needs only a few instances — the autoscaling premise
    (GPUs split between prefill and decode, so prefill gets ~half)."""
    from repro.serving import traces

    max_instances = (n_hosts * devs_per_host) // prof.devices_per_instance
    # per-instance request capacity at the trace's mean prompt length
    prompt_mean = {"burstgpt": 512, "azure_code": 2048, "azure_conv": 1024}[kind]
    per_inst = prof.prefill_tps / prompt_mean
    target = frac * max_instances * per_inst
    tr = traces.TRACES[kind](duration=duration, seed=seed)
    return traces.scale_to_capacity(tr, target)
