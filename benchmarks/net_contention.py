"""Unified data plane: scale-up vs KV-drain contention on shared links.

The point of ``repro.net.FlowSim`` is that a multicast scale-up, a KV-cache
drain and a cold start finally *interact*: this benchmark measures a 4-way
cross-leaf scale-up (Algorithm-11 plan, executed as flows) and an 8-flow KV
drain crossing the same leaf uplink, alone and together, plus degraded-link,
oversubscribed-spine and latency-model scenarios the old per-module
bandwidth models could not express.  Two extras since the latency PR:

  * a request-granular KV drain (sizes from a real trace's prompt lengths)
    run with and without per-hop latency — small per-request messages are
    latency-dominated, bulk transfers are not;
  * a leaf-failure scenario through the MaaS FleetScheduler: a leaf dies
    mid-live-scale and the cold start completes via the scheduler's
    failure-subscription re-grant, NOT via the runtime drain path;
  * a deep-vs-wide planning scenario: with switching delay dominating,
    bandwidth-only Algorithm-11 planning serializes every target into one
    deep chain while latency-aware planning splits into shallow chains —
    the realized (FlowSim) completion gap is the headline of the
    planner/data-plane convergence PR.

    PYTHONPATH=src python -m benchmarks.net_contention [--smoke]
"""

from __future__ import annotations

from benchmarks.common import bench_record, markdown_table, smoke, write_csv
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.net import LEAF_DOWN, Flow, FlowKind, FlowSim, MulticastExecution

N_KV = 8
KV_BYTES = int(2e9)  # per drained request batch
MODEL_BYTES = int(16e9)  # 8B model in bf16
DEGRADE = 0.1  # degraded downlink multiplier
OVERSUB = 8.0  # oversubscribed-spine factor
LINK_LAT = 200e-6  # per-hop propagation (200 us)
SWITCH_LAT = 25e-6  # per switching element (25 us)


def _sizes():
    if smoke():
        return 2, int(1e8), int(4e8)
    return N_KV, KV_BYTES, MODEL_BYTES


def build():
    """2 leaves x 8 devices @100 Gbps; model sources (decode role, free
    egress) and draining prefill instances live in leaf 0, scale-up targets
    and KV destinations in leaf 1 — every flow crosses the leaf-0 uplink /
    leaf-1 downlink, so the spine scenarios actually bind."""
    topo = tp.add_host_sources(tp.make_cluster(4, 4, bw_gbps=100.0))
    srcs = [0, 1]
    for i in srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    kv_srcs = [2, 3, 4, 5]  # prefill instances draining their KV cross-leaf
    for i in kv_srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.PREFILL
    leaf1 = [d.id for d in topo.spares() if d.leaf == 1]
    # KV pages drain INTO the scale-up targets — the §5.4 incast scenario:
    # the parameter stream and the migrations share each target's ingress
    tgts = kv_dsts = leaf1[:4]
    return topo, srcs, kv_srcs, tgts, kv_dsts


def run_scenario(*, scale: bool, kv: bool, degrade: bool = False,
                 oversub: float = 1.0, latency: bool = False):
    n_kv, kv_bytes, model_bytes = _sizes()
    topo, srcs, kv_srcs, tgts, kv_dsts = build()
    sim = FlowSim(
        topo,
        spine_oversub=oversub,
        link_latency_s=LINK_LAT if latency else 0.0,
        switch_latency_s=SWITCH_LAT if latency else 0.0,
    )
    if degrade:
        sim.degrade_link((LEAF_DOWN, 1, 0), DEGRADE)

    ex = None
    if scale:
        plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
        assert mc.validate_plan(topo, plan) == []
        ex = MulticastExecution(plan, model_bytes)
        ex.start(sim, 0.0)
    kv_flows = []
    if kv:
        for k in range(n_kv):
            kv_flows.append(
                sim.start(
                    Flow(FlowKind.KV_MIGRATION, kv_srcs[k % len(kv_srcs)],
                         kv_dsts[k % len(kv_dsts)], float(kv_bytes)),
                    0.0,
                )
            )
    sim.advance_to(1e6)
    t_scale = ex.done_at if ex is not None else None
    t_kv = max((f.finished_at for f in kv_flows), default=None)
    return t_scale, t_kv


def run_per_request_drain(*, latency: bool):
    """Request-granular serving realism: one KV flow per request, sized from
    a real trace's prompt lengths.  Small messages are latency-dominated."""
    from repro.serving import traces

    n_req = 16 if smoke() else 64
    kv_per_tok = 131072  # the 8b profile's KV bytes/token
    trace = traces.burstgpt(duration=30.0, base_rate=4.0, seed=5)[:n_req]
    sizes = traces.kv_volumes(trace, kv_per_tok)
    topo, srcs, kv_srcs, tgts, kv_dsts = build()
    sim = FlowSim(
        topo,
        link_latency_s=LINK_LAT if latency else 0.0,
        switch_latency_s=SWITCH_LAT if latency else 0.0,
    )
    flows = [
        sim.start(
            Flow(FlowKind.SERVING, kv_srcs[k % len(kv_srcs)],
                 kv_dsts[k % len(kv_dsts)], float(sz), tag=f"req{k}"),
            0.0,
        )
        for k, sz in enumerate(sizes)
    ]
    sim.advance_to(1e6)
    return max(f.finished_at for f in flows)


def run_deep_vs_wide():
    """Latency-aware planning headline.  Single leaf, two model sources,
    switching delay dominating per-hop cost: bandwidth-only Algorithm 11
    chains every target behind ONE source (deep serial store-and-forward),
    latency-aware planning re-ranks source selection on projected arrival
    and splits the targets across both sources.  Returns
    (depth_bw, t_bw, depth_lat, t_lat, analytic_lat) with ``t_*`` the
    FlowSim-REALIZED completion of each plan under identical latency."""
    n_tgts = 4 if smoke() else 6
    model_bytes = int(1e8) if smoke() else int(2e8)
    link_lat, switch_lat = 0.01, 0.05
    topo = tp.make_cluster(2 + n_tgts, 1, hosts_per_leaf=2 + n_tgts, bw_gbps=8.0)
    srcs = [0, 1]
    for i in srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    tgts = [d.id for d in topo.spares()]

    def depth(plan):
        return max((len(c.edges) for c in plan.chains), default=0)

    def realize(plan):
        sim = FlowSim(topo, link_latency_s=link_lat, switch_latency_s=switch_lat)
        ex = MulticastExecution(plan, model_bytes)
        ex.start(sim, 0.0)
        sim.advance_to(1e6)
        assert ex.done, "multicast execution never completed"
        return ex.done_at

    plan_bw = mc.plan_multicast(topo, srcs, tgts, len(tgts))
    view = FlowSim(topo, link_latency_s=link_lat, switch_latency_s=switch_lat)
    plan_lat = mc.plan_multicast(
        topo, srcs, tgts, len(tgts), net=view, model_bytes=model_bytes
    )
    assert mc.validate_plan(topo, plan_lat) == []
    return (
        depth(plan_bw),
        realize(plan_bw),
        depth(plan_lat),
        realize(plan_lat),
        plan_lat.transfer_seconds(model_bytes),
    )


def run_leaf_failure_regrant():
    """A leaf dies mid-live-scale: the FleetScheduler's failure subscription
    cancels the doomed grant and re-grants on a surviving leaf inside the
    SAME event — the cold start completes without the runtime drain path
    ever retiring an engine.  Returns (seconds to drain all requests,
    regrants, drain-path retirements of doomed engines)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.autoscaler import PolicyConfig
    from repro.models import transformer as TF
    from repro.serving.disagg import pools as P
    from repro.serving.maas import FleetPolicy, FleetScheduler

    cfg = get_config("granite-8b", reduced=True).replace(name="bench-fail")
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    topo = tp.add_host_sources(tp.make_cluster(3, 2, hosts_per_leaf=1, bw_gbps=100.0))
    fleet = FleetScheduler(topo, policy=FleetPolicy(idle_to_zero_s=1e9))
    fleet.add_model(
        cfg, params, n_prefill=1, n_decode=1, n_slots=2, max_seq=48,
        model_bytes=int(2e9), prefill_capacity_tps=50.0,
        decode_capacity_tps=20.0,
        policy=PolicyConfig(max_instances=3, kv_upper=0.5),
    )
    rt = fleet.tenants["bench-fail"].runtime
    rng = np.random.default_rng(3)
    now = 0.0
    for _ in range(8 if smoke() else 16):
        fleet.submit("bench-fail",
                     rng.integers(0, cfg.vocab_size, size=16).astype(np.int32),
                     6, now)
    loading = []
    for _ in range(400):
        now += 0.02
        fleet.tick(now)
        loading = [pe for pe in rt.pool.all() if pe.state == P.LOADING]
        if loading:
            break
    assert loading, "no live-scale started — cannot exercise the failure path"
    t_fail = now
    doomed = {pe.device_id for pe in loading}
    fleet.net.fail_leaf(topo.leaf_of(loading[0].device_id), now)
    # everything on the dead leaf was handled INSIDE the failure event by
    # the subscription; whatever survives in the pool would be the runtime
    # drain path's to handle — there must be nothing left for it
    left_for_drain = len(doomed & {pe.device_id for pe in rt.pool.all()})
    for _ in range(20000):
        if fleet.n_outstanding == 0:
            break
        now += 0.02
        fleet.tick(now)
    assert fleet.n_outstanding == 0, "requests lost after leaf failure"
    return now - t_fail, fleet.stats.failure_regrants, left_for_drain


def run():
    """Raw (unrounded) scenario results: [name, t_scale | None, t_kv | None].
    Assertions compare these raw floats; rounding happens only for display."""
    rows = []
    cases = [
        ("scale-up alone (dedicated)", dict(scale=True, kv=False)),
        ("kv-drain alone (dedicated)", dict(scale=False, kv=True)),
        ("scale-up + kv-drain (contended)", dict(scale=True, kv=True)),
        ("contended, downlink degraded x%.2g" % DEGRADE,
         dict(scale=True, kv=True, degrade=True)),
        ("contended, spine %gx oversubscribed" % OVERSUB,
         dict(scale=True, kv=True, oversub=OVERSUB)),
        ("contended + latency (%gus/link, %gus/switch)"
         % (LINK_LAT * 1e6, SWITCH_LAT * 1e6),
         dict(scale=True, kv=True, latency=True)),
    ]
    for name, kw in cases:
        t_scale, t_kv = run_scenario(**kw)
        rows.append([name, t_scale, t_kv])
    for lat in (False, True):
        t = run_per_request_drain(latency=lat)
        rows.append([
            "per-request kv drain%s" % (" + latency" if lat else ""),
            None,
            t,
        ])
    return rows


def _display(rows):
    return [
        [name,
         round(t_scale, 4) if t_scale is not None else "-",
         round(t_kv, 4) if t_kv is not None else "-"]
        for name, t_scale, t_kv in rows
    ]


def main():
    rows = run()
    write_csv("net_contention.csv",
              ["scenario", "scale_up_done_s", "kv_drain_done_s"], _display(rows))
    print(markdown_table(["scenario", "scale-up done (s)", "KV drain done (s)"],
                         _display(rows)))
    t_scale_alone, t_kv_alone = rows[0][1], rows[1][2]
    contended, degraded, oversubbed, latencied = rows[2], rows[3], rows[4], rows[5]
    perreq, perreq_lat = rows[6], rows[7]
    # headline: sharing the uplink slows BOTH consumers — the three core
    # scenarios report DISTINCT scale-up times ...
    assert t_scale_alone < contended[1] < degraded[1], (rows[:4],)
    assert contended[2] > t_kv_alone, (contended, t_kv_alone)
    assert degraded[2] >= contended[2], degraded
    # ... an oversubscribed spine is at least as slow as non-blocking ...
    assert oversubbed[1] >= contended[1] - 1e-9, (oversubbed, contended)
    # ... latency terms stretch the same contended scenario further ...
    assert latencied[1] > contended[1] and latencied[2] > contended[2], latencied
    # ... and request-granular drains are measurably latency-bound
    assert perreq_lat[2] > perreq[2], (perreq, perreq_lat)

    depth_bw, t_bw, depth_lat, t_lat, analytic = run_deep_vs_wide()
    print("\ndeep-vs-wide planning under dominant switching latency: "
          "bandwidth-only depth %d realizes %.3fs; latency-aware depth %d "
          "realizes %.3fs (analytic prediction %.3fs)" %
          (depth_bw, t_bw, depth_lat, t_lat, analytic))
    assert depth_lat < depth_bw, "latency-aware planner did not go wider"
    assert t_lat < t_bw, "latency-aware plan did not realize faster"
    # planner/data-plane convergence: analytic time within 1% of realized
    assert abs(analytic - t_lat) <= 0.01 * t_lat, (analytic, t_lat)

    t_recover, regrants, left_for_drain = run_leaf_failure_regrant()
    print("\nleaf failure mid-live-scale: all requests served %.2fs after "
          "the failure via %d scheduler re-grant(s); doomed engines left "
          "to the runtime drain path: %d" %
          (t_recover, regrants, left_for_drain))
    # recorded perf baseline: the realized data-plane completion times
    metrics = {}
    for name, t_scale, t_kv in rows:
        key = name.split(" (")[0].replace(" ", "_").replace("+", "and")
        if t_scale is not None:
            metrics[f"{key}.scale_up_s"] = t_scale
        if t_kv is not None:
            metrics[f"{key}.kv_drain_s"] = t_kv
    metrics.update({
        "deep_vs_wide.bandwidth_only_s": t_bw,
        "deep_vs_wide.latency_aware_s": t_lat,
        "leaf_failure.recover_s": t_recover,
        "leaf_failure.regrants": float(regrants),
    })
    bench_record("net_contention", metrics, seed=0)
    assert regrants >= 1, "failure subscription never re-granted"
    assert left_for_drain == 0, "runtime drain path handled the failure"
    print("\ncontention, degradation, oversubscription and latency all "
          "measurably stretch scale-up and drain completion; latency-aware "
          "planning beats bandwidth-only chains when switching delay "
          "dominates — and a leaf failure completes via scheduler re-grant, "
          "not runtime drain")
    return rows


if __name__ == "__main__":
    main()
