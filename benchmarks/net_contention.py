"""Unified data plane: scale-up vs KV-drain contention on shared links.

The point of ``repro.net.FlowSim`` is that a multicast scale-up, a KV-cache
drain and a cold start finally *interact*: this benchmark measures a 4-way
cross-leaf scale-up (Algorithm-11 plan, executed as flows) and an 8-flow KV
drain crossing the same leaf uplink, alone and together, plus degraded-link
and oversubscribed-spine scenarios the old per-module bandwidth models
could not express.

    PYTHONPATH=src python -m benchmarks.net_contention [--smoke]
"""

from __future__ import annotations

from benchmarks.common import markdown_table, smoke, write_csv
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.net import LEAF_DOWN, Flow, FlowKind, FlowSim, MulticastExecution

N_KV = 8
KV_BYTES = int(2e9)  # per drained request batch
MODEL_BYTES = int(16e9)  # 8B model in bf16
DEGRADE = 0.1  # degraded downlink multiplier
OVERSUB = 8.0  # oversubscribed-spine factor


def _sizes():
    if smoke():
        return 2, int(1e8), int(4e8)
    return N_KV, KV_BYTES, MODEL_BYTES


def build():
    """2 leaves x 8 devices @100 Gbps; model sources (decode role, free
    egress) and draining prefill instances live in leaf 0, scale-up targets
    and KV destinations in leaf 1 — every flow crosses the leaf-0 uplink /
    leaf-1 downlink, so the spine scenarios actually bind."""
    topo = tp.add_host_sources(tp.make_cluster(4, 4, bw_gbps=100.0))
    srcs = [0, 1]
    for i in srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    kv_srcs = [2, 3, 4, 5]  # prefill instances draining their KV cross-leaf
    for i in kv_srcs:
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.PREFILL
    leaf1 = [d.id for d in topo.spares() if d.leaf == 1]
    # KV pages drain INTO the scale-up targets — the §5.4 incast scenario:
    # the parameter stream and the migrations share each target's ingress
    tgts = kv_dsts = leaf1[:4]
    return topo, srcs, kv_srcs, tgts, kv_dsts


def run_scenario(*, scale: bool, kv: bool, degrade: bool = False,
                 oversub: float = 1.0):
    n_kv, kv_bytes, model_bytes = _sizes()
    topo, srcs, kv_srcs, tgts, kv_dsts = build()
    sim = FlowSim(topo, spine_oversub=oversub)
    if degrade:
        sim.degrade_link((LEAF_DOWN, 1, 0), DEGRADE)

    ex = None
    if scale:
        plan = mc.plan_multicast(topo, srcs, tgts, len(tgts))
        assert mc.validate_plan(topo, plan) == []
        ex = MulticastExecution(plan, model_bytes)
        ex.start(sim, 0.0)
    kv_flows = []
    if kv:
        for k in range(n_kv):
            kv_flows.append(
                sim.start(
                    Flow(FlowKind.KV_MIGRATION, kv_srcs[k % len(kv_srcs)],
                         kv_dsts[k % len(kv_dsts)], float(kv_bytes)),
                    0.0,
                )
            )
    sim.advance_to(1e6)
    t_scale = ex.done_at if ex is not None else None
    t_kv = max((f.finished_at for f in kv_flows), default=None)
    return t_scale, t_kv


def run():
    rows = []
    cases = [
        ("scale-up alone (dedicated)", dict(scale=True, kv=False)),
        ("kv-drain alone (dedicated)", dict(scale=False, kv=True)),
        ("scale-up + kv-drain (contended)", dict(scale=True, kv=True)),
        ("contended, downlink degraded x%.2g" % DEGRADE,
         dict(scale=True, kv=True, degrade=True)),
        ("contended, spine %gx oversubscribed" % OVERSUB,
         dict(scale=True, kv=True, oversub=OVERSUB)),
    ]
    for name, kw in cases:
        t_scale, t_kv = run_scenario(**kw)
        rows.append([
            name,
            round(t_scale, 3) if t_scale is not None else "-",
            round(t_kv, 3) if t_kv is not None else "-",
        ])
    return rows


def main():
    rows = run()
    write_csv("net_contention.csv",
              ["scenario", "scale_up_done_s", "kv_drain_done_s"], rows)
    print(markdown_table(["scenario", "scale-up done (s)", "KV drain done (s)"],
                         rows))
    t_scale_alone, t_kv_alone = rows[0][1], rows[1][2]
    contended, degraded, oversubbed = rows[2], rows[3], rows[4]
    # headline: sharing the uplink slows BOTH consumers ...
    assert contended[1] > t_scale_alone, (contended, t_scale_alone)
    assert contended[2] > t_kv_alone, (contended, t_kv_alone)
    # ... a degraded downlink compounds it ...
    assert degraded[1] >= contended[1] and degraded[2] >= contended[2], degraded
    # ... and an oversubscribed spine is at least as slow as non-blocking
    assert oversubbed[1] >= contended[1] - 1e-9, (oversubbed, contended)
    print("\ncontention, degradation and oversubscription all measurably "
          "stretch scale-up and drain completion — interactions the old "
          "per-module bandwidth models could not express")
    return rows


if __name__ == "__main__":
    main()
