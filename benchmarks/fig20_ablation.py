"""Fig. 20: ablation — +Network, +Multicast(fast), +ZigZag(live).

Each step enables one BlitzScale technique on top of the previous:
  ssd            : SSD-only loading (the S-LLM-miss path)
  +network       : compute-network unicast, interference-ignorant
  +multicast     : Algorithm-11 interference-free multi-chain plan
  +zigzag (live) : live cooperative execution during loading
"""

from __future__ import annotations

from benchmarks.common import calibrated_trace, markdown_table, smoke, write_csv
from repro.core import simulator as sim

STEPS = [
    ("ssd", sim.SSD_ONLY),
    ("+network", sim.BLITZ_NAIVE),
    ("+multicast", sim.BLITZ_NOLIVE),
    ("+zigzag(live)", sim.BLITZ),
]


def run(duration=None):
    duration = duration or (40.0 if smoke() else 150.0)
    pairs = [("burstgpt", "8b"), ("azure_code", "24b"), ("azure_conv", "24b")]
    rows = []
    for trace_name, size in (pairs[:1] if smoke() else pairs):
        prof = sim.profile_for(size)
        tr = calibrated_trace(trace_name, prof, duration=duration, seed=5)
        for name, cfg in STEPS:
            r = sim.run_system(cfg, prof, tr)
            rows.append([
                trace_name, name,
                round(r.mean_ttft(), 4), round(r.p99_ttft(), 4),
                round(r.p99_tbt(), 5), round(r.slo_attainment(prof), 4),
                round(sum(r.scale_seconds) / max(len(r.scale_seconds), 1), 3),
            ])
    return rows


def main():
    rows = run()
    write_csv("fig20_ablation.csv",
              ["trace", "step", "mean_ttft", "p99_ttft", "p99_tbt",
               "slo_attainment", "mean_scale_s"], rows)
    print(markdown_table(
        ["trace", "step", "mean TTFT", "p99 TTFT", "p99 TBT", "SLO", "scale(s)"],
        rows))
    # each increment should not regress mean TTFT (aggregate over traces)
    if not smoke():
        for trace_name in {r[0] for r in rows}:
            sub = [r for r in rows if r[0] == trace_name]
            assert sub[0][2] >= sub[-1][2], sub  # full blitz beats ssd
    return rows


if __name__ == "__main__":
    main()
