"""Fig. 3 (a-d): SLO attainment vs autoscaling-stop duration.

Reproduces the paper's characterization: a simulator provisions instances and
applies a manual scaling delay; SLO attainment degrades as the delay grows.
The paper's anchor points: SSD (12.8 s for 8B @10 Gbps) is unusable; host
cache (~0.5-1 s) marginal; network multicast (~0.15-0.6 s) holds SLO.
"""

from __future__ import annotations

from benchmarks.common import calibrated_trace, markdown_table, smoke, write_csv
from repro.core import simulator as sim


DELAYS = [0.05, 0.5, 12.8] if smoke() else [0.05, 0.15, 0.5, 1.0, 2.0, 5.0, 12.8]
MODELS = ["8b"] if smoke() else ["8b", "24b", "72b"]


def run(duration=None):
    duration = duration or (40.0 if smoke() else 150.0)
    rows = []
    for size in MODELS:
        prof = sim.profile_for(size)
        tr = calibrated_trace("burstgpt", prof, duration=duration, seed=1)
        for d in DELAYS:
            r = sim.run_system(sim.delay_system(d), prof, tr)
            rows.append([size, d, round(r.slo_attainment(prof), 4),
                         round(r.mean_ttft(), 4), round(r.p99_ttft(), 4)])
    return rows


def main():
    rows = run()
    write_csv("fig3_slo_vs_speed.csv",
              ["model", "scale_stop_s", "slo_attainment", "mean_ttft_s", "p99_ttft_s"],
              rows)
    print(markdown_table(
        ["model", "stop(s)", "SLO att.", "mean TTFT", "p99 TTFT"], rows))
    # headline check: longer stops monotonically hurt attainment per model
    if not smoke():
        for size in MODELS:
            att = [r[2] for r in rows if r[0] == size]
            assert att[0] >= att[-1], (size, att)
    return rows


if __name__ == "__main__":
    main()
