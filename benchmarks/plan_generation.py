"""§5.1/§5.2 claims: online plan generation latency + ZigZag solver times.

Paper: plan generation must run online; the ILP solves in <40 ms for
Llama3-8B-scale problems; the ILP-free rule removes solver time entirely."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_record, markdown_table, smoke, write_csv
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.core.zigzag import simulate_zigzag, solve_pipeline_ilp


def plan_latency():
    rows = []
    for n_hosts in (4, 16) if smoke() else (4, 16, 64, 256):
        topo = tp.add_host_sources(tp.make_cluster(n_hosts, 8))
        accel = [d.id for d in topo.devices if not d.is_host]
        srcs = accel[: max(2, n_hosts // 4)]
        for i in srcs:
            topo.device(i).model = "m"
            topo.device(i).role = tp.Role.DECODE
        spares = [d.id for d in topo.spares()]
        times = []
        for _ in range(5):
            plan = mc.plan_multicast(topo, srcs, spares, len(spares))
            times.append(plan.gen_seconds)
        assert mc.validate_plan(topo, plan) == []
        rows.append([n_hosts * 8, len(plan.chains),
                     round(float(np.median(times)) * 1e3, 3)])
    return rows


def ilp_latency():
    rows = []
    cases = [(8, 32)] if smoke() else [(8, 32), (12, 32), (12, 80), (16, 80)]
    for n, layers in cases:
        t0 = time.perf_counter()
        plan = solve_pipeline_ilp(n, layers, 6.0)
        ilp_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        zz = simulate_zigzag(n, layers, 6.0)
        free_ms = (time.perf_counter() - t0) * 1e3
        rows.append([n, layers, round(ilp_ms, 2), round(plan.avg_latency, 2),
                     round(free_ms, 3), round(zz.avg_latency, 2)])
    return rows


def main():
    p_rows = plan_latency()
    write_csv("plan_generation.csv", ["gpus", "chains", "plan_ms"], p_rows)
    print(markdown_table(["cluster GPUs", "chains", "plan gen (ms)"], p_rows))
    assert all(r[2] < 40.0 for r in p_rows), p_rows  # paper: online (<40 ms)

    i_rows = ilp_latency()
    write_csv("zigzag_solver.csv",
              ["batches", "layers", "ilp_ms", "ilp_avg_latency",
               "ilpfree_ms", "ilpfree_avg_latency"], i_rows)
    print(markdown_table(
        ["batches", "layers", "ILP (ms)", "ILP avg lat",
         "ILP-free (ms)", "ILP-free avg lat"], i_rows))
    metrics = {f"plan_gen_ms.gpus{gpus}": ms for gpus, _, ms in p_rows}
    metrics.update({
        f"zigzag.b{b}_l{layers}.ilp_ms": ilp_ms
        for b, layers, ilp_ms, *_ in i_rows
    })
    bench_record("plan_generation", metrics, seed=0)
    return p_rows, i_rows


if __name__ == "__main__":
    main()
