"""Fig. 21: throughput timeline while scaling six 24B prefill instances.

BlitzScale (2 multicast chains + live tails) starts emitting tokens within
the first layer loads and finishes the scale faster than AllCache (PCIe
host-cache loads, stop-the-world)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import markdown_table, smoke, write_csv
from repro.core import multicast as mc
from repro.core import topology as tp
from repro.core.simulator import profile_for
from repro.core.topology import gbps_to_bytes_per_s
from repro.core.zigzag import live_throughput_multiplier


def run():
    prof = profile_for("24b")
    n_new = 6

    # cluster A: 4 hosts x 8 GPUs, NVLink scale-up, 100 Gbps RDMA
    topo = tp.add_host_sources(tp.make_cluster(4, 8, bw_gbps=100.0))
    # two deployed decode instances (free egress) on hosts 0/1 = sources
    for i in (0, 1, 8, 9):
        topo.device(i).model = "m"
        topo.device(i).role = tp.Role.DECODE
    spares = [d.id for d in topo.spares()]
    plan = mc.plan_multicast(topo, [0, 1, 8, 9], spares, n_new * prof.devices_per_instance)
    assert mc.validate_plan(topo, plan) == []

    t_blitz = plan.transfer_seconds(prof.param_bytes)
    t_allcache = (prof.param_bytes / prof.devices_per_instance) / gbps_to_bytes_per_s(256.0)

    # throughput timeline: 1 base instance + scaling instances' contribution
    ts = np.linspace(0, max(t_blitz, t_allcache) * 1.3, 20 if smoke() else 80)
    rows = []
    states = []  # per-timestep device-state attribution (ledger semantics)
    L = prof.n_layers
    n_scale_devs = n_new * prof.devices_per_instance
    base_devs = prof.devices_per_instance
    for t in ts:
        k = min(L, int(L * t / max(t_blitz, 1e-9)))
        # live chains: tails serve cooperatively as layers land
        live_mult = live_throughput_multiplier(k, L)
        blitz_tp = 1.0 * live_mult + (n_new - len(plan.chains)) * (1.0 if t >= t_blitz else 0.0)
        if t >= t_blitz:
            blitz_tp = 1.0 + n_new
        allcache_tp = 1.0 + (n_new if t >= t_allcache else 0.0)
        rows.append([round(float(t), 3), round(blitz_tp, 3), round(allcache_tp, 3)])
        # device-state split, analytic counterpart of the simulator ledger:
        # blitz tails serve with the fraction of layers already landed
        # (serving) and stall on the remainder; allcache devices are pure
        # loading_params until the PCIe load finishes (stop-the-world)
        f = min(k / L, 1.0) if t < t_blitz else 1.0
        states.append([
            round(float(t), 3), "blitz",
            round(base_devs + n_scale_devs * f, 2),     # serving_prefill
            round(n_scale_devs * (1.0 - f), 2),         # stalled_waiting_layers
            0.0,                                        # loading_params
        ])
        done_ac = t >= t_allcache
        states.append([
            round(float(t), 3), "allcache",
            round(base_devs + (n_scale_devs if done_ac else 0), 2),
            0.0,
            round(0 if done_ac else n_scale_devs, 2),
        ])
    return rows, states, t_blitz, t_allcache, plan


def main():
    rows, states, t_blitz, t_allcache, plan = run()
    write_csv("fig21_live_timeline.csv",
              ["t_s", "blitz_rel_throughput", "allcache_rel_throughput"], rows)
    write_csv("fig21_device_states.csv",
              ["t_s", "system", "serving_prefill", "stalled_waiting_layers",
               "loading_params"], states)
    print(f"chains: {len(plan.chains)}, blitz scale {t_blitz:.2f}s vs "
          f"allcache {t_allcache:.2f}s")
    print(markdown_table(["t(s)", "blitz", "allcache"], rows[::10]))
    # headline: blitz emits extra tokens before allcache finishes loading,
    # and the pipelined chain finishes within ~2x of the PCIe load
    mid = [r for r in rows if r[0] < t_allcache]
    assert any(r[1] > 1.0 for r in mid)
    return rows


if __name__ == "__main__":
    main()
