"""Fig. 17: end-to-end TTFT/TBT — BlitzScale vs ServerlessLLM vs AllCache
across the three real-world-shaped traces.

Paper headline: 47-75% shorter mean TTFT vs S-LLM, up to 94% shorter tail
TBT; AllCache sits between (fast loads, but still stop-the-world)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_trace, markdown_table, smoke, write_csv, write_json
from repro.core import simulator as sim

import dataclasses

# The compressed 150 s traces stand in for the paper's multi-hour ones, so
# S-LLM's 5-minute keepalive is compressed proportionally (60 s): azure_code's
# inter-burst gap then evicts the cache exactly as in the paper's §6.1.
SYSTEMS = {
    "blitz": sim.BLITZ,
    "sllm": dataclasses.replace(sim.SLLM, keepalive_s=60.0),
    "allcache": sim.ALLCACHE,
}
# paper's trace->model pairing (§6.1: one trace per model per cluster)
PAIRS = [("burstgpt", "8b"), ("azure_code", "24b"), ("azure_conv", "24b")]


def run(duration=None):
    duration = duration or (40.0 if smoke() else 150.0)
    rows = []
    cdfs = {}
    pairs = PAIRS[:1] if smoke() else PAIRS
    for trace_name, size in pairs:
        prof = sim.profile_for(size)
        tr = calibrated_trace(trace_name, prof, duration=duration, seed=2)
        for name, cfg in SYSTEMS.items():
            r = sim.run_system(cfg, prof, tr)
            ttfts, tbts = r.ttfts(), r.tbts()
            rows.append([
                trace_name, size, name,
                round(float(np.mean(ttfts)), 4), round(float(np.percentile(ttfts, 99)), 4),
                round(float(np.mean(tbts)), 5), round(float(np.percentile(tbts, 99)), 5),
                round(r.slo_attainment(prof), 4),
            ])
            cdfs[f"{trace_name}/{name}"] = {
                "ttft_p": np.percentile(ttfts, [50, 90, 95, 99, 99.9]).tolist(),
                "tbt_p": np.percentile(tbts, [50, 90, 95, 99, 99.9]).tolist(),
            }
    return rows, cdfs


def main():
    rows, cdfs = run()
    write_csv("fig17_e2e_traces.csv",
              ["trace", "model", "system", "mean_ttft", "p99_ttft",
               "mean_tbt", "p99_tbt", "slo_attainment"], rows)
    write_json("fig17_cdfs.json", cdfs)
    print(markdown_table(
        ["trace", "model", "system", "mean TTFT", "p99 TTFT", "mean TBT",
         "p99 TBT", "SLO"], rows))
    if smoke():
        return rows
    # headline: blitz has the lowest mean TTFT on every trace (ties allowed
    # on azure_conv where S-LLM always cache-hits — paper §6.1)
    for trace_name, _ in PAIRS:
        sub = {r[2]: r[3] for r in rows if r[0] == trace_name}
        assert sub["blitz"] <= sub["sllm"] * 1.05, (trace_name, sub)
        assert sub["blitz"] <= sub["allcache"] * 1.05, (trace_name, sub)
    # and strictly beats S-LLM on the isolated-burst traces
    for trace_name in ("burstgpt", "azure_code"):
        sub = {r[2]: r[3] for r in rows if r[0] == trace_name}
        assert sub["blitz"] < sub["sllm"], (trace_name, sub)
    return rows


if __name__ == "__main__":
    main()
