"""Unified flow-level network data plane.

Everything BLITZSCALE moves over the compute network — multicast chain
hops, KV-cache migrations, cold-start unicasts, sharded AllGathers and
background serving streams — rides one congestion-aware flow simulator
with progressive max-min fair sharing over the ``core.topology`` graph,
advanced event-by-event.  See ``flowsim.FlowSim`` for the engine and
``multicast_exec.MulticastExecution`` for plan execution timing.
"""

from repro.net.events import (
    DEVICE_FAILED,
    DEVICE_RECOVERED,
    FAILURE_KINDS,
    FLOW_ABORTED,
    FLOW_COMPLETED,
    FLOW_REROUTED,
    FLOW_STARTED,
    LEAF_FAILED,
    LINK_DEGRADED,
    LINK_FAILED,
    LINK_RECOVERED,
    FlowEventLog,
    NetEvent,
)
from repro.net.flows import Flow, FlowKind
from repro.net.flowsim import FlowSim, flow_done_eps, maxmin_rates
from repro.net.links import (
    DEV_IN,
    DEV_OUT,
    LEAF_DOWN,
    LEAF_UP,
    SCALEUP,
    Link,
    LinkProfile,
    NetworkModel,
)
from repro.net.multicast_exec import MulticastExecution

__all__ = [
    "Flow",
    "FlowKind",
    "FlowSim",
    "FlowEventLog",
    "NetEvent",
    "maxmin_rates",
    "flow_done_eps",
    "MulticastExecution",
    "Link",
    "LinkProfile",
    "NetworkModel",
    "DEV_IN",
    "DEV_OUT",
    "LEAF_UP",
    "LEAF_DOWN",
    "SCALEUP",
    "FLOW_STARTED",
    "FLOW_COMPLETED",
    "FLOW_ABORTED",
    "FLOW_REROUTED",
    "LINK_DEGRADED",
    "LINK_FAILED",
    "LINK_RECOVERED",
    "DEVICE_FAILED",
    "DEVICE_RECOVERED",
    "LEAF_FAILED",
    "FAILURE_KINDS",
]
