"""FlowSim event-subscription API + serializable flow-event log.

The flow simulator used to talk to its consumers only through per-flow
callbacks (``on_complete`` / ``on_abort``) — enough for the party that
*started* a flow, but invisible to everyone else.  The control planes need
more: the FleetScheduler wants to know about a leaf failure the instant it
happens (not one tick later, when the victim runtime has drained its
half-loaded engine), and the regression harness wants the full event
stream of a seeded run to diff against a golden file.

``FlowSim.subscribe`` delivers every :class:`NetEvent` to every subscriber,
in simulation order:

  * ``FLOW_STARTED`` / ``FLOW_COMPLETED`` / ``FLOW_ABORTED`` — one per flow
    lifecycle edge (per-flow callbacks fire first, then subscribers see the
    settled world); ``FLOW_REROUTED`` when a failure moved a live flow onto
    a surviving spine plane instead of aborting it;
  * ``LINK_DEGRADED`` / ``LINK_FAILED`` / ``LINK_RECOVERED`` and
    ``DEVICE_FAILED`` / ``DEVICE_RECOVERED`` / ``LEAF_FAILED`` — scenario
    mutations.  Failure events are emitted AFTER the evicted flows' aborts
    have settled, so a subscriber reacting to ``LEAF_FAILED`` observes a
    consistent post-failure network (re-routes applied, doomed flows gone).

:class:`FlowEventLog` is the canonical subscriber for the golden-trace
regression tests: it renders each event as one deterministic text line
(``repr`` floats — shortest round-trip representation, so a golden diff is
bit-for-bit on event times).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.net.flows import Flow
    from repro.net.links import LinkKey

FLOW_STARTED = "flow_started"
FLOW_COMPLETED = "flow_completed"
FLOW_ABORTED = "flow_aborted"
#: a link/device/leaf failure moved a still-live flow onto a surviving
#: spine plane instead of aborting it; emitted after the failure's aborts
#: settle and BEFORE the failure event itself, so incident bundles show
#: the reroute inside the failure window
FLOW_REROUTED = "flow_rerouted"
LINK_DEGRADED = "link_degraded"
LINK_FAILED = "link_failed"
LINK_RECOVERED = "link_recovered"
DEVICE_FAILED = "device_failed"
DEVICE_RECOVERED = "device_recovered"
LEAF_FAILED = "leaf_failed"

#: the event kinds a placement control plane should re-plan on
FAILURE_KINDS = frozenset({LINK_FAILED, DEVICE_FAILED, LEAF_FAILED})


@dataclasses.dataclass(frozen=True)
class NetEvent:
    """One observable network event, stamped with simulation time."""

    kind: str
    t: float
    flow: "Flow | None" = None
    link_key: "LinkKey | None" = None
    device: int | None = None
    leaf: int | None = None

    def render(self) -> str:
        """One deterministic text line (golden-trace serialization)."""
        parts = [repr(float(self.t)), self.kind]
        if self.flow is not None:
            f = self.flow
            parts.append(
                f"{f.kind.value}[{f.tag or '-'}] {f.src}->{f.dst} "
                f"{repr(float(f.size))}"
            )
        if self.link_key is not None:
            parts.append("link=" + ":".join(str(x) for x in self.link_key))
        if self.device is not None:
            parts.append(f"dev={self.device}")
        if self.leaf is not None:
            parts.append(f"leaf={self.leaf}")
        return " ".join(parts)


class FlowEventLog:
    """Subscriber that accumulates rendered event lines.

    Usage::

        log = FlowEventLog()
        sim.subscribe(log)
        ...  # run the scenario
        assert log.lines() == golden_file_lines

    ``maxlen`` turns the log into a bounded ring buffer: only the newest
    ``maxlen`` events are retained and ``dropped`` counts evictions — the
    always-on production shape (keep the recent window, never grow without
    bound).  The default (``maxlen=None``) keeps everything, which is what
    the golden-trace tests rely on.
    """

    def __init__(self, maxlen: int | None = None):
        from collections import deque

        self.events: "deque[NetEvent]" = deque(maxlen=maxlen)
        self.maxlen = maxlen
        self.dropped = 0

    def __call__(self, ev: NetEvent) -> None:
        if self.maxlen is not None and len(self.events) == self.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def lines(self) -> list[str]:
        return [ev.render() for ev in self.events]

    def dump(self) -> str:
        return "\n".join(self.lines()) + "\n"

    def count(self, kind: str) -> int:
        return sum(1 for ev in self.events if ev.kind == kind)

    def iter_kinds(self, *kinds: str):
        """Iterate retained events whose kind is in ``kinds`` (e.g.
        ``log.iter_kinds(*FAILURE_KINDS)`` for the replan-worthy subset)."""
        want = frozenset(kinds)
        return (ev for ev in self.events if ev.kind in want)

    @property
    def oldest_t(self) -> float | None:
        """Timestamp of the oldest RETAINED event (None when empty) — a
        window reader compares it against its window start to detect that
        eviction already ate into the window."""
        return self.events[0].t if self.events else None

    def since(self, t: float) -> list[NetEvent]:
        """Retained events at/after ``t`` (the flight-recorder window)."""
        return [ev for ev in self.events if ev.t >= t]

    def truncated_since(self, t: float) -> bool:
        """True when events at/after ``t`` are KNOWN to have been evicted:
        the ring has dropped events and the oldest retained one is already
        inside the window (or nothing survives at all)."""
        if self.dropped == 0:
            return False
        return self.oldest_t is None or self.oldest_t > t
