"""Typed flows riding the shared flow-level network simulator.

Every byte BLITZSCALE moves over the compute network is one of a small set
of flow types; unifying them in one simulator is what lets a cold start, a
live scale-up and a KV-cache drain contend on the same leaf uplink — the
interference Algorithm 11 is designed to dodge:

  * ``MULTICAST_HOP`` — one hop of a serial forwarding chain (§5.1);
  * ``ALLGATHER``     — the intra-scale-up AllGather completing a Fig. 14
                        parallel sharded transfer;
  * ``KV_MIGRATION``  — frozen KV pages prefill->decode (§2.1, §5.4);
  * ``COLD_START``    — unicast parameter load from the O(1) host copy (or
                        an interference-ignorant GPU copy — the "+Network"
                        ablation baseline);
  * ``SERVING``       — live KVCache serving traffic that scaling flows
                        must not collide with.  Request-granular since the
                        latency-model PR: one finite flow per finished
                        prefill, sized at the request's ACTUAL KV volume
                        (``prompt_tokens x kv_bytes_per_token``).  A size of
                        ``math.inf`` still denotes the legacy persistent
                        background stream (it never completes, it only takes
                        its max-min share) — the PR-3 configuration the
                        golden-trace regression test pins.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Callable

from repro.net.links import Link


class FlowKind(enum.Enum):
    MULTICAST_HOP = "multicast_hop"
    ALLGATHER = "allgather"
    KV_MIGRATION = "kv_migration"
    COLD_START = "cold_start"
    SERVING = "serving"


@dataclasses.dataclass(eq=False)
class Flow:
    """One src->dst transfer; rate is assigned by the simulator's max-min
    fair sharing and changes whenever the set of competing flows does."""

    kind: FlowKind
    src: int
    dst: int
    size: float  # bytes; math.inf = persistent background flow
    payload: Any = None
    on_complete: Callable[["Flow", float], None] | None = None
    on_abort: Callable[["Flow", float], None] | None = None
    tag: str = ""
    # extra first-byte latency charged on top of the routed path's own
    # propagation + switching terms — multicast executions use it to give
    # chain hop k the cumulative latency of its upstream hops (a pipelined
    # forwarding chain cannot deliver byte 0 at depth k before k store-and-
    # forward stages have elapsed)
    extra_latency_s: float = 0.0
    # causal position inside a multicast execution (chain index / edge depth);
    # None for every non-multicast flow.  Purely observational: the tracer
    # bridge stamps these onto hop spans so a critical-path analyzer can
    # reconstruct the forwarding DAG without tree-parenting overlapping
    # pipelined hops under each other.
    chain: int | None = None
    hop: int | None = None

    # -- simulator-managed state --------------------------------------------
    remaining: float = dataclasses.field(init=False)
    transferred: float = 0.0
    rate: float = 0.0  # bytes/s under the current max-min allocation
    started_at: float | None = None
    finished_at: float | None = None
    aborted: bool = False
    # first-byte setup: while ``active_at`` is in the future the flow is
    # propagating (rate 0, contends with nobody); None = active immediately
    # (the zero-latency configuration never sets it, keeping that code path
    # bit-for-bit identical to the pure bandwidth model)
    active_at: float | None = None
    path: list[Link] = dataclasses.field(default_factory=list, repr=False)
    # admission order on the simulator (ties in the event calendar break on
    # it, and every introspection API sorts by it so results keep the
    # engine's start order regardless of index layout); -1 = never admitted
    seq: int = dataclasses.field(init=False, default=-1, repr=False)
    # calendar generation: bumped whenever the flow's projected event time
    # goes stale (rate change, reroute, removal) — heap entries carrying an
    # older generation are discarded lazily on pop
    cal_gen: int = dataclasses.field(init=False, default=0, repr=False)

    def __post_init__(self):
        self.remaining = float(self.size)

    @property
    def background(self) -> bool:
        return not math.isfinite(self.size)

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    def eta(self, now: float) -> float:
        """Finish time under the CURRENT rate (changes on any flow event)."""
        if self.done:
            return self.finished_at
        if self.rate <= 0.0 or self.background:
            return math.inf
        return now + self.remaining / self.rate
