"""Flow-level *execution* of an Algorithm-11 multicast plan.

Planning stays greedy (``repro.core.multicast.plan_multicast``); this module
turns the resulting chains into typed flows on the shared :class:`FlowSim`,
so *realized* transfer times reflect whatever serving / migration / cold-
start traffic is live — instead of the plan's dedicated-link estimate.

Each chain edge becomes ``sharded_ways`` parallel ``MULTICAST_HOP`` flows of
``|M| / ways`` bytes (the Fig. 14 parallel sharded transfer), plus the
intra-scale-up ``ALLGATHER`` flows that re-assemble the full copy on the
receiving domain.  Pipelined forwarding (Fig. 13a) is approximated at flow
granularity: every hop streams concurrently, and a node is *ready* when its
incoming hop has finished AND its upstream node is ready — under dedicated
links every hop runs at the bottleneck rate and the whole chain completes
in ``|M| / B`` like the analytic model; under contention the max over the
chain prefix is exact for a stable bottleneck.

Failure handling: if any hop's link fails without a surviving route, the
whole execution aborts (remaining hops are withdrawn) and ``on_abort``
fires — the caller (ClusterRuntime / Simulator) re-plans from surviving
sources.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.multicast import MulticastPlan, Node
from repro.net.flows import Flow, FlowKind
from repro.net.flowsim import FlowSim


@dataclasses.dataclass
class _EdgeState:
    chain_idx: int
    edge_idx: int
    flows: list[Flow]
    pending: int
    done_at: float | None = None


class MulticastExecution:
    """One plan's in-flight transfer: flows + per-node readiness tracking."""

    def __init__(
        self,
        plan: MulticastPlan,
        model_bytes: int,
        *,
        on_node_ready: Callable[[Node, float], None] | None = None,
        on_done: Callable[["MulticastExecution", float], None] | None = None,
        on_abort: Callable[["MulticastExecution", float], None] | None = None,
        tracer=None,
        parent_span=None,
    ):
        self.plan = plan
        self.model_bytes = model_bytes
        self.on_node_ready = on_node_ready
        self.on_done = on_done
        self.on_abort = on_abort
        # duck-typed (repro.obs.Tracer-shaped) to keep repro.net free of an
        # obs import; None / disabled tracers cost one attribute check
        self.tracer = tracer if tracer is not None and tracer.enabled else None
        self.parent_span = parent_span
        self.sim: FlowSim | None = None
        self.flows: list[Flow] = []
        self.edges: list[_EdgeState] = []
        self._edge_of: dict[int, _EdgeState] = {}  # id(flow) -> edge state
        self.node_ready_at: dict[Node, float] = {}
        self.done_at: float | None = None
        self.aborted = False
        self._build()

    def _build(self) -> None:
        for ci, chain in enumerate(self.plan.chains):
            for ei, edge in enumerate(chain.edges):
                ways = max(1, edge.sharded_ways)
                pairs = list(
                    zip(edge.src.device_ids[:ways], edge.dst.device_ids[:ways])
                )
                hop_bytes = self.model_bytes / len(pairs)
                flows = [
                    Flow(
                        FlowKind.MULTICAST_HOP,
                        s,
                        d,
                        hop_bytes,
                        on_complete=self._flow_done,
                        on_abort=self._flow_aborted,
                        tag=f"chain{ci}.hop{ei}",
                        chain=ci,
                        hop=ei,
                    )
                    for s, d in pairs
                ]
                if len(pairs) > 1 or edge.dst.size > len(pairs):
                    # Fig. 14: every receiving device AllGathers the shards
                    # it did not receive over the scale-up fabric (members
                    # beyond the sharded pairs pull the full copy there)
                    anchor = edge.dst.device_ids[0]
                    other = (
                        edge.dst.device_ids[1] if edge.dst.size > 1 else anchor
                    )
                    for j, d in enumerate(edge.dst.device_ids):
                        frac = (
                            (len(pairs) - 1) / len(pairs) if j < len(pairs) else 1.0
                        )
                        if frac <= 0.0:
                            continue
                        flows.append(
                            Flow(
                                FlowKind.ALLGATHER,
                                anchor if d != anchor else other,
                                d,
                                self.model_bytes * frac,
                                on_complete=self._flow_done,
                                on_abort=self._flow_aborted,
                                tag=f"chain{ci}.allgather{ei}",
                                chain=ci,
                                hop=ei,
                            )
                        )
                st = _EdgeState(ci, ei, flows, pending=len(flows))
                self.edges.append(st)
                for f in flows:
                    self._edge_of[id(f)] = st
                self.flows.extend(flows)

    # -- lifecycle -----------------------------------------------------------
    def start(self, sim: FlowSim, now: float | None = None) -> "MulticastExecution":
        self.sim = sim
        if now is not None:
            sim.advance_to(now)
        # source nodes are ready by definition
        for chain in self.plan.chains:
            if chain.nodes:
                self.node_ready_at[chain.nodes[0]] = sim.now
        if not self.flows:
            self.done_at = sim.now
            if self.on_done:
                self.on_done(self, sim.now)
            return self
        self._charge_chain_latency(sim)
        sim.start_many(self.flows)
        return self

    def _charge_chain_latency(self, sim: FlowSim) -> None:
        """Under the latency model, hop ``k`` of a pipelined forwarding
        chain cannot deliver its first byte before the store-and-forward
        latencies of hops ``0..k-1`` have elapsed — charge each hop the
        cumulative latency of its upstream edges as ``extra_latency_s``.
        Parallel sharded sibling flows of one edge are shards of the SAME
        store-and-forward stage, so downstream hops pay the slowest
        sibling (``max``), and each hop budgets ``FlowSim.hop_latency`` —
        the worst latency across live spine planes, since routing picks
        planes by load, not latency: the per-flow charge (its actual
        routed path + this prefix) can never exceed what downstream hops
        budgeted for it, which is what keeps hop-k first bytes causally
        behind hop-(k-1) under heterogeneous per-plane profiles.  This is
        the same per-hop value the latency-aware planner sums, so analytic
        ``MulticastPlan.transfer_seconds`` matches realized completion on
        uncontended networks.  Zero-latency networks leave every flow
        untouched."""
        by_chain: dict[int, list[_EdgeState]] = {}
        for st in self.edges:
            by_chain.setdefault(st.chain_idx, []).append(st)
        for states in by_chain.values():
            prefix = 0.0
            for st in sorted(states, key=lambda s: s.edge_idx):
                edge_lat = 0.0
                for f in st.flows:
                    f.extra_latency_s = prefix
                    if f.kind is FlowKind.MULTICAST_HOP:
                        edge_lat = max(edge_lat, sim.hop_latency(f.src, f.dst))
                prefix += edge_lat

    def cancel(self, sim: FlowSim | None = None, now: float | None = None) -> None:
        """Withdraw all outstanding hops without firing abort callbacks
        (the consumer was drained on purpose)."""
        sim = sim or self.sim
        if sim is None:
            return
        self.aborted = True
        for f in self.flows:
            if not f.done and not f.aborted:
                sim.remove(f, now, abort=False)

    # -- flow callbacks ------------------------------------------------------
    def _flow_done(self, flow: Flow, t: float) -> None:
        st = self._edge_of[id(flow)]
        st.pending -= 1
        if st.pending == 0:
            st.done_at = t
            self._propagate(t)

    def _flow_aborted(self, flow: Flow, t: float) -> None:
        if self.aborted:
            return
        self.aborted = True
        for f in self.flows:
            if f is not flow and not f.done and not f.aborted and self.sim:
                self.sim.remove(f, abort=False)
        if self.on_abort:
            self.on_abort(self, t)

    def _propagate(self, t: float) -> None:
        """Walk each chain in order: a node is ready when its incoming edge
        finished and its predecessor is ready (flow-granular pipelining)."""
        by_chain: dict[int, list[_EdgeState]] = {}
        for st in self.edges:
            by_chain.setdefault(st.chain_idx, []).append(st)
        all_done = True
        for ci, chain in enumerate(self.plan.chains):
            prev_ready = self.node_ready_at.get(chain.nodes[0], None)
            for st in sorted(by_chain.get(ci, []), key=lambda s: s.edge_idx):
                node = chain.edges[st.edge_idx].dst
                if st.done_at is None or prev_ready is None:
                    all_done = False
                    break
                ready = max(st.done_at, prev_ready)
                if node not in self.node_ready_at:
                    self.node_ready_at[node] = ready
                    if self.tracer is not None:
                        self.tracer.instant(
                            "layer_arrival", max(ready, t), cat="scale",
                            parent=self.parent_span, chain=ci,
                            devices=list(node.device_ids))
                    if self.on_node_ready:
                        self.on_node_ready(node, max(ready, t))
                prev_ready = self.node_ready_at[node]
        if all_done and self.done_at is None and not self.aborted:
            self.done_at = max(self.node_ready_at.values(), default=t)
            if self.on_done:
                self.on_done(self, t)

    # -- queries -------------------------------------------------------------
    def flows_into(self, dev: int) -> list[Flow]:
        """The parameter hops landing on ``dev`` (AllGather excluded) —
        drives flow-backed :class:`LiveSession` progress."""
        return [
            f for f in self.flows if f.dst == dev and f.kind is FlowKind.MULTICAST_HOP
        ]

    def bytes_into(self, dev: int) -> float:
        return sum(f.transferred for f in self.flows_into(dev))

    @property
    def done(self) -> bool:
        return self.done_at is not None
