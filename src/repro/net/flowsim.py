"""Congestion-aware flow-level network simulator (progressive max-min).

One :class:`FlowSim` serves every bandwidth consumer in the repo — multicast
chain execution, KV-cache migration, cold-start unicast, background serving
streams — over the directed-link graph of :class:`repro.net.links
.NetworkModel`.  Rates follow *progressive filling* max-min fairness:

  repeat until every flow is frozen:
    find the link whose remaining capacity / unfrozen users is smallest;
    freeze those users at that fair share; subtract it along their paths.

This yields the classic invariants (property-tested in tests/test_net.py):
per-link conservation (sum of rates <= capacity), and every flow
bottlenecked on at least one saturated link where no competitor gets more.
The per-ingress fair-share incast model this replaces is the single-link
special case: ``n`` flows into one ingress each get ``BW/n``.

Fleet-scale core (the incremental engine)
-----------------------------------------
The naive engine re-solves progressive filling over EVERY flow on every
arrival/departure/activation and scans every flow per integration step —
O(flows x links) per event, hopeless at thousands of devices.  The default
engine (``incremental=True``) keeps allocations bit-for-bit identical while
re-solving only what an event can actually change:

  * **incremental max-min** — progressive filling decomposes exactly over
    connected components of the flow/link sharing graph (flows in disjoint
    components never compete for a link, so their fill order can't interact
    and the float arithmetic per component is identical to the full solve).
    A maintained link->flows index finds the component of a changed flow by
    BFS; only those flows are re-solved, everyone else keeps their frozen
    rate.  A FULL re-solve still happens on any link-capacity mutation
    (degrade / fail / recover / eviction reroutes) — those can move rates
    in every component at once and are rare scenario events.
  * **event calendar** — a lazy-invalidation heap of projected completion
    and activation times replaces the O(flows) min-scan per step.  Each
    active finite flow carries one entry keyed by the projected time it
    enters its completion epsilon zone; entries go stale (generation bump)
    on any rate change and are discarded on pop.  Because eager per-step
    integration drifts a projection by at most a few ulps between
    refreshes, the engine pops every candidate within a small pad of the
    heap top and evaluates the EXACT per-flow expressions the naive scan
    used (``remaining / rate``, ``active_at - now``) — the min over that
    superset is bit-for-bit the naive scan's min.  Same-timestamp events
    batch exactly as before: every completion inside the epsilon window of
    a step settles in one batch, with one rate re-solve.
  * the same index de-linearizes ``flows_through`` / ``flows_into`` /
    ``utilization``, failure eviction, and the router's plane load count.

``incremental=False`` keeps the pre-refactor reference engine (full solve +
linear scans) — the oracle the equivalence property tests drive and the
baseline ``benchmarks/net_scale.py`` measures speedup against.

Time advances event-by-event: flow start, flow finish, and any scenario
mutation (degrade / fail / recover) are rate-change events; between events
every flow progresses linearly at its frozen rate, so integration is exact.

Latency terms (``link_latency_s`` per-hop propagation, ``switch_latency_s``
per switching element) compose with the bandwidth shares as first-byte
setup time: a starting flow spends its path latency *propagating* — rate
zero, contending with nobody — and only then claims its max-min share, so
an uncontended transfer takes ``latency + size/bandwidth`` exactly.  Both
terms default to zero, in which case behaviour (and floating-point
arithmetic) is identical to the pure bandwidth-sharing model.

Scenario knobs: ``degrade_link`` (bandwidth multiplier), ``fail_link`` /
``fail_device`` / ``fail_leaf`` (flows re-route onto a surviving spine
plane when one exists — emitting ``FLOW_REROUTED`` with their first-byte
latency re-charged for the new path — else abort via their ``on_abort``
callback, the hook Autoscaler/FleetScheduler re-planning hangs off),
``spine_oversub`` (oversubscribed spines) and ``spine_planes`` (parallel
spine planes).

Every lifecycle edge and scenario mutation is also broadcast to
``subscribe``d observers as a :class:`repro.net.events.NetEvent` — the
channel the FleetScheduler uses to react to failures immediately and the
golden-trace regression harness uses to diff seeded runs.
"""

from __future__ import annotations

import heapq
import math
from typing import Callable, Iterable, Sequence

from repro.core.topology import NVLINK_GBPS, Topology
from repro.net import events as ev
from repro.net.events import NetEvent
from repro.net.flows import Flow, FlowKind
from repro.net.links import DEV_IN, DEV_OUT, LEAF_DOWN, LEAF_UP, Link, LinkKey, NetworkModel

_EPS = 1e-9


def flow_done_eps(size: float) -> float:
    """The ONE completion threshold shared by the live engine and the
    what-if estimator: a transfer of ``size`` bytes is done once its
    remaining bytes drop to ``_EPS * max(size, 1.0)``.  Keeping both sides
    on this helper is what lets ``estimate_transfer_time`` and realized
    completion agree on tiny flows (the planner's <=1% guarantee)."""
    return _EPS * max(size, 1.0)


def maxmin_rates(paths: Sequence[Sequence[Link]]) -> list[float]:
    """Progressive-filling max-min allocation for ``paths[i]`` = the links
    flow ``i`` crosses.  Pure function — shared by the live engine (full
    and per-component incremental re-solves) and the non-mutating what-if
    estimator.  Empty paths get ``inf`` (same-device transfers are
    instant)."""
    n = len(paths)
    rates = [0.0] * n
    users: dict[LinkKey, list[int]] = {}
    cap: dict[LinkKey, float] = {}
    for i, path in enumerate(paths):
        for l in path:
            users.setdefault(l.key, []).append(i)
            cap.setdefault(l.key, l.rate_cap)
    # unfrozen-user count per link, maintained across freeze rounds — the
    # same value the original formulation rescanned ``users`` for every
    # round, so shares (and therefore every float) are computed identically
    live = {key: len(idxs) for key, idxs in users.items()}
    unfrozen = {i for i in range(n) if paths[i]}
    for i in range(n):
        if not paths[i]:
            rates[i] = math.inf
    while unfrozen:
        best_key, best_share = None, math.inf
        for key, lv in live.items():
            if lv == 0:
                continue
            share = cap[key] / lv
            if share < best_share:
                best_key, best_share = key, share
        if best_key is None:  # pragma: no cover - every flow has links
            break
        for i in users[best_key]:
            if i not in unfrozen:
                continue
            rates[i] = best_share
            unfrozen.discard(i)
            for l in paths[i]:
                cap[l.key] = max(0.0, cap[l.key] - best_share)
                live[l.key] -= 1
    return rates


class FlowSim:
    """The shared flow-level data plane over one cluster topology."""

    def __init__(
        self,
        topo: Topology,
        *,
        spine_oversub: float = 1.0,
        spine_planes: int = 1,
        scaleup_gbps: float = NVLINK_GBPS,
        link_latency_s: float = 0.0,
        switch_latency_s: float = 0.0,
        link_profiles=None,
        incremental: bool = True,
    ):
        self.net = NetworkModel(
            topo,
            spine_oversub=spine_oversub,
            spine_planes=spine_planes,
            scaleup_gbps=scaleup_gbps,
            link_latency_s=link_latency_s,
            switch_latency_s=switch_latency_s,
            link_profiles=link_profiles,
        )
        self.flows: list[Flow] = []
        self.now = 0.0
        self.completed_count = 0
        self.aborted_count = 0
        #: False selects the pre-refactor reference engine: full max-min on
        #: every event and linear min/done scans.  Allocations and event
        #: streams are bit-for-bit identical either way (property-tested);
        #: the flag exists as the equivalence oracle and the net_scale
        #: benchmark baseline.
        self.incremental = incremental
        self._subscribers: list[Callable[[NetEvent], None]] = []
        # optional link-time ledger (repro.obs.ledger.LinkLedger): accrues
        # per-link bytes/busy-seconds by flow kind on every integration
        # step.  None (the default) keeps the data plane untouched — no
        # events, no extra arithmetic, golden traces bit-for-bit.
        self.ledger = None
        # -- indices (maintained in BOTH engines; the reference engine only
        # uses them where results are provably identical: router plane
        # loads, introspection, failure eviction) -------------------------
        self._next_seq = 0
        self._link_flows: dict[LinkKey, dict[Flow, None]] = {}
        self._src_flows: dict[int, dict[Flow, None]] = {}
        self._dst_flows: dict[int, dict[Flow, None]] = {}
        # -- event calendar (incremental engine only): heap of
        # (projected_t, flow.seq, flow.cal_gen, flow) --------------------
        self._cal: list[tuple[float, int, int, Flow]] = []

    # -- event subscription --------------------------------------------------
    def subscribe(self, cb: Callable[[NetEvent], None]) -> Callable:
        """Deliver every :class:`NetEvent` to ``cb`` in simulation order.
        Returns ``cb`` so ``sim.subscribe(FlowEventLog())`` reads naturally."""
        self._subscribers.append(cb)
        return cb

    def unsubscribe(self, cb: Callable[[NetEvent], None]) -> None:
        if cb in self._subscribers:
            self._subscribers.remove(cb)

    def attach_ledger(self, ledger):
        """Attach a :class:`repro.obs.ledger.LinkLedger` (duck-typed:
        anything with ``accrue_flow(flow, moved_bytes, dt)`` and
        ``note_time(now)``).  Returns the ledger for chaining."""
        self.ledger = ledger
        return ledger

    def _emit(self, kind: str, **kw) -> None:
        if not self._subscribers:
            return
        event = NetEvent(kind, self.now, **kw)
        for cb in list(self._subscribers):
            cb(event)

    # -- latency -------------------------------------------------------------
    @property
    def has_latency(self) -> bool:
        """True when any link carries a latency term — the flag the multicast
        planner keys its latency-aware ranking on (a zero-latency network
        plans bit-for-bit like the pure bandwidth model)."""
        return self.net.has_latency

    def route_latency(self, src: int, dst: int) -> float:
        """Nominal (plane-0) first-byte latency of a src->dst path."""
        return self.net.route_latency(src, dst)

    def hop_latency(self, src: int, dst: int) -> float:
        """Worst-case src->dst first-byte latency across live spine planes —
        what a multicast planner (and a chain execution charging downstream
        hops their upstream store-and-forward delay) should budget per hop:
        routing picks planes by load, not latency, so the slowest live plane
        bounds when the next hop's first byte can move."""
        return self.net.hop_latency(src, dst)

    def _flow_latency(self, flow: Flow) -> float:
        return self.net.path_latency(flow.path) + flow.extra_latency_s

    # -- flow / endpoint indices ---------------------------------------------
    def _index(self, f: Flow) -> None:
        for l in f.path:
            self._link_flows.setdefault(l.key, {})[f] = None
        self._src_flows.setdefault(f.src, {})[f] = None
        self._dst_flows.setdefault(f.dst, {})[f] = None

    def _unindex(self, f: Flow) -> None:
        for l in f.path:
            d = self._link_flows.get(l.key)
            if d is not None:
                d.pop(f, None)
                if not d:
                    del self._link_flows[l.key]
        for table, key in ((self._src_flows, f.src), (self._dst_flows, f.dst)):
            d = table.get(key)
            if d is not None:
                d.pop(f, None)
                if not d:
                    del table[key]

    def _set_path(self, f: Flow, path: list[Link]) -> None:
        """Reroute ``f`` onto ``path``, keeping the link index coherent."""
        for l in f.path:
            d = self._link_flows.get(l.key)
            if d is not None:
                d.pop(f, None)
                if not d:
                    del self._link_flows[l.key]
        f.path = path
        for l in path:
            self._link_flows.setdefault(l.key, {})[f] = None

    # -- event calendar -------------------------------------------------------
    # Projected keys are refreshed lazily: eager per-step integration drifts
    # a completion projection by ulps between refreshes, so every pop-side
    # consumer evaluates candidates within _cal_pad of the boundary with the
    # exact per-flow expressions and treats the key only as an ordering hint.
    def _cal_pad(self, scale: float = 0.0) -> float:
        return 1e-7 * (1.0 + abs(self.now) + scale)

    def _cal_push(self, f: Flow) -> None:
        """(Re)schedule ``f``'s calendar entry, invalidating any prior one.
        Completion entries are keyed by the projected time the flow enters
        its done-epsilon zone; activation entries by the exact activation
        time.  Background flows and stalled (rate-0) flows outside the done
        zone schedule nothing — they impose no future event."""
        f.cal_gen += 1
        if f.active_at is not None:
            heapq.heappush(self._cal, (f.active_at, f.seq, f.cal_gen, f))
            return
        if f.background:
            return
        eps = flow_done_eps(f.size)
        if f.rate > 0.0:
            key = self.now + (f.remaining - eps) / f.rate
        elif f.remaining <= eps:
            key = self.now  # already in the done zone, just stalled
        else:
            return
        heapq.heappush(self._cal, (key, f.seq, f.cal_gen, f))
        if len(self._cal) > 64 and len(self._cal) > 4 * len(self.flows):
            self._cal = [e for e in self._cal if e[2] == e[3].cal_gen]
            heapq.heapify(self._cal)

    def _next_dt(self) -> float:
        """Exact min over flows of ``active_at - now`` (propagating) and
        ``remaining / rate`` (active) — bit-for-bit the reference engine's
        linear scan, computed from the calendar: pop every candidate whose
        key could still beat the best exact value, evaluate it exactly,
        re-push with a refreshed key."""
        cal = self._cal
        best = math.inf
        bound = math.inf
        repush: list[Flow] = []
        while cal:
            key, _, gen, f = cal[0]
            if gen != f.cal_gen:
                heapq.heappop(cal)
                continue
            if key - self.now > bound:
                break
            heapq.heappop(cal)
            if f.active_at is not None:
                d = f.active_at - self.now
            elif f.rate > 0.0:
                d = f.remaining / f.rate
            else:
                d = math.inf  # stalled in the done zone: no dt event
            repush.append(f)
            if d < best:
                best = d
                bound = best + self._cal_pad(best)
        for f in repush:
            self._cal_push(f)
        return best

    def _collect_done(self) -> list[Flow]:
        """Flows in the completion-epsilon zone, in admission order — the
        exact ``remaining <= eps`` test the reference engine scans for,
        applied only to calendar candidates at/near the current time."""
        cal = self._cal
        pad = self._cal_pad()
        done: list[Flow] = []
        repush: list[Flow] = []
        while cal:
            key, _, gen, f = cal[0]
            if gen != f.cal_gen:
                heapq.heappop(cal)
                continue
            if key - self.now > pad:
                break
            heapq.heappop(cal)
            if (
                f.active_at is None
                and not f.background
                and f.remaining <= flow_done_eps(f.size)
            ):
                done.append(f)
            else:
                repush.append(f)
        for f in repush:
            self._cal_push(f)
        done.sort(key=lambda f: f.seq)
        return done

    # -- routing -------------------------------------------------------------
    def _route(self, src: int, dst: int) -> list[Link] | None:
        """Pick a live path: for cross-leaf flows, the spine plane with the
        fewest active flows among non-failed planes.  None = no live path.
        Plane load is read from the link->flows index (one dict-len per
        spine link) instead of scanning every flow."""
        best, best_load = None, None
        for p in range(self.net.spine_planes):
            path = self.net.path(src, dst, plane=p)
            if any(l.failed for l in path):
                continue
            load = sum(
                len(self._link_flows.get(l.key, ())) for l in path if l.is_spine
            )
            if best is None or load < best_load:
                best, best_load = path, load
            if len(path) <= 2:  # intra-leaf / intra-scale-up: plane-independent
                break
        return best

    def device_ok(self, dev: int) -> bool:
        return self.net.device_ok(dev)

    def dead_devices(self) -> set[int]:
        """Accelerators whose NIC (either direction) is failed — the ONE
        definition of 'dead' every failure-subscription control plane
        (FleetScheduler, standalone ClusterRuntime) tears down against."""
        return {
            d.id
            for d in self.net.topo.devices
            if not d.is_host and not self.net.device_ok(d.id)
        }

    # -- flow lifecycle ------------------------------------------------------
    def start(self, flow: Flow, now: float | None = None) -> Flow:
        """Begin a transfer.  Advances to ``now`` first, so rates of already-
        running flows are settled before the newcomer claims its share."""
        self.start_many([flow], now)
        return flow

    def start_many(self, flows: Sequence[Flow], now: float | None = None) -> list[Flow]:
        """Begin a batch of transfers with ONE rate recomputation at the end
        — a multi-chain multicast plan joining a loaded network would
        otherwise run a progressive-filling pass per hop."""
        if now is not None:
            self.advance_to(now)
        instant: list[Flow] = []
        aborted: list[Flow] = []
        fresh_active: list[Flow] = []
        for flow in flows:
            flow.started_at = self.now
            self._emit(ev.FLOW_STARTED, flow=flow)  # every abort/completion
            path = self._route(flow.src, flow.dst)  # has a matching start
            if path is None:
                aborted.append(flow)
                continue
            flow.path = path
            if not path or flow.remaining <= 0.0:
                instant.append(flow)  # same-device (or empty) transfer
                continue
            lat = self._flow_latency(flow)
            if lat > 0.0:
                flow.active_at = self.now + lat  # first-byte setup
            flow.seq = self._next_seq
            self._next_seq += 1
            self.flows.append(flow)
            self._index(flow)
            if self.incremental:
                if flow.active_at is None:
                    fresh_active.append(flow)
                else:
                    flow.rate = 0.0  # propagating: contends with nobody
                    self._cal_push(flow)  # activation entry
        if self.incremental:
            if fresh_active:
                self._recompute(seeds=fresh_active)
        else:
            self._recompute()
        for flow in instant:
            flow.transferred = flow.size if math.isfinite(flow.size) else 0.0
            flow.remaining = 0.0
            flow.finished_at = self.now
            self.completed_count += 1
            if flow.on_complete:
                flow.on_complete(flow, self.now)
            self._emit(ev.FLOW_COMPLETED, flow=flow)
        for flow in aborted:
            self._abort(flow)
        return list(flows)

    def remove(self, flow: Flow, now: float | None = None, *, abort: bool = True) -> None:
        """Withdraw a flow (e.g. its consumer was drained).  ``abort=True``
        fires the flow's on_abort callback."""
        if now is not None:
            self.advance_to(now)
        if flow not in self.flows:
            return
        self.flows.remove(flow)
        self._unindex(flow)
        flow.cal_gen += 1  # drop any calendar entry
        if self.incremental:
            if flow.active_at is None:
                self._recompute(seeds=[flow])
            # a propagating flow held no bandwidth: nothing to re-solve
        else:
            self._recompute()
        if abort:
            self._abort(flow, removed=True)

    def _abort(self, flow: Flow, *, removed: bool = False) -> None:
        flow.aborted = True
        self.aborted_count += 1
        if flow.on_abort:
            flow.on_abort(flow, self.now)
        self._emit(ev.FLOW_ABORTED, flow=flow)

    # -- time ----------------------------------------------------------------
    def _done_eps(self, flow: Flow) -> float:
        return flow_done_eps(flow.size)

    def _activate_pending(self) -> bool:
        """Flip flows whose first-byte setup latency has elapsed into the
        contending set.  Returns True when any activation happened (rates
        were re-filled)."""
        if not self.incremental:
            hit = [
                f for f in self.flows
                if f.active_at is not None and f.active_at - self.now <= _EPS
            ]
            if not hit:
                return False
            for f in hit:
                f.active_at = None
            self._recompute()
            return True
        # calendar engine: due activations sit at (or near) the heap top;
        # completion entries inside the same window are re-pushed untouched
        cal = self._cal
        hit: list[Flow] = []
        repush: list[Flow] = []
        while cal:
            key, _, gen, f = cal[0]
            if gen != f.cal_gen:
                heapq.heappop(cal)
                continue
            if key - self.now > _EPS:
                break
            heapq.heappop(cal)
            if f.active_at is not None and f.active_at - self.now <= _EPS:
                hit.append(f)
            else:
                repush.append(f)
        for f in repush:
            self._cal_push(f)
        if not hit:
            return False
        for f in hit:
            f.active_at = None
        self._recompute(seeds=hit)
        return True

    def advance_to(self, now: float) -> list[Flow]:
        """Integrate to ``now``, settling completions (and latency-model
        activations) at their exact event times (rates are re-filled after
        every event).  Returns flows completed in completion order.  The
        incremental engine batches every same-timestamp completion into one
        settle + one component re-solve, exactly as the reference engine's
        epsilon-window scan did."""
        completed: list[Flow] = []
        self._activate_pending()
        while now - self.now > _EPS:
            if self.incremental:
                dt_evt = self._next_dt()
            else:
                dt_evt = math.inf
                for f in self.flows:
                    if f.active_at is not None:
                        dt_evt = min(dt_evt, f.active_at - self.now)
                    elif not f.background and f.rate > 0.0:
                        dt_evt = min(dt_evt, f.remaining / f.rate)
            step = min(now - self.now, dt_evt)
            if step > 0.0:
                led = self.ledger
                for f in self.flows:
                    if f.active_at is None and f.rate > 0.0:
                        moved = f.rate * step
                        f.transferred += moved
                        if not f.background:
                            f.remaining -= moved
                        if led is not None:
                            led.accrue_flow(f, moved, step)
                self.now += step
            activated = self._activate_pending()
            if self.incremental:
                done = self._collect_done()
            else:
                done = [
                    f for f in self.flows
                    if f.active_at is None
                    and not f.background
                    and f.remaining <= self._done_eps(f)
                ]
            if done:
                for f in done:
                    f.remaining = 0.0
                    f.transferred = float(f.size)
                    f.finished_at = self.now
                    self.flows.remove(f)
                    self._unindex(f)
                    f.cal_gen += 1
                    self.completed_count += 1
                    completed.append(f)
                if self.incremental:
                    self._recompute(seeds=done)
                else:
                    self._recompute()
                for f in done:
                    if f.on_complete:
                        f.on_complete(f, self.now)
                for f in done:
                    self._emit(ev.FLOW_COMPLETED, flow=f)
            if step <= 0.0 and not done and not activated:
                break  # nothing can progress (all flows stalled at rate 0)
        if now > self.now:
            self.now = now
        self._activate_pending()
        if self.ledger is not None:
            self.ledger.note_time(self.now)
        return completed

    def next_event_time(self) -> float | None:
        """When the earliest in-flight flow finishes under current rates (or
        a propagating flow activates and rates change) — where a discrete-
        event driver should schedule its next net poll.  O(candidates) off
        the calendar top in the incremental engine."""
        if not self.incremental:
            ts = [
                self.now + f.remaining / f.rate
                for f in self.flows
                if f.active_at is None and not f.background and f.rate > 0.0
            ]
            ts.extend(f.active_at for f in self.flows if f.active_at is not None)
            return min(ts) if ts else None
        cal = self._cal
        best = math.inf
        bound = math.inf
        repush: list[Flow] = []
        while cal:
            key, _, gen, f = cal[0]
            if gen != f.cal_gen:
                heapq.heappop(cal)
                continue
            if key > bound:
                break
            heapq.heappop(cal)
            if f.active_at is not None:
                t = f.active_at
            elif f.rate > 0.0:
                t = self.now + f.remaining / f.rate
            else:
                t = math.inf  # stalled in the done zone
            repush.append(f)
            if t < best:
                best = t
                bound = best + self._cal_pad(abs(best))
        for f in repush:
            self._cal_push(f)
        return best if math.isfinite(best) else None

    # -- rate allocation -----------------------------------------------------
    def _recompute(self, seeds: Sequence[Flow] | None = None) -> None:
        """Re-solve max-min rates.  ``seeds=None`` (or the reference engine)
        re-solves every flow; otherwise only the connected component of the
        flow/link sharing graph reachable from ``seeds`` — which progressive
        filling provably allocates identically to the full solve, float for
        float."""
        if not self.incremental or seeds is None:
            active = [f for f in self.flows if f.active_at is None]
            rates = maxmin_rates([f.path for f in active])
            for f, r in zip(active, rates):
                f.rate = r
            for f in self.flows:
                if f.active_at is not None:
                    f.rate = 0.0  # still propagating: contends with nobody
            if self.incremental:
                for f in active:
                    self._cal_push(f)
            return
        comp = self._component(seeds)
        if not comp:
            return
        comp.sort(key=lambda f: f.seq)  # full-solve enumeration order
        rates = maxmin_rates([f.path for f in comp])
        for f, r in zip(comp, rates):
            f.rate = r
            self._cal_push(f)

    def _component(self, seeds: Sequence[Flow]) -> list[Flow]:
        """Active flows transitively sharing a link with any seed (seeds
        themselves included only while still indexed — removed flows seed
        their old neighbourhood without rejoining it)."""
        comp: list[Flow] = []
        seen: set[Flow] = set()
        links_done: set[LinkKey] = set()
        stack = list(seeds)
        while stack:
            f = stack.pop()
            for l in f.path:
                if l.key in links_done:
                    continue
                links_done.add(l.key)
                for g in self._link_flows.get(l.key, ()):
                    if g.active_at is None and g not in seen:
                        seen.add(g)
                        comp.append(g)
                        stack.append(g)
        return comp

    # -- scenario knobs ------------------------------------------------------
    def degrade_link(self, key: LinkKey, multiplier: float, now: float | None = None) -> None:
        """Scale a link's capacity (1.0 restores it).  Takes effect as a
        rate-change event at ``now``.  Capacity mutations re-solve the FULL
        allocation — they can shift rates in every component at once."""
        if now is not None:
            self.advance_to(now)
        self.net.link(key).degrade = multiplier
        self._recompute()
        self._emit(ev.LINK_DEGRADED, link_key=key)

    def fail_link(self, key: LinkKey, now: float | None = None) -> list[Flow]:
        """Fail one directed link.  Flows crossing it re-route onto a
        surviving spine plane when possible (emitting FLOW_REROUTED);
        otherwise they abort (their ``on_abort`` fires — the re-planning
        hook).  Returns aborted flows.  Subscribers see LINK_FAILED *after*
        the aborts and reroutes have settled, so a control plane reacting to
        it observes the post-failure network."""
        if now is not None:
            self.advance_to(now)
        link = self.net.link(key)
        link.failed = True
        aborted = self._evict_failed(failed_keys=(key,))
        self._emit(ev.LINK_FAILED, link_key=key)
        return aborted

    def fail_device(self, dev: int, now: float | None = None) -> list[Flow]:
        """Fail a whole device: its NIC links go down AND any flow with the
        device as an endpoint aborts (scale-up fabric hops included — the
        accelerator is gone, not just its scale-out port)."""
        if now is not None:
            self.advance_to(now)
        self.net.link((DEV_OUT, dev)).failed = True
        self.net.link((DEV_IN, dev)).failed = True
        aborted = self._evict_failed(
            dead_devs={dev}, failed_keys=((DEV_OUT, dev), (DEV_IN, dev))
        )
        self._emit(ev.DEVICE_FAILED, device=dev)
        return aborted

    def fail_leaf(self, leaf: int, now: float | None = None) -> list[Flow]:
        """Fail a whole leaf switch: every member NIC and every uplink."""
        if now is not None:
            self.advance_to(now)
        keys: list[LinkKey] = []
        for d in self.net.topo.devices:
            if d.leaf == leaf:
                keys += [(DEV_OUT, d.id), (DEV_IN, d.id)]
        for p in range(self.net.spine_planes):
            keys += [(LEAF_UP, leaf, p), (LEAF_DOWN, leaf, p)]
        for key in keys:
            self.net.link(key).failed = True
        aborted = self._evict_failed(failed_keys=keys)
        self._emit(ev.LEAF_FAILED, leaf=leaf)
        return aborted

    def recover_link(self, key: LinkKey, now: float | None = None) -> None:
        if now is not None:
            self.advance_to(now)
        self.net.link(key).failed = False
        self._recompute()
        self._emit(ev.LINK_RECOVERED, link_key=key)

    def recover_device(self, dev: int, now: float | None = None) -> None:
        if now is not None:
            self.advance_to(now)
        self.net.link((DEV_OUT, dev)).failed = False
        self.net.link((DEV_IN, dev)).failed = False
        self._recompute()
        self._emit(ev.DEVICE_RECOVERED, device=dev)

    def _evict_failed(
        self,
        dead_devs: set[int] = frozenset(),
        failed_keys: Iterable[LinkKey] | None = None,
    ) -> list[Flow]:
        """Settle flows hit by the links in ``failed_keys`` / the devices in
        ``dead_devs``: re-route onto a surviving plane (re-charging first-
        byte latency for flows still propagating, since their budget came
        from the dead path) or abort.  Candidates come from the link and
        endpoint indices — live flows never cross an already-failed link
        (routing and prior evictions guarantee it), so the newly failed
        keys bound the damage.  ``failed_keys=None`` falls back to a full
        sweep."""
        if failed_keys is None and not dead_devs:
            candidates = list(self.flows)
        else:
            cand: dict[Flow, None] = {}
            for key in failed_keys or ():
                for f in self._link_flows.get(key, ()):
                    cand[f] = None
            for dev in sorted(dead_devs):
                for f in self._src_flows.get(dev, ()):
                    cand[f] = None
                for f in self._dst_flows.get(dev, ()):
                    cand[f] = None
            candidates = sorted(cand, key=lambda f: f.seq)
        aborted: list[Flow] = []
        rerouted: list[Flow] = []
        for f in candidates:
            endpoint_dead = f.src in dead_devs or f.dst in dead_devs
            if not endpoint_dead and not any(l.failed for l in f.path):
                continue
            alt = None if endpoint_dead else self._route(f.src, f.dst)
            if alt is not None and alt:
                self._set_path(f, alt)  # re-routed onto a surviving plane
                if f.active_at is not None:
                    # its first byte never escaped the dead path: the setup
                    # charge restarts on the new path's latency
                    f.active_at = self.now + self._flow_latency(f)
                    f.cal_gen += 1
                    if self.incremental:
                        self._cal_push(f)
                rerouted.append(f)
            else:
                self.flows.remove(f)
                self._unindex(f)
                f.cal_gen += 1
                aborted.append(f)
        self._recompute()  # full: capacities and paths changed
        for f in aborted:
            self._abort(f, removed=True)
        for f in rerouted:
            self._emit(ev.FLOW_REROUTED, flow=f)
        return aborted

    # -- what-if estimation (non-mutating) -----------------------------------
    def estimate_transfer_time(
        self, src: int, dst: int, nbytes: float, *, max_events: int = 10_000
    ) -> float:
        """Seconds a hypothetical src->dst transfer of ``nbytes`` would take
        under the CURRENT traffic (existing flows run to completion, no new
        arrivals).  Pure — the live state is untouched.  ``inf`` when no
        live path exists.  Includes the latency model: the hypothetical
        flow (and any still-propagating live flow) only starts claiming
        bandwidth once its first-byte setup has elapsed.  Completion uses
        :func:`flow_done_eps` — the SAME threshold as the live engine, so a
        what-if answer and the realized completion agree even on tiny
        flows.  Used by FleetScheduler placement affinity."""
        path = self._route(src, dst)
        if path is None:
            return math.inf
        if not path or nbytes <= 0:
            return 0.0
        paths = [f.path for f in self.flows]
        rem = [f.remaining for f in self.flows]
        fin = [not f.background for f in self.flows]
        eps = [self._done_eps(f) for f in self.flows]
        # time (from now) at which each flow starts claiming bandwidth
        act = [
            max(0.0, f.active_at - self.now) if f.active_at is not None else 0.0
            for f in self.flows
        ]
        paths.append(list(path))
        rem.append(float(nbytes))
        fin.append(True)
        eps.append(flow_done_eps(float(nbytes)))
        act.append(self.net.path_latency(path))
        target = len(paths) - 1
        t = 0.0
        for _ in range(max_events):
            live = [i for i in range(len(paths)) if act[i] <= t + _EPS]
            rates_live = maxmin_rates([paths[i] for i in live])
            rates = [0.0] * len(paths)
            for i, r in zip(live, rates_live):
                rates[i] = r
            dt = math.inf
            for i in range(len(paths)):
                if act[i] > t + _EPS:
                    dt = min(dt, act[i] - t)  # activation boundary
                elif fin[i] and rates[i] > 0.0:
                    dt = min(dt, rem[i] / rates[i])
            if not math.isfinite(dt):
                return math.inf  # stalled (zero-capacity link on the path)
            t += dt
            done_idx = []
            for i in range(len(paths)):
                if rates[i] > 0.0 and fin[i]:
                    rem[i] -= rates[i] * dt
                    if rem[i] <= eps[i]:
                        done_idx.append(i)
            if target in done_idx:
                return t
            for i in reversed(done_idx):
                del paths[i], rem[i], fin[i], act[i], eps[i]
                if i < target:
                    target -= 1
        return math.inf  # pragma: no cover - event budget exhausted

    # -- introspection -------------------------------------------------------
    def flows_through(self, key: LinkKey) -> list[Flow]:
        return sorted(self._link_flows.get(key, ()), key=lambda f: f.seq)

    def flows_into(self, dev: int, kinds: Iterable[FlowKind] | None = None) -> list[Flow]:
        ks = set(kinds) if kinds is not None else None
        fs = sorted(self._dst_flows.get(dev, ()), key=lambda f: f.seq)
        return [f for f in fs if ks is None or f.kind in ks]

    def utilization(self, key: LinkKey) -> float:
        link = self.net.link(key)
        if link.rate_cap <= 0.0:
            return 0.0
        used = sum(f.rate for f in self.flows_through(key) if math.isfinite(f.rate))
        return used / link.rate_cap
