"""Congestion-aware flow-level network simulator (progressive max-min).

One :class:`FlowSim` serves every bandwidth consumer in the repo — multicast
chain execution, KV-cache migration, cold-start unicast, background serving
streams — over the directed-link graph of :class:`repro.net.links
.NetworkModel`.  Rates follow *progressive filling* max-min fairness:

  repeat until every flow is frozen:
    find the link whose remaining capacity / unfrozen users is smallest;
    freeze those users at that fair share; subtract it along their paths.

This yields the classic invariants (property-tested in tests/test_net.py):
per-link conservation (sum of rates <= capacity), and every flow
bottlenecked on at least one saturated link where no competitor gets more.
The per-ingress fair-share incast model this replaces is the single-link
special case: ``n`` flows into one ingress each get ``BW/n``.

Time advances event-by-event: flow start, flow finish, and any scenario
mutation (degrade / fail / recover) are rate-change events; between events
every flow progresses linearly at its frozen rate, so integration is exact.

Latency terms (``link_latency_s`` per-hop propagation, ``switch_latency_s``
per switching element) compose with the bandwidth shares as first-byte
setup time: a starting flow spends its path latency *propagating* — rate
zero, contending with nobody — and only then claims its max-min share, so
an uncontended transfer takes ``latency + size/bandwidth`` exactly.  Both
terms default to zero, in which case behaviour (and floating-point
arithmetic) is identical to the pure bandwidth-sharing model.

Scenario knobs: ``degrade_link`` (bandwidth multiplier), ``fail_link`` /
``fail_device`` / ``fail_leaf`` (flows re-route onto a surviving spine
plane when one exists, else abort via their ``on_abort`` callback — the
hook Autoscaler/FleetScheduler re-planning hangs off), ``spine_oversub``
(oversubscribed spines) and ``spine_planes`` (parallel spine planes).

Every lifecycle edge and scenario mutation is also broadcast to
``subscribe``d observers as a :class:`repro.net.events.NetEvent` — the
channel the FleetScheduler uses to react to failures immediately and the
golden-trace regression harness uses to diff seeded runs.
"""

from __future__ import annotations

import math
from typing import Callable, Iterable, Sequence

from repro.core.topology import NVLINK_GBPS, Topology
from repro.net import events as ev
from repro.net.events import NetEvent
from repro.net.flows import Flow, FlowKind
from repro.net.links import DEV_IN, DEV_OUT, LEAF_DOWN, LEAF_UP, Link, LinkKey, NetworkModel

_EPS = 1e-9


def maxmin_rates(paths: Sequence[Sequence[Link]]) -> list[float]:
    """Progressive-filling max-min allocation for ``paths[i]`` = the links
    flow ``i`` crosses.  Pure function — shared by the live engine and the
    non-mutating what-if estimator.  Empty paths get ``inf`` (same-device
    transfers are instant)."""
    n = len(paths)
    rates = [0.0] * n
    users: dict[LinkKey, list[int]] = {}
    cap: dict[LinkKey, float] = {}
    for i, path in enumerate(paths):
        for l in path:
            users.setdefault(l.key, []).append(i)
            cap.setdefault(l.key, l.rate_cap)
    unfrozen = {i for i in range(n) if paths[i]}
    for i in range(n):
        if not paths[i]:
            rates[i] = math.inf
    while unfrozen:
        best_key, best_share = None, math.inf
        for key, idxs in users.items():
            live = sum(1 for i in idxs if i in unfrozen)
            if live == 0:
                continue
            share = cap[key] / live
            if share < best_share:
                best_key, best_share = key, share
        if best_key is None:  # pragma: no cover - every flow has links
            break
        for i in users[best_key]:
            if i not in unfrozen:
                continue
            rates[i] = best_share
            unfrozen.discard(i)
            for l in paths[i]:
                cap[l.key] = max(0.0, cap[l.key] - best_share)
    return rates


class FlowSim:
    """The shared flow-level data plane over one cluster topology."""

    def __init__(
        self,
        topo: Topology,
        *,
        spine_oversub: float = 1.0,
        spine_planes: int = 1,
        scaleup_gbps: float = NVLINK_GBPS,
        link_latency_s: float = 0.0,
        switch_latency_s: float = 0.0,
        link_profiles=None,
    ):
        self.net = NetworkModel(
            topo,
            spine_oversub=spine_oversub,
            spine_planes=spine_planes,
            scaleup_gbps=scaleup_gbps,
            link_latency_s=link_latency_s,
            switch_latency_s=switch_latency_s,
            link_profiles=link_profiles,
        )
        self.flows: list[Flow] = []
        self.now = 0.0
        self.completed_count = 0
        self.aborted_count = 0
        self._subscribers: list[Callable[[NetEvent], None]] = []
        # optional link-time ledger (repro.obs.ledger.LinkLedger): accrues
        # per-link bytes/busy-seconds by flow kind on every integration
        # step.  None (the default) keeps the data plane untouched — no
        # events, no extra arithmetic, golden traces bit-for-bit.
        self.ledger = None

    # -- event subscription --------------------------------------------------
    def subscribe(self, cb: Callable[[NetEvent], None]) -> Callable:
        """Deliver every :class:`NetEvent` to ``cb`` in simulation order.
        Returns ``cb`` so ``sim.subscribe(FlowEventLog())`` reads naturally."""
        self._subscribers.append(cb)
        return cb

    def unsubscribe(self, cb: Callable[[NetEvent], None]) -> None:
        if cb in self._subscribers:
            self._subscribers.remove(cb)

    def attach_ledger(self, ledger):
        """Attach a :class:`repro.obs.ledger.LinkLedger` (duck-typed:
        anything with ``accrue_flow(flow, moved_bytes, dt)`` and
        ``note_time(now)``).  Returns the ledger for chaining."""
        self.ledger = ledger
        return ledger

    def _emit(self, kind: str, **kw) -> None:
        if not self._subscribers:
            return
        event = NetEvent(kind, self.now, **kw)
        for cb in list(self._subscribers):
            cb(event)

    # -- latency -------------------------------------------------------------
    @property
    def has_latency(self) -> bool:
        """True when any link carries a latency term — the flag the multicast
        planner keys its latency-aware ranking on (a zero-latency network
        plans bit-for-bit like the pure bandwidth model)."""
        return self.net.has_latency

    def route_latency(self, src: int, dst: int) -> float:
        """Nominal (plane-0) first-byte latency of a src->dst path."""
        return self.net.route_latency(src, dst)

    def hop_latency(self, src: int, dst: int) -> float:
        """Worst-case src->dst first-byte latency across live spine planes —
        what a multicast planner (and a chain execution charging downstream
        hops their upstream store-and-forward delay) should budget per hop:
        routing picks planes by load, not latency, so the slowest live plane
        bounds when the next hop's first byte can move."""
        return self.net.hop_latency(src, dst)

    def _flow_latency(self, flow: Flow) -> float:
        return self.net.path_latency(flow.path) + flow.extra_latency_s

    # -- routing -------------------------------------------------------------
    def _route(self, src: int, dst: int) -> list[Link] | None:
        """Pick a live path: for cross-leaf flows, the spine plane with the
        fewest active flows among non-failed planes.  None = no live path."""
        best, best_load = None, None
        for p in range(self.net.spine_planes):
            path = self.net.path(src, dst, plane=p)
            if any(l.failed for l in path):
                continue
            load = sum(
                1 for f in self.flows for l in f.path if l.key[0] in (LEAF_UP, LEAF_DOWN)
                and l in path
            )
            if best is None or load < best_load:
                best, best_load = path, load
            if len(path) <= 2:  # intra-leaf / intra-scale-up: plane-independent
                break
        return best

    def device_ok(self, dev: int) -> bool:
        return self.net.device_ok(dev)

    def dead_devices(self) -> set[int]:
        """Accelerators whose NIC (either direction) is failed — the ONE
        definition of 'dead' every failure-subscription control plane
        (FleetScheduler, standalone ClusterRuntime) tears down against."""
        return {
            d.id
            for d in self.net.topo.devices
            if not d.is_host and not self.net.device_ok(d.id)
        }

    # -- flow lifecycle ------------------------------------------------------
    def start(self, flow: Flow, now: float | None = None) -> Flow:
        """Begin a transfer.  Advances to ``now`` first, so rates of already-
        running flows are settled before the newcomer claims its share."""
        self.start_many([flow], now)
        return flow

    def start_many(self, flows: Sequence[Flow], now: float | None = None) -> list[Flow]:
        """Begin a batch of transfers with ONE rate recomputation at the end
        — a multi-chain multicast plan joining a loaded network would
        otherwise run a full progressive-filling pass per hop."""
        if now is not None:
            self.advance_to(now)
        instant: list[Flow] = []
        aborted: list[Flow] = []
        for flow in flows:
            flow.started_at = self.now
            self._emit(ev.FLOW_STARTED, flow=flow)  # every abort/completion
            path = self._route(flow.src, flow.dst)  # has a matching start
            if path is None:
                aborted.append(flow)
                continue
            flow.path = path
            if not path or flow.remaining <= 0.0:
                instant.append(flow)  # same-device (or empty) transfer
                continue
            lat = self._flow_latency(flow)
            if lat > 0.0:
                flow.active_at = self.now + lat  # first-byte setup
            self.flows.append(flow)
        self._recompute()
        for flow in instant:
            flow.transferred = flow.size if math.isfinite(flow.size) else 0.0
            flow.remaining = 0.0
            flow.finished_at = self.now
            self.completed_count += 1
            if flow.on_complete:
                flow.on_complete(flow, self.now)
            self._emit(ev.FLOW_COMPLETED, flow=flow)
        for flow in aborted:
            self._abort(flow)
        return list(flows)

    def remove(self, flow: Flow, now: float | None = None, *, abort: bool = True) -> None:
        """Withdraw a flow (e.g. its consumer was drained).  ``abort=True``
        fires the flow's on_abort callback."""
        if now is not None:
            self.advance_to(now)
        if flow not in self.flows:
            return
        self.flows.remove(flow)
        self._recompute()
        if abort:
            self._abort(flow, removed=True)

    def _abort(self, flow: Flow, *, removed: bool = False) -> None:
        flow.aborted = True
        self.aborted_count += 1
        if flow.on_abort:
            flow.on_abort(flow, self.now)
        self._emit(ev.FLOW_ABORTED, flow=flow)

    # -- time ----------------------------------------------------------------
    def _done_eps(self, flow: Flow) -> float:
        return _EPS * max(flow.size, 1.0)

    def _activate_pending(self) -> bool:
        """Flip flows whose first-byte setup latency has elapsed into the
        contending set.  Returns True when any activation happened (rates
        were re-filled)."""
        hit = [
            f for f in self.flows
            if f.active_at is not None and f.active_at - self.now <= _EPS
        ]
        if not hit:
            return False
        for f in hit:
            f.active_at = None
        self._recompute()
        return True

    def advance_to(self, now: float) -> list[Flow]:
        """Integrate to ``now``, settling completions (and latency-model
        activations) at their exact event times (rates are re-filled after
        every event).  Returns flows completed in completion order."""
        completed: list[Flow] = []
        self._activate_pending()
        while now - self.now > _EPS:
            dt_evt = math.inf
            for f in self.flows:
                if f.active_at is not None:
                    dt_evt = min(dt_evt, f.active_at - self.now)
                elif not f.background and f.rate > 0.0:
                    dt_evt = min(dt_evt, f.remaining / f.rate)
            step = min(now - self.now, dt_evt)
            if step > 0.0:
                led = self.ledger
                for f in self.flows:
                    if f.active_at is None and f.rate > 0.0:
                        moved = f.rate * step
                        f.transferred += moved
                        if not f.background:
                            f.remaining -= moved
                        if led is not None:
                            led.accrue_flow(f, moved, step)
                self.now += step
            activated = self._activate_pending()
            done = [
                f for f in self.flows
                if f.active_at is None
                and not f.background
                and f.remaining <= self._done_eps(f)
            ]
            if done:
                for f in done:
                    f.remaining = 0.0
                    f.transferred = float(f.size)
                    f.finished_at = self.now
                    self.flows.remove(f)
                    self.completed_count += 1
                    completed.append(f)
                self._recompute()
                for f in done:
                    if f.on_complete:
                        f.on_complete(f, self.now)
                for f in done:
                    self._emit(ev.FLOW_COMPLETED, flow=f)
            if step <= 0.0 and not done and not activated:
                break  # nothing can progress (all flows stalled at rate 0)
        if now > self.now:
            self.now = now
        self._activate_pending()
        if self.ledger is not None:
            self.ledger.note_time(self.now)
        return completed

    def next_event_time(self) -> float | None:
        """When the earliest in-flight flow finishes under current rates (or
        a propagating flow activates and rates change) — where a discrete-
        event driver should schedule its next net poll."""
        ts = [
            self.now + f.remaining / f.rate
            for f in self.flows
            if f.active_at is None and not f.background and f.rate > 0.0
        ]
        ts.extend(f.active_at for f in self.flows if f.active_at is not None)
        return min(ts) if ts else None

    # -- rate allocation -----------------------------------------------------
    def _recompute(self) -> None:
        active = [f for f in self.flows if f.active_at is None]
        rates = maxmin_rates([f.path for f in active])
        for f, r in zip(active, rates):
            f.rate = r
        for f in self.flows:
            if f.active_at is not None:
                f.rate = 0.0  # still propagating: contends with nobody

    # -- scenario knobs ------------------------------------------------------
    def degrade_link(self, key: LinkKey, multiplier: float, now: float | None = None) -> None:
        """Scale a link's capacity (1.0 restores it).  Takes effect as a
        rate-change event at ``now``."""
        if now is not None:
            self.advance_to(now)
        self.net.link(key).degrade = multiplier
        self._recompute()
        self._emit(ev.LINK_DEGRADED, link_key=key)

    def fail_link(self, key: LinkKey, now: float | None = None) -> list[Flow]:
        """Fail one directed link.  Flows crossing it re-route onto a
        surviving spine plane when possible; otherwise they abort (their
        ``on_abort`` fires — the re-planning hook).  Returns aborted flows.
        Subscribers see LINK_FAILED *after* the aborts have settled, so a
        control plane reacting to it observes the post-failure network."""
        if now is not None:
            self.advance_to(now)
        link = self.net.link(key)
        link.failed = True
        aborted = self._evict_failed()
        self._emit(ev.LINK_FAILED, link_key=key)
        return aborted

    def fail_device(self, dev: int, now: float | None = None) -> list[Flow]:
        """Fail a whole device: its NIC links go down AND any flow with the
        device as an endpoint aborts (scale-up fabric hops included — the
        accelerator is gone, not just its scale-out port)."""
        if now is not None:
            self.advance_to(now)
        self.net.link((DEV_OUT, dev)).failed = True
        self.net.link((DEV_IN, dev)).failed = True
        aborted = self._evict_failed(dead_devs={dev})
        self._emit(ev.DEVICE_FAILED, device=dev)
        return aborted

    def fail_leaf(self, leaf: int, now: float | None = None) -> list[Flow]:
        """Fail a whole leaf switch: every member NIC and every uplink."""
        if now is not None:
            self.advance_to(now)
        for d in self.net.topo.devices:
            if d.leaf == leaf:
                self.net.link((DEV_OUT, d.id)).failed = True
                self.net.link((DEV_IN, d.id)).failed = True
        for p in range(self.net.spine_planes):
            self.net.link((LEAF_UP, leaf, p)).failed = True
            self.net.link((LEAF_DOWN, leaf, p)).failed = True
        aborted = self._evict_failed()
        self._emit(ev.LEAF_FAILED, leaf=leaf)
        return aborted

    def recover_link(self, key: LinkKey, now: float | None = None) -> None:
        if now is not None:
            self.advance_to(now)
        self.net.link(key).failed = False
        self._recompute()
        self._emit(ev.LINK_RECOVERED, link_key=key)

    def recover_device(self, dev: int, now: float | None = None) -> None:
        if now is not None:
            self.advance_to(now)
        self.net.link((DEV_OUT, dev)).failed = False
        self.net.link((DEV_IN, dev)).failed = False
        self._recompute()
        self._emit(ev.DEVICE_RECOVERED, device=dev)

    def _evict_failed(self, dead_devs: set[int] = frozenset()) -> list[Flow]:
        aborted: list[Flow] = []
        for f in list(self.flows):
            endpoint_dead = f.src in dead_devs or f.dst in dead_devs
            if not endpoint_dead and not any(l.failed for l in f.path):
                continue
            alt = None if endpoint_dead else self._route(f.src, f.dst)
            if alt is not None and alt:
                f.path = alt  # re-routed onto a surviving plane
            else:
                self.flows.remove(f)
                aborted.append(f)
        self._recompute()
        for f in aborted:
            self._abort(f, removed=True)
        return aborted

    # -- what-if estimation (non-mutating) -----------------------------------
    def estimate_transfer_time(
        self, src: int, dst: int, nbytes: float, *, max_events: int = 10_000
    ) -> float:
        """Seconds a hypothetical src->dst transfer of ``nbytes`` would take
        under the CURRENT traffic (existing flows run to completion, no new
        arrivals).  Pure — the live state is untouched.  ``inf`` when no
        live path exists.  Includes the latency model: the hypothetical
        flow (and any still-propagating live flow) only starts claiming
        bandwidth once its first-byte setup has elapsed.  Used by
        FleetScheduler placement affinity."""
        path = self._route(src, dst)
        if path is None:
            return math.inf
        if not path or nbytes <= 0:
            return 0.0
        paths = [f.path for f in self.flows]
        rem = [f.remaining for f in self.flows]
        fin = [not f.background for f in self.flows]
        # time (from now) at which each flow starts claiming bandwidth
        act = [
            max(0.0, f.active_at - self.now) if f.active_at is not None else 0.0
            for f in self.flows
        ]
        paths.append(list(path))
        rem.append(float(nbytes))
        fin.append(True)
        act.append(self.net.path_latency(path))
        target = len(paths) - 1
        t = 0.0
        for _ in range(max_events):
            live = [i for i in range(len(paths)) if act[i] <= t + _EPS]
            rates_live = maxmin_rates([paths[i] for i in live])
            rates = [0.0] * len(paths)
            for i, r in zip(live, rates_live):
                rates[i] = r
            dt = math.inf
            for i in range(len(paths)):
                if act[i] > t + _EPS:
                    dt = min(dt, act[i] - t)  # activation boundary
                elif fin[i] and rates[i] > 0.0:
                    dt = min(dt, rem[i] / rates[i])
            if not math.isfinite(dt):
                return math.inf  # stalled (zero-capacity link on the path)
            t += dt
            done_idx = []
            for i in range(len(paths)):
                if rates[i] > 0.0 and fin[i]:
                    rem[i] -= rates[i] * dt
                    if rem[i] <= _EPS * max(rem[i] + rates[i] * dt, 1.0):
                        done_idx.append(i)
            if target in done_idx:
                return t
            for i in reversed(done_idx):
                del paths[i], rem[i], fin[i], act[i]
                if i < target:
                    target -= 1
        return math.inf  # pragma: no cover - event budget exhausted

    # -- introspection -------------------------------------------------------
    def flows_through(self, key: LinkKey) -> list[Flow]:
        return [f for f in self.flows if any(l.key == key for l in f.path)]

    def flows_into(self, dev: int, kinds: Iterable[FlowKind] | None = None) -> list[Flow]:
        ks = set(kinds) if kinds is not None else None
        return [
            f for f in self.flows if f.dst == dev and (ks is None or f.kind in ks)
        ]

    def utilization(self, key: LinkKey) -> float:
        link = self.net.link(key)
        if link.rate_cap <= 0.0:
            return 0.0
        used = sum(f.rate for f in self.flows_through(key) if math.isfinite(f.rate))
        return used / link.rate_cap
