"""Directed-link model of the scale-out network (leaf-spine + scale-up).

The flow simulator shares bandwidth on *directed* links — the paper's
full-duplex cornerstone (Fig. 7c): a device's egress and ingress are two
independent links, so opposite-direction flows never contend.  The graph is
derived from :class:`repro.core.topology.Topology`:

  * every device (accelerator or CPU-host pseudo-device) gets a NIC egress
    link (``DEV_OUT``) and a NIC ingress link (``DEV_IN``) at its scale-out
    bandwidth;
  * every leaf gets per-direction uplinks to the spine (``LEAF_UP`` /
    ``LEAF_DOWN``), sized at the sum of member NIC bandwidth divided by
    ``spine_oversub`` — ``spine_oversub=1`` reproduces the planner's
    non-blocking ECMP assumption (§5.1), larger values model oversubscribed
    spines; ``spine_planes>1`` splits each uplink into parallel planes so a
    failed plane can re-route instead of aborting;
  * every scale-up (NVLink/ICI) domain gets one shared fabric link
    (``SCALEUP``) at aggregate NVLink bandwidth — intra-domain hops use it
    instead of the scale-out NICs, so they are near-free but still modelled.

Scenario knobs live on the :class:`Link`: ``degrade`` multiplies capacity
(a flapping or rate-limited link) and ``failed`` zeroes it (the flow
simulator re-routes or aborts flows crossing a failed link).

Latency model: every link carries a propagation delay (``prop_delay_s``)
and every switching element between two consecutive links on a path adds
``switch_latency_s`` — so a cross-leaf path (NIC egress → leaf uplink →
leaf downlink → NIC ingress) pays 4 propagation terms + 3 switching terms,
an intra-leaf path pays 2 + 1, and the scale-up fabric pays only its own
propagation.  :meth:`NetworkModel.path_latency` composes them; the flow
simulator charges the total as first-byte setup time before a flow starts
claiming its max-min bandwidth share, so small transfers (per-request KV
pages, per-layer multicast messages) become latency-dominated while bulk
transfers stay bandwidth-dominated.  Both terms default to zero, which
reproduces the pure bandwidth-sharing model exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.topology import NVLINK_GBPS, Topology, gbps_to_bytes_per_s

DEV_OUT = "dev_out"  # device NIC egress -> leaf switch
DEV_IN = "dev_in"  # leaf switch -> device NIC ingress
LEAF_UP = "leaf_up"  # leaf -> spine (per plane)
LEAF_DOWN = "leaf_down"  # spine -> leaf (per plane)
SCALEUP = "scaleup"  # shared NVLink/ICI fabric of one scale-up domain

LinkKey = tuple  # (kind, id) or (kind, id, plane)


@dataclasses.dataclass
class Link:
    """One directed link with its scenario state."""

    key: LinkKey
    capacity: float  # bytes/s nominal
    degrade: float = 1.0  # bandwidth multiplier (degraded-link scenario)
    failed: bool = False
    prop_delay_s: float = 0.0  # per-hop propagation delay (latency model)

    @property
    def rate_cap(self) -> float:
        return 0.0 if self.failed else self.capacity * self.degrade

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = "FAILED" if self.failed else (
            f"x{self.degrade:g}" if self.degrade != 1.0 else "ok"
        )
        return f"Link({self.key}, {self.capacity:.3g} B/s, {state})"


class NetworkModel:
    """The directed-link graph + deterministic path routing."""

    def __init__(
        self,
        topo: Topology,
        *,
        spine_oversub: float = 1.0,
        spine_planes: int = 1,
        scaleup_gbps: float = NVLINK_GBPS,
        link_latency_s: float = 0.0,
        switch_latency_s: float = 0.0,
    ):
        if spine_planes < 1:
            raise ValueError("spine_planes must be >= 1")
        if link_latency_s < 0.0 or switch_latency_s < 0.0:
            raise ValueError("latency terms must be >= 0")
        self.topo = topo
        self.spine_planes = spine_planes
        self.link_latency_s = link_latency_s
        self.switch_latency_s = switch_latency_s
        self.links: dict[LinkKey, Link] = {}
        leaf_bw: dict[int, float] = {}
        for d in topo.devices:
            bw = gbps_to_bytes_per_s(d.bw_gbps)
            self._add((DEV_OUT, d.id), bw)
            self._add((DEV_IN, d.id), bw)
            leaf_bw[d.leaf] = leaf_bw.get(d.leaf, 0.0) + bw
        for leaf, bw in leaf_bw.items():
            per_plane = bw / spine_oversub / spine_planes
            for p in range(spine_planes):
                self._add((LEAF_UP, leaf, p), per_plane)
                self._add((LEAF_DOWN, leaf, p), per_plane)
        groups: dict[int, int] = {}
        for d in topo.devices:
            if not d.is_host:
                groups[d.scaleup] = groups.get(d.scaleup, 0) + 1
        for su, n in groups.items():
            self._add((SCALEUP, su), gbps_to_bytes_per_s(scaleup_gbps) * n)

    def _add(self, key: LinkKey, capacity: float) -> None:
        self.links[key] = Link(key, capacity, prop_delay_s=self.link_latency_s)

    def link(self, key: LinkKey) -> Link:
        return self.links[key]

    def path_latency(self, path: Sequence[Link]) -> float:
        """First-byte latency of a path: per-hop propagation plus one
        switching delay per element between consecutive links.  Empty paths
        (same-device transfers) have zero latency."""
        if not path:
            return 0.0
        return (
            sum(l.prop_delay_s for l in path)
            + self.switch_latency_s * (len(path) - 1)
        )

    # -- routing -------------------------------------------------------------
    def path(self, src: int, dst: int, *, plane: int = 0) -> list[Link]:
        """The (single, deterministic) path of a src->dst flow on spine
        ``plane``.  Same-device flows have an empty path (instant)."""
        if src == dst:
            return []
        a, b = self.topo.device(src), self.topo.device(dst)
        if a.scaleup == b.scaleup and not a.is_host and not b.is_host:
            return [self.links[(SCALEUP, a.scaleup)]]
        p = [self.links[(DEV_OUT, src)]]
        if a.leaf != b.leaf:
            p.append(self.links[(LEAF_UP, a.leaf, plane)])
            p.append(self.links[(LEAF_DOWN, b.leaf, plane)])
        p.append(self.links[(DEV_IN, dst)])
        return p

    def device_ok(self, dev: int) -> bool:
        """False when the device's NIC (either direction) is failed — such a
        device cannot be a transfer endpoint and should not be provisioned."""
        return not (
            self.links[(DEV_OUT, dev)].failed or self.links[(DEV_IN, dev)].failed
        )
