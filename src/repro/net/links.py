"""Directed-link model of the scale-out network (leaf-spine + scale-up).

The flow simulator shares bandwidth on *directed* links — the paper's
full-duplex cornerstone (Fig. 7c): a device's egress and ingress are two
independent links, so opposite-direction flows never contend.  The graph is
derived from :class:`repro.core.topology.Topology`:

  * every device (accelerator or CPU-host pseudo-device) gets a NIC egress
    link (``DEV_OUT``) and a NIC ingress link (``DEV_IN``) at its scale-out
    bandwidth;
  * every leaf gets per-direction uplinks to the spine (``LEAF_UP`` /
    ``LEAF_DOWN``), sized at the sum of member NIC bandwidth divided by
    ``spine_oversub`` — ``spine_oversub=1`` reproduces the planner's
    non-blocking ECMP assumption (§5.1), larger values model oversubscribed
    spines; ``spine_planes>1`` splits each uplink into parallel planes so a
    failed plane can re-route instead of aborting;
  * every scale-up (NVLink/ICI) domain gets one shared fabric link
    (``SCALEUP``) at aggregate NVLink bandwidth — intra-domain hops use it
    instead of the scale-out NICs, so they are near-free but still modelled.

Scenario knobs live on the :class:`Link`: ``degrade`` multiplies capacity
(a flapping or rate-limited link) and ``failed`` zeroes it (the flow
simulator re-routes or aborts flows crossing a failed link).

Latency model: every link carries a propagation delay (``prop_delay_s``)
and a switching delay (``switch_delay_s``) for the switching element a
path traverses to *enter* that link — so a cross-leaf path (NIC egress →
leaf uplink → leaf downlink → NIC ingress) pays 4 propagation terms + 3
switching terms, an intra-leaf path pays 2 + 1, and the scale-up fabric
pays only its own propagation.  :meth:`NetworkModel.path_latency` composes
them; the flow simulator charges the total as first-byte setup time before
a flow starts claiming its max-min bandwidth share, so small transfers
(per-request KV pages, per-layer multicast messages) become
latency-dominated while bulk transfers stay bandwidth-dominated.  Both
terms default to zero, which reproduces the pure bandwidth-sharing model
exactly.

Heterogeneous hardware: the uniform ``link_latency_s`` / ``switch_latency_s``
knobs seed every link identically; ``link_profiles`` overrides individual
links (a slow inter-building uplink, a fast NVLink-class NIC island) with
per-link latency, switching delay and/or bandwidth — see
:class:`LinkProfile`.  A profile keyed ``(LEAF_UP, leaf)`` (no plane)
applies to every spine plane of that uplink.  With no profiles the model
is bit-for-bit the uniform PR-4 arithmetic (golden-trace pinned).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.core.topology import NVLINK_GBPS, Topology, gbps_to_bytes_per_s

DEV_OUT = "dev_out"  # device NIC egress -> leaf switch
DEV_IN = "dev_in"  # leaf switch -> device NIC ingress
LEAF_UP = "leaf_up"  # leaf -> spine (per plane)
LEAF_DOWN = "leaf_down"  # spine -> leaf (per plane)
SCALEUP = "scaleup"  # shared NVLink/ICI fabric of one scale-up domain

LinkKey = tuple  # (kind, id) or (kind, id, plane)


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Per-link override of the uniform latency/bandwidth knobs.

    ``None`` fields keep the uniform value, so a profile can override any
    subset: ``LinkProfile(latency_s=5e-4)`` models a long-haul uplink,
    ``LinkProfile(bandwidth_gbps=400.0)`` a faster NIC generation,
    ``LinkProfile(switch_latency_s=1e-4)`` a slow switching ASIC feeding
    that link."""

    latency_s: float | None = None  # propagation delay of this link
    switch_latency_s: float | None = None  # delay of the element entering it
    bandwidth_gbps: float | None = None  # capacity override


@dataclasses.dataclass
class Link:
    """One directed link with its scenario state."""

    key: LinkKey
    capacity: float  # bytes/s nominal
    degrade: float = 1.0  # bandwidth multiplier (degraded-link scenario)
    failed: bool = False
    prop_delay_s: float = 0.0  # per-hop propagation delay (latency model)
    switch_delay_s: float = 0.0  # switching element traversed to enter this link

    @property
    def rate_cap(self) -> float:
        return 0.0 if self.failed else self.capacity * self.degrade

    @property
    def is_spine(self) -> bool:
        """True for leaf<->spine uplinks/downlinks — the links whose
        population decides load-balanced plane selection in the router."""
        return self.key[0] in (LEAF_UP, LEAF_DOWN)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = "FAILED" if self.failed else (
            f"x{self.degrade:g}" if self.degrade != 1.0 else "ok"  # simcheck: exact-float -- 1.0 is the pristine-link sentinel, set only by assignment
        )
        return f"Link({self.key}, {self.capacity:.3g} B/s, {state})"


class NetworkModel:
    """The directed-link graph + deterministic path routing."""

    def __init__(
        self,
        topo: Topology,
        *,
        spine_oversub: float = 1.0,
        spine_planes: int = 1,
        scaleup_gbps: float = NVLINK_GBPS,
        link_latency_s: float = 0.0,
        switch_latency_s: float = 0.0,
        link_profiles: Mapping[LinkKey, LinkProfile] | None = None,
    ):
        if spine_planes < 1:
            raise ValueError("spine_planes must be >= 1")
        if link_latency_s < 0.0 or switch_latency_s < 0.0:
            raise ValueError("latency terms must be >= 0")
        self.topo = topo
        self.spine_planes = spine_planes
        self.link_latency_s = link_latency_s
        self.switch_latency_s = switch_latency_s
        self.links: dict[LinkKey, Link] = {}
        leaf_bw: dict[int, float] = {}
        for d in topo.devices:
            bw = gbps_to_bytes_per_s(d.bw_gbps)
            self._add((DEV_OUT, d.id), bw)
            self._add((DEV_IN, d.id), bw)
            leaf_bw[d.leaf] = leaf_bw.get(d.leaf, 0.0) + bw
        for leaf, bw in leaf_bw.items():
            per_plane = bw / spine_oversub / spine_planes
            for p in range(spine_planes):
                self._add((LEAF_UP, leaf, p), per_plane)
                self._add((LEAF_DOWN, leaf, p), per_plane)
        groups: dict[int, int] = {}
        for d in topo.devices:
            if not d.is_host:
                groups[d.scaleup] = groups.get(d.scaleup, 0) + 1
        for su, n in groups.items():
            self._add((SCALEUP, su), gbps_to_bytes_per_s(scaleup_gbps) * n)
        self._apply_profiles(link_profiles or {})
        # heterogeneous/uniform latency present at all?  A zero-latency
        # graph routes the planner onto its pure-bandwidth path (bit-for-bit
        # the legacy arithmetic).
        self.has_latency = any(
            l.prop_delay_s > 0.0 or l.switch_delay_s > 0.0
            for l in self.links.values()
        )

    def _add(self, key: LinkKey, capacity: float) -> None:
        self.links[key] = Link(
            key,
            capacity,
            prop_delay_s=self.link_latency_s,
            switch_delay_s=self.switch_latency_s,
        )

    def _apply_profiles(self, profiles: Mapping[LinkKey, LinkProfile]) -> None:
        for key, prof in profiles.items():
            keys = self._expand_profile_key(tuple(key))
            if not keys:
                raise ValueError(f"link_profiles key {key!r} matches no link")
            for field in ("latency_s", "switch_latency_s", "bandwidth_gbps"):
                v = getattr(prof, field)
                if v is not None and v < 0.0:
                    raise ValueError(f"link_profiles[{key!r}].{field} must be >= 0")
            for k in keys:
                link = self.links[k]
                if prof.latency_s is not None:
                    link.prop_delay_s = prof.latency_s
                if prof.switch_latency_s is not None:
                    link.switch_delay_s = prof.switch_latency_s
                if prof.bandwidth_gbps is not None:
                    link.capacity = gbps_to_bytes_per_s(prof.bandwidth_gbps)

    def _expand_profile_key(self, key: LinkKey) -> list[LinkKey]:
        """A profile key is either an exact link key or a plane-less
        ``(LEAF_UP/LEAF_DOWN, leaf)`` shorthand covering every spine plane."""
        if key in self.links:
            return [key]
        if len(key) == 2 and key[0] in (LEAF_UP, LEAF_DOWN):
            planes = [
                (key[0], key[1], p)
                for p in range(self.spine_planes)
                if (key[0], key[1], p) in self.links
            ]
            return planes
        return []

    def link(self, key: LinkKey) -> Link:
        return self.links[key]

    def path_latency(self, path: Sequence[Link]) -> float:
        """First-byte latency of a path: per-hop propagation plus the
        switching delay of every element between consecutive links (charged
        to the link being entered, so heterogeneous profiles compose as a
        per-hop sum).  Empty paths (same-device transfers) have zero
        latency."""
        if not path:
            return 0.0
        return (
            sum(l.prop_delay_s for l in path)
            + sum(l.switch_delay_s for l in path[1:])
        )

    def route_latency(self, src: int, dst: int) -> float:
        """Nominal (plane-0) first-byte latency of a src->dst path — the
        latency view a multicast planner consults per candidate hop."""
        return self.path_latency(self.path(src, dst, plane=0))

    def hop_latency(self, src: int, dst: int) -> float:
        """Worst-case first-byte latency across live spine planes.  Routing
        picks planes by load, not latency, so a store-and-forward stage must
        conservatively budget the slowest live plane for its downstream
        hops.  Falls back to the plane-0 value when every plane is down
        (the flow will abort anyway)."""
        worst, any_live = 0.0, False
        for p in range(self.spine_planes):
            path = self.path(src, dst, plane=p)
            lat = self.path_latency(path)
            if len(path) <= 2:  # intra-leaf / scale-up: plane-independent
                return lat
            if not any(l.failed for l in path):
                any_live = True
                worst = max(worst, lat)
        if any_live:
            return worst
        return self.path_latency(self.path(src, dst, plane=0))

    # -- routing -------------------------------------------------------------
    def path(self, src: int, dst: int, *, plane: int = 0) -> list[Link]:
        """The (single, deterministic) path of a src->dst flow on spine
        ``plane``.  Same-device flows have an empty path (instant)."""
        if src == dst:
            return []
        a, b = self.topo.device(src), self.topo.device(dst)
        if a.scaleup == b.scaleup and not a.is_host and not b.is_host:
            return [self.links[(SCALEUP, a.scaleup)]]
        p = [self.links[(DEV_OUT, src)]]
        if a.leaf != b.leaf:
            p.append(self.links[(LEAF_UP, a.leaf, plane)])
            p.append(self.links[(LEAF_DOWN, b.leaf, plane)])
        p.append(self.links[(DEV_IN, dst)])
        return p

    def device_ok(self, dev: int) -> bool:
        """False when the device's NIC (either direction) is failed — such a
        device cannot be a transfer endpoint and should not be provisioned."""
        return not (
            self.links[(DEV_OUT, dev)].failed or self.links[(DEV_IN, dev)].failed
        )
