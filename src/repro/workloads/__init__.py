"""Workload generators — the bottom of the import DAG.

Synthetic request traces (arrival processes + token-length distributions)
are consumed by every layer above: the simulator drives itself from them,
the serving runtimes replay them, benchmarks sweep them.  They therefore
live *below* ``repro.core`` and ``repro.serving`` so that neither has to
reach upward for a trace (simcheck's layering rule enforces this — this
package may import nothing from ``repro``).

``repro.serving.traces`` remains as a compatibility shim re-exporting
everything here.
"""

from repro.workloads.traces import (
    TRACES,
    azure_code,
    azure_conv,
    burstgpt,
    kv_volumes,
    multi_model_mix,
    request_kv_bytes,
    scale_to_capacity,
    zipf_weights,
)

__all__ = [
    "TRACES",
    "azure_code",
    "azure_conv",
    "burstgpt",
    "kv_volumes",
    "multi_model_mix",
    "request_kv_bytes",
    "scale_to_capacity",
    "zipf_weights",
]
