"""Synthetic trace generators shaped after the paper's three workloads.

The paper evaluates BurstGPT [71], AzureCode and AzureConv [14], scaled with
TraceUpscaler so the average request rate is half the cluster's max serving
capacity (§6).  We reproduce the *shapes* (first column of Fig. 17):

  * **BurstGPT** — sharp 5x bursts within ~2 s on a modest baseline rate,
    recurring every ~100 s;
  * **AzureCode** — two isolated bursts separated by minutes of quiet (the
    gap defeats TTL host caching — §6.1's S-LLM analysis);
  * **AzureConv** — continuously arriving bursts (S-LLM always cache-hits).

Token-length distributions follow the published Azure traces: conversation
prompts ~1024 tokens / outputs ~256; code prompts ~2048 / outputs ~64;
BurstGPT ~512/128 (lognormal).
"""

from __future__ import annotations

import numpy as np


def _lognormal_tokens(rng, mean: float, n: int, lo: int = 16, hi: int = 8192) -> np.ndarray:
    sigma = 0.6
    mu = np.log(mean) - sigma**2 / 2
    return np.clip(rng.lognormal(mu, sigma, n).astype(int), lo, hi)


def _emit(rng, rate_fn, duration: float, prompt_mean: int, output_mean: int,
          ) -> list[tuple[float, int, int]]:
    """Inhomogeneous Poisson arrivals by thinning."""
    peak = max(rate_fn(t) for t in np.linspace(0, duration, 2048))
    t = 0.0
    times = []
    while t < duration:
        t += rng.exponential(1.0 / peak)
        if t < duration and rng.random() < rate_fn(t) / peak:
            times.append(t)
    n = len(times)
    prompts = _lognormal_tokens(rng, prompt_mean, n)
    outputs = _lognormal_tokens(rng, output_mean, n, lo=8, hi=2048)
    return [(float(t), int(p), int(o)) for t, p, o in zip(times, prompts, outputs)]


def burstgpt(duration: float = 300.0, base_rate: float = 2.0, *,
             burst_mult: float = 5.0, burst_every: float = 100.0,
             burst_len: float = 8.0, seed: int = 0) -> list[tuple[float, int, int]]:
    rng = np.random.default_rng(seed)

    def rate(t):
        phase = t % burst_every
        return base_rate * (burst_mult if 5.0 <= phase < 5.0 + burst_len else 1.0)

    return _emit(rng, rate, duration, prompt_mean=512, output_mean=128)


def azure_code(duration: float = 300.0, base_rate: float = 1.5, *,
               seed: int = 1) -> list[tuple[float, int, int]]:
    rng = np.random.default_rng(seed)
    b1, b2 = 0.1 * duration, 0.75 * duration  # two isolated bursts

    def rate(t):
        if b1 <= t < b1 + 10 or b2 <= t < b2 + 10:
            return base_rate * 6.0
        return base_rate * 0.5

    return _emit(rng, rate, duration, prompt_mean=2048, output_mean=64)


def azure_conv(duration: float = 300.0, base_rate: float = 2.0, *,
               seed: int = 2) -> list[tuple[float, int, int]]:
    rng = np.random.default_rng(seed)

    def rate(t):
        # continuous bursts: sinusoidal surges every ~40 s
        import math
        return base_rate * (1.0 + 2.5 * max(0.0, math.sin(2 * math.pi * t / 40.0)) ** 4)

    return _emit(rng, rate, duration, prompt_mean=1024, output_mean=256)


TRACES = {"burstgpt": burstgpt, "azure_code": azure_code, "azure_conv": azure_conv}


# ---------------------------------------------------------------------------
# Multi-model MaaS traces (fleet arbitration / scale-to-zero workloads)
# ---------------------------------------------------------------------------


def zipf_weights(n: int, alpha: float = 1.2) -> np.ndarray:
    """Skewed model popularity: weight of the rank-k model ∝ 1/k^alpha —
    the MaaS regime the paper targets (a few hot models, a long cold tail
    that should spend most of its life scaled to zero)."""
    ranks = np.arange(1, n + 1, dtype=float)
    w = ranks**-alpha
    return w / w.sum()


def multi_model_mix(
    models: list[str],
    *,
    duration: float = 300.0,
    total_rate: float = 4.0,
    alpha: float = 1.2,
    kind: str | dict = "burstgpt",
    stagger: bool = True,
    seed: int = 0,
) -> list[tuple[float, str, int, int]]:
    """Merged fleet trace: each model draws arrivals from ``kind``'s shape
    at a Zipf share of ``total_rate``; returns (t, model, prompt_tokens,
    output_tokens) sorted by time.

    ``kind`` may be a dict mapping model -> trace kind, so per-tenant SLO
    classes get per-tenant shapes in ONE merged trace — e.g. a latency-tier
    chatbot on ``burstgpt`` bursts riding alongside a throughput-tier batch
    model on steady ``azure_conv`` surges (models not in the dict fall back
    to ``burstgpt``).

    ``stagger`` rotates each model's arrivals by a fraction of the horizon
    so bursts peak at *different* times — the premise of fleet sharing:
    aggregate demand is far smoother than any one model's, so a shared pool
    needs far fewer devices than per-model peak provisioning (Fig. 18)."""
    ws = zipf_weights(len(models), alpha)
    merged: list[tuple[float, str, int, int]] = []
    for k, (m, w) in enumerate(zip(models, ws)):
        k_kind = kind.get(m, "burstgpt") if isinstance(kind, dict) else kind
        tr = TRACES[k_kind](duration=duration, base_rate=total_rate * float(w), seed=seed + k)
        off = k * duration / len(models) if stagger else 0.0
        merged.extend(((t + off) % duration, m, p, o) for t, p, o in tr)
    merged.sort()
    return merged


def request_kv_bytes(prompt_tokens: int, kv_bytes_per_token: int) -> int:
    """KV-cache volume one request's prefill produces — the bytes its
    prefill→decode stream actually moves over the network (the simulator's
    per-request serving flows are sized with this, replacing the old
    persistent background streams)."""
    return max(1, int(prompt_tokens)) * int(kv_bytes_per_token)


def kv_volumes(trace: list[tuple[float, int, int]],
               kv_bytes_per_token: int) -> list[int]:
    """Per-request KV stream sizes for a whole trace, in arrival order."""
    return [request_kv_bytes(p, kv_bytes_per_token) for _, p, _ in trace]


def scale_to_capacity(trace: list[tuple[float, int, int]],
                      target_rate: float) -> list[tuple[float, int, int]]:
    """TraceUpscaler-style: rescale arrival times so the mean request rate
    matches ``target_rate`` while preserving the temporal pattern (§6)."""
    if not trace:
        return trace
    duration = trace[-1][0]
    cur = len(trace) / max(duration, 1e-9)
    k = cur / target_rate
    return [(t * k, p, o) for t, p, o in trace]
