"""Decode-time state containers: GQA KV cache, MLA compressed cache, SSM state.

All caches are plain pytrees (dicts of arrays) so they flow through jit /
pjit / scan unchanged and can be sharded with PartitionSpecs.  ``lengths`` is
per-sequence so continuous batching can mix requests at different decode
depths in one batch.

A paged variant (block tables) backs the serving engine; a property test
asserts paged == contiguous numerics.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard


# ---------------------------------------------------------------------------
# Contiguous GQA KV cache
# ---------------------------------------------------------------------------
#
# Optional int8 quantization (§Perf C3): values are stored as
# round(x / s * 127) int8 with per-(batch, kv-head, token) absmax scales
# (B, KV, S) f32.  Dequantization multiplies the attention scores (for K)
# and the combine probabilities (for V) — exact per-token scaling, no
# materialized dequantized cache.


def quantize_kv(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """x -> (int8 values, f32 scales) with absmax scaling along `axis`."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def init_kv_cache(batch: int, max_seq: int, n_kv: int, head_dim: int, dtype,
                  *, quant: bool = False) -> dict:
    """Cache layout is (B, KV, S, D) — seq-major per KV head.  The decode
    dot contracts D with batch dims (B, KV), so this layout feeds the MXU
    directly; the (B, S, KV, D) activation layout would force a physical
    transpose copy of the whole cache every layer (§Perf C1: ~12 ms/step for
    granite decode_32k)."""
    vdtype = jnp.int8 if quant else dtype
    out = {
        "k": jnp.zeros((batch, n_kv, max_seq, head_dim), vdtype),
        "v": jnp.zeros((batch, n_kv, max_seq, head_dim), vdtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    if quant:
        out["k_scale"] = jnp.zeros((batch, n_kv, max_seq), jnp.float32)
        out["v_scale"] = jnp.zeros((batch, n_kv, max_seq), jnp.float32)
    return out


def kv_cache_abstract(batch: int, max_seq: int, n_kv: int, head_dim: int, dtype,
                      *, quant: bool = False) -> dict:
    vdtype = jnp.int8 if quant else dtype
    out = {
        "k": jax.ShapeDtypeStruct((batch, n_kv, max_seq, head_dim), vdtype),
        "v": jax.ShapeDtypeStruct((batch, n_kv, max_seq, head_dim), vdtype),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if quant:
        out["k_scale"] = jax.ShapeDtypeStruct((batch, n_kv, max_seq), jnp.float32)
        out["v_scale"] = jax.ShapeDtypeStruct((batch, n_kv, max_seq), jnp.float32)
    return out


def kv_cache_axes(*, quant: bool = False) -> dict:
    """Logical axes for sharding the cache."""
    out = {
        "k": ("cache_batch", "cache_kv_heads", "cache_seq", "head_dim"),
        "v": ("cache_batch", "cache_kv_heads", "cache_seq", "head_dim"),
        "lengths": ("cache_batch",),
    }
    if quant:
        out["k_scale"] = ("cache_batch", "cache_kv_heads", "cache_seq")
        out["v_scale"] = ("cache_batch", "cache_kv_heads", "cache_seq")
    return out


def write_prompt_kv(cache: dict, k: jax.Array, v: jax.Array, lengths: jax.Array) -> dict:
    """Write a full prompt's K/V (B, S, KV, D activations) at positions
    [0, S) — one transpose at prefill time (amortized over all decodes)."""
    kt = k.transpose(0, 2, 1, 3)  # (B, KV, S, D)
    vt = v.transpose(0, 2, 1, 3)
    out = {"lengths": lengths.astype(jnp.int32)}
    if "k_scale" in cache:
        kq, ks = quantize_kv(kt)
        vq, vs = quantize_kv(vt)
        out["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0))
        out["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0))
        out["k_scale"] = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0, 0))
        out["v_scale"] = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0, 0))
        return out
    out["k"] = jax.lax.dynamic_update_slice(cache["k"], kt.astype(cache["k"].dtype), (0, 0, 0, 0))
    out["v"] = jax.lax.dynamic_update_slice(cache["v"], vt.astype(cache["v"].dtype), (0, 0, 0, 0))
    return out


def append_kv_uniform(cache: dict, k_new: jax.Array, v_new: jax.Array) -> dict:
    """Lockstep append (§Perf C2): all sequences write at the SAME seq
    position (the batch max length).  A dynamic-update-slice at a traced
    *scalar* index partitions cleanly under GSPMD (each seq shard checks
    ownership and writes one row in place) — unlike the per-row masked
    ``where``, which rewrites the whole cache slice every step (~20 ms of
    granite decode_32k's 37.5 ms baseline).  Production engines keep decode
    slots position-aligned for exactly this reason; exact when all lengths
    are equal (the dry-run serve cells), and the attention mask additionally
    admits the shared write position for stragglers."""
    pos = jnp.max(cache["lengths"])  # traced scalar

    def write(buf, new):  # buf: (B, KV, S, D); new: (B, KV, D)
        return jax.lax.dynamic_update_slice(
            buf, new[:, :, None, :].astype(buf.dtype), (0, 0, pos, 0)
        )

    out = {"lengths": cache["lengths"] + 1}
    if "k_scale" in cache:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        out["k"] = write(cache["k"], kq)
        out["v"] = write(cache["v"], vq)
        out["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks[:, :, None], (0, 0, pos))
        out["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs[:, :, None], (0, 0, pos))
        return out
    out["k"] = write(cache["k"], k_new)
    out["v"] = write(cache["v"], v_new)
    return out


def append_kv(cache: dict, k_new: jax.Array, v_new: jax.Array) -> dict:
    """Append one token's K/V (B, KV, D) at each sequence's current length.

    Implemented as a masked ``where`` over the seq axis rather than a
    per-batch scatter: a scatter with runtime indices onto the seq-SHARDED
    cache dim makes GSPMD all-gather the whole cache (measured 4.8 GiB/chip
    per decode step for granite decode_32k); the iota-compare form is
    elementwise, fully partitionable, and fuses into the attention read."""
    idx = cache["lengths"]  # (B,)
    smax = cache["k"].shape[2]
    mask = jnp.arange(smax)[None, None, :, None] == idx[:, None, None, None]

    def write(buf, new):  # new: (B, KV, D) -> broadcast over the seq axis
        return jnp.where(mask, new[:, :, None, :].astype(buf.dtype), buf)

    out = {"lengths": idx + 1}
    if "k_scale" in cache:
        kq, ks = quantize_kv(k_new)  # (B, KV, D) -> int8 + (B, KV)
        vq, vs = quantize_kv(v_new)
        out["k"] = write(cache["k"], kq)
        out["v"] = write(cache["v"], vq)
        smask = mask[..., 0]
        out["k_scale"] = jnp.where(smask, ks[:, :, None], cache["k_scale"])
        out["v_scale"] = jnp.where(smask, vs[:, :, None], cache["v_scale"])
        return out
    out["k"] = write(cache["k"], k_new)
    out["v"] = write(cache["v"], v_new)
    return out


# ---------------------------------------------------------------------------
# MLA compressed cache (latent c_kv + shared rope key per token)
# ---------------------------------------------------------------------------


def init_mla_cache(batch: int, max_seq: int, kv_lora_rank: int, rope_dim: int, dtype) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_seq, kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, rope_dim), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def mla_cache_abstract(batch: int, max_seq: int, kv_lora_rank: int, rope_dim: int, dtype) -> dict:
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_seq, kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, max_seq, rope_dim), dtype),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def mla_cache_axes() -> dict:
    return {
        "ckv": ("cache_batch", "cache_seq", None),
        "krope": ("cache_batch", "cache_seq", None),
        "lengths": ("cache_batch",),
    }


def append_mla_uniform(cache: dict, ckv_new: jax.Array, krope_new: jax.Array) -> dict:
    """Lockstep MLA append — see ``append_kv_uniform`` (§Perf C2)."""
    pos = jnp.max(cache["lengths"])

    def write(buf, new):  # buf: (B, S, R); new: (B, R)
        return jax.lax.dynamic_update_slice(
            buf, new[:, None, :].astype(buf.dtype), (0, pos, 0)
        )

    return {
        "ckv": write(cache["ckv"], ckv_new),
        "krope": write(cache["krope"], krope_new),
        "lengths": cache["lengths"] + 1,
    }


def append_mla(cache: dict, ckv_new: jax.Array, krope_new: jax.Array) -> dict:
    """Masked-where append (see ``append_kv`` for why not a scatter)."""
    idx = cache["lengths"]
    smax = cache["ckv"].shape[1]
    mask = jnp.arange(smax)[None, :] == idx[:, None]  # (B, S)

    def write(buf, new):
        return jnp.where(mask[..., None], new[:, None].astype(buf.dtype), buf)

    return {
        "ckv": write(cache["ckv"], ckv_new),
        "krope": write(cache["krope"], krope_new),
        "lengths": idx + 1,
    }


# ---------------------------------------------------------------------------
# Mamba2 SSM state (constant-size: this is why long_500k is SSM-only)
# ---------------------------------------------------------------------------


def init_ssm_state(batch: int, cfg) -> dict:
    d_xbc = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_xbc), cfg.dtype),
        "h": jnp.zeros(
            (batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
    }


def ssm_state_abstract(batch: int, cfg) -> dict:
    d_xbc = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, d_xbc), cfg.dtype),
        "h": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
    }


def ssm_state_axes() -> dict:
    return {
        "conv": ("cache_batch", None, None),
        "h": ("cache_batch", "ssm_heads", None, None),
    }


# ---------------------------------------------------------------------------
# Paged KV cache (serving engine; vLLM-style block tables)
# ---------------------------------------------------------------------------


class PagedKVCache:
    """Host-managed paged cache: a pool of fixed-size blocks plus per-request
    block tables.  Gathers into contiguous form for the jitted decode step —
    the serving engine uses this to admit/evict requests without copying
    whole caches.  (Numerics identical to the contiguous cache; see tests.)
    """

    def __init__(self, n_blocks: int, block_size: int, n_kv: int, head_dim: int, dtype):
        self.block_size = block_size
        self.n_kv = n_kv
        self.head_dim = head_dim
        self.k_pool = np.zeros((n_blocks, block_size, n_kv, head_dim), dtype=np.float32)
        self.v_pool = np.zeros((n_blocks, block_size, n_kv, head_dim), dtype=np.float32)
        self.free: list[int] = list(range(n_blocks))[::-1]
        self.tables: dict[int, list[int]] = {}
        self.lengths: dict[int, int] = {}
        self._dtype = dtype

    @property
    def n_free_blocks(self) -> int:
        return len(self.free)

    def allocate(self, req_id: int) -> None:
        assert req_id not in self.tables
        self.tables[req_id] = []
        self.lengths[req_id] = 0

    def release(self, req_id: int) -> None:
        self.free.extend(self.tables.pop(req_id, []))
        self.lengths.pop(req_id, None)

    def _ensure_capacity(self, req_id: int, new_len: int) -> None:
        need = -(-new_len // self.block_size)  # ceil
        table = self.tables[req_id]
        while len(table) < need:
            if not self.free:
                raise MemoryError("paged KV cache exhausted")
            table.append(self.free.pop())

    def append(self, req_id: int, k: np.ndarray, v: np.ndarray) -> None:
        """k/v: (T, KV, D) — append T tokens for request req_id."""
        t = k.shape[0]
        start = self.lengths[req_id]
        self._ensure_capacity(req_id, start + t)
        table = self.tables[req_id]
        for i in range(t):
            pos = start + i
            blk, off = table[pos // self.block_size], pos % self.block_size
            self.k_pool[blk, off] = k[i]
            self.v_pool[blk, off] = v[i]
        self.lengths[req_id] = start + t

    def gather(self, req_id: int, max_seq: int) -> tuple[np.ndarray, np.ndarray, int]:
        """Materialize a contiguous (max_seq, KV, D) view for the jit step."""
        length = self.lengths[req_id]
        k = np.zeros((max_seq, self.n_kv, self.head_dim), np.float32)
        v = np.zeros_like(k)
        table = self.tables[req_id]
        for pos in range(length):
            blk, off = table[pos // self.block_size], pos % self.block_size
            k[pos] = self.k_pool[blk, off]
            v[pos] = self.v_pool[blk, off]
        return k, v, length
