"""Mamba2 (SSD — state-space duality) mixer block.  [arXiv:2405.21060]

Chunked SSD algorithm:
  * within-chunk: quadratic attention-like form  Y_diag = (C B^T ∘ L) X
  * chunk boundary states:  S_c = Σ_j decay_j · dt_j · B_j ⊗ X_j
  * inter-chunk: linear recurrence  h_c = γ_c h_{c-1} + S_c  (lax.scan)
  * off-diagonal contribution: Y_off = C · h_{c-1} · decay_in

Decode is the O(1) recurrent form over the (H, P, N) state — this is why the
long_500k cell is SSM/hybrid-only.  A sequential-scan reference
(``ssd_reference``) backs the property test chunked == sequential.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import TensorSpec, shard
from repro.models.layers import rmsnorm


def mamba2_template(cfg) -> dict[str, TensorSpec]:
    d = cfg.d_model
    din = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    h = cfg.ssm_nheads
    d_xbc = din + 2 * g * n
    d_in_proj = 2 * din + 2 * g * n + h  # z, x, B, C, dt
    return {
        "in_proj": TensorSpec((d, d_in_proj), ("d_model", "d_ff"), dtype=cfg.dtype),
        "conv_w": TensorSpec((cfg.ssm_conv, d_xbc), ("conv", "d_ff"), dtype=cfg.dtype),
        "conv_b": TensorSpec((d_xbc,), ("d_ff",), init="zeros", dtype=cfg.dtype),
        "a_log": TensorSpec((h,), ("ssm_heads",), init="ssm_a", dtype=jnp.float32),
        "d_skip": TensorSpec((h,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": TensorSpec((h,), ("ssm_heads",), init="ssm_dt", dtype=jnp.float32),
        "norm_w": TensorSpec((din,), ("d_ff",), init="ones", dtype=cfg.dtype),
        "out_proj": TensorSpec((din, d), ("d_ff", "d_model"), dtype=cfg.dtype),
    }


def _split_in_proj(cfg, zxbcdt: jax.Array):
    din = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    h = cfg.ssm_nheads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : din + din + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _split_xbc(cfg, xbc: jax.Array):
    din = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    x = xbc[..., :din]
    b = xbc[..., din : din + g * n]
    c = xbc[..., din + g * n :]
    return x, b, c


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, S, D), w: (K, D)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[i, j] = sum_{j < t <= i} x[t]; -inf for j > i."""
    t = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  — already softplus'd
    a: jax.Array,  # (H,) negative
    b_in: jax.Array,  # (B, S, G, N)
    c_in: jax.Array,  # (B, S, G, N)
    chunk: int,
    h_init: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(b_in.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    cf = jnp.repeat(c_in.astype(jnp.float32), rep, axis=2)

    # chunked views: (B, nc, Q, ...)
    xc = xf.reshape(bsz, nc, chunk, h, p)
    dtc = dtf.reshape(bsz, nc, chunk, h)
    bc = bf.reshape(bsz, nc, chunk, h, n)
    cc = cf.reshape(bsz, nc, chunk, h, n)

    da = dtc * a[None, None, None, :]  # (B, nc, Q, H)
    da_t = da.transpose(0, 1, 3, 2)  # (B, nc, H, Q)
    cum = jnp.cumsum(da_t, axis=-1)  # (B, nc, H, Q)

    # (1) within-chunk (quadratic) term
    l_mat = jnp.exp(_segsum(da_t))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc)
    scores = scores * l_mat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_j on key side
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # (2) per-chunk boundary states: S_c = Σ_j exp(cum_last - cum_j) dt_j B_j ⊗ X_j
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B, nc, H, Q)
    sc = jnp.einsum(
        "bchq,bcqh,bcqhn,bcqhp->bchpn", decay_to_end, dtc, bc, xc
    )  # (B, nc, H, P, N)

    # (3) inter-chunk recurrence
    gamma = jnp.exp(cum[..., -1])  # (B, nc, H) total decay per chunk

    def rec(carry, inp):
        s_c, gam = inp  # (B,H,P,N), (B,H)
        h_prev = carry
        h_new = h_prev * gam[..., None, None] + s_c
        return h_new, h_prev  # emit state *entering* the chunk

    h0 = (
        h_init.astype(jnp.float32)
        if h_init is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    sc_t = sc.transpose(1, 0, 2, 3, 4)  # (nc, B, H, P, N)
    gam_t = gamma.transpose(1, 0, 2)  # (nc, B, H)
    h_final, h_enter = jax.lax.scan(rec, h0, (sc_t, gam_t))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # (4) off-diagonal: Y_off = decay_in · C · h_enter
    decay_in = jnp.exp(cum)  # (B, nc, H, Q) decay from chunk start to q
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", cc, h_enter, decay_in)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, a, b_in, c_in, h_init=None):
    """Sequential per-token recurrence — oracle for the chunked path."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    bf = jnp.repeat(b_in.astype(jnp.float32), rep, axis=2)
    cf = jnp.repeat(c_in.astype(jnp.float32), rep, axis=2)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)

    def step(h_prev, t):
        xt, dtt, bt, ct = t
        decay = jnp.exp(dtt * a)[..., None, None]  # (B,H,1,1)
        h_new = h_prev * decay + dtt[..., None, None] * (
            xt[..., :, None] * bt[:, :, None, :]
        )
        y = jnp.einsum("bhn,bhpn->bhp", ct, h_new)
        return h_new, y

    h0 = (
        h_init.astype(jnp.float32)
        if h_init is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    xs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        bf.transpose(1, 0, 2, 3),
        cf.transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_final


def mamba2_forward(
    params: dict,
    u: jax.Array,  # (B, S, d_model)
    cfg,
) -> jax.Array:
    """Full-sequence forward (train/prefill)."""
    b, s, _ = u.shape
    zxbcdt = u @ params["in_proj"]
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x, b_in, c_in = _split_xbc(cfg, xbc)
    h = cfg.ssm_nheads
    x = x.reshape(b, s, h, cfg.ssm_headdim)
    b_in = b_in.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    c_in = c_in.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    # pad sequence to chunk multiple
    chunk = cfg.ssm_chunk
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, _ = ssd_chunked(x, dt, params["a_log"], b_in, c_in, chunk)
    y = y[:, :s]
    y = y + params["d_skip"][None, None, :, None] * x[:, :s].astype(jnp.float32)
    y = y.reshape(b, s, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return shard(out, "batch", "seq", "act_d_model")


def mamba2_prefill(params: dict, u: jax.Array, cfg, state: dict) -> tuple[jax.Array, dict]:
    """Prefill that also produces the decode state (conv tail + final h)."""
    b, s, _ = u.shape
    zxbcdt = u @ params["in_proj"]
    z, xbc_raw, dt = _split_in_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    x, b_in, c_in = _split_xbc(cfg, xbc)
    h = cfg.ssm_nheads
    x = x.reshape(b, s, h, cfg.ssm_headdim)
    b_in = b_in.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    c_in = c_in.reshape(b, s, cfg.ssm_ngroups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    chunk = cfg.ssm_chunk
    pad = (-s) % chunk
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bp = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cp = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        xp, dtp, bp, cp = x, dt, b_in, c_in
    y, h_final = ssd_chunked(xp, dtp, params["a_log"], bp, cp, chunk)
    # NOTE: padded steps have dt=0 -> decay=1, no state update; h_final exact.
    y = y[:, :s]
    y = y + params["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    k = cfg.ssm_conv - 1
    conv_tail = xbc_raw[:, -k:, :] if s >= k else jnp.pad(xbc_raw, ((0, 0), (k - s, 0), (0, 0)))
    new_state = {"conv": conv_tail.astype(state["conv"].dtype), "h": h_final}
    return shard(out, "batch", "seq", "act_d_model"), new_state


def mamba2_decode(params: dict, u: jax.Array, cfg, state: dict) -> tuple[jax.Array, dict]:
    """One-token recurrent step. u: (B, 1, d_model)."""
    b = u.shape[0]
    zxbcdt = u[:, 0] @ params["in_proj"]  # (B, ·)
    din = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    h = cfg.ssm_nheads
    z = zxbcdt[:, :din]
    xbc_new = zxbcdt[:, din : din + din + 2 * g * n]
    dt = zxbcdt[:, -h:]

    # conv ring buffer: window = [conv_state, xbc_new]
    window = jnp.concatenate([state["conv"], xbc_new[:, None, :]], axis=1)  # (B, K, D)
    w = params["conv_w"]  # (K, D)
    conv_out = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), w.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(u.dtype)
    x = conv_out[:, :din].reshape(b, h, cfg.ssm_headdim)
    b_in = conv_out[:, din : din + g * n].reshape(b, g, n)
    c_in = conv_out[:, din + g * n :].reshape(b, g, n)
    rep = h // g
    b_r = jnp.repeat(b_in, rep, axis=1).astype(jnp.float32)  # (B, H, N)
    c_r = jnp.repeat(c_in, rep, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    decay = jnp.exp(dt * params["a_log"])[..., None, None]  # (B, H, 1, 1)
    h_new = state["h"] * decay + dt[..., None, None] * (
        x.astype(jnp.float32)[..., :, None] * b_r[:, :, None, :]
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_r, h_new)  # (B, H, P)
    y = y + params["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, din).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype), params["norm_w"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    new_state = {"conv": window[:, 1:].astype(state["conv"].dtype), "h": h_new}
    return shard(out, "batch", "seq", "act_d_model"), new_state
