"""Unified multi-family model: dense / MoE / SSM / hybrid / enc-dec / VLM.

One parameter template + three execution paths (train / prefill / decode),
all expressed as ``lax.scan`` over *stacked* per-layer parameters so that

  * training remats layer-by-layer,
  * the autoscaling data plane can ship parameters as an ordered sequence of
    layer blocks (the unit of BlitzScale's multicast chains and live
    scaling), and
  * ``forward_layers_range`` executes an arbitrary ``[lo, hi)`` slice of
    layers — the *fine-grained layer-level serving abstraction* of the paper
    (§4): a partially-loaded instance runs layers ``[0, k)`` and forwards the
    activation to the overloaded instance for ``[k, L)``.

Layer families:
  dense / vlm : [norm1 -> GQA|MLA -> +res -> norm2 -> MLP -> +res]
  moe         : [norm1 -> GQA     -> +res -> norm2 -> MoE -> +res]
  ssm         : [norm1 -> Mamba2  -> +res]
  hybrid      : ssm layers with one *shared* (attn+MLP) block invoked every
                ``attn_every`` layers (zamba2)
  encdec      : encoder [non-causal GQA + MLP] x n_enc, decoder adds
                cross-attention against the encoder output (whisper)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (
    TensorSpec,
    constrain_layer_params,
    init_from_template,
    shard,
    stack_template,
)
from repro.models import attention, kvcache, layers, mamba2, moe
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------


def _norm_spec(cfg, dim=None) -> TensorSpec:
    return TensorSpec(((dim or cfg.d_model),), ("d_model",), init="ones", dtype=cfg.dtype)


def attn_layer_template(cfg, *, cross: bool = False) -> dict:
    """One attention+mlp block (dense/moe/vlm/encdec families)."""
    t: dict[str, Any] = {"norm1": _norm_spec(cfg), "norm2": _norm_spec(cfg)}
    if cfg.attn == "mla":
        t["attn"] = attention.mla_template(cfg)
    else:
        t["attn"] = attention.gqa_template(cfg)
    if cross:
        t["norm_x"] = _norm_spec(cfg)
        t["xattn"] = attention.gqa_template(cfg)
    if cfg.n_experts:
        t["moe"] = moe.moe_template(cfg)
    else:
        t["mlp"] = layers.mlp_template(cfg)
    return t


def ssm_layer_template(cfg) -> dict:
    return {"norm1": _norm_spec(cfg), "mixer": mamba2.mamba2_template(cfg)}


def layer_template(cfg) -> dict:
    """The per-layer template of the *main* (decoder) stack."""
    if cfg.family == "ssm" or cfg.family == "hybrid":
        return ssm_layer_template(cfg)
    return attn_layer_template(cfg, cross=(cfg.family == "encdec"))


def param_template(cfg: ModelConfig) -> dict:
    """Full-model TensorSpec pytree.  ``layers`` leaves carry a leading
    stacked 'layers' axis (the scan/multicast-block axis)."""
    t: dict[str, Any] = {
        "embed": layers.embedding_template(cfg),
        "layers": stack_template(layer_template(cfg), cfg.n_layers),
        "final_norm": _norm_spec(cfg),
    }
    if cfg.family == "hybrid":
        # one *shared* attention+MLP block reused at every invocation site
        t["shared"] = attn_layer_template(cfg)
    if cfg.family == "encdec":
        t["encoder"] = stack_template(attn_layer_template(cfg), cfg.n_enc_layers)
        t["enc_norm"] = _norm_spec(cfg)
    return t


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    return init_from_template(key, param_template(cfg))


def n_layer_blocks(cfg: ModelConfig) -> int:
    """Number of multicast/live-scaling blocks = scan layers (+enc for
    encdec, +1 shared block for hybrid)."""
    n = cfg.n_layers
    if cfg.family == "encdec":
        n += cfg.n_enc_layers
    if cfg.family == "hybrid":
        n += 1
    return n


# ---------------------------------------------------------------------------
# Single-layer forwards (train/prefill mode: full sequence)
# ---------------------------------------------------------------------------


def _attn_layer_fwd(
    cfg,
    lp: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cache: dict | None = None,
    enc_out: jax.Array | None = None,
    enc_lengths: jax.Array | None = None,
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Full-sequence attention layer. Returns (x, new_cache, aux_loss)."""
    h = layers.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if cfg.attn == "mla":
        a, new_cache = attention.mla_prefill(lp["attn"], h, positions, cfg, cache=cache)
    else:
        a, new_cache = attention.gqa_prefill(
            lp["attn"], h, positions, cfg, causal=causal, cache=cache
        )
    x = x + a
    if enc_out is not None:
        hx = layers.rmsnorm(x, lp["norm_x"], cfg.norm_eps)
        # cross-attention: kv from encoder output, no rope, not causal
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
        ax, _ = attention.gqa_prefill(
            lp["xattn"], hx, positions, cfg, causal=False, use_rope=False,
            kv_override=(kx, vx),
        )
        x = x + ax
    h2 = layers.rmsnorm(x, lp["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        m, aux = moe.moe_forward(lp["moe"], h2, cfg)
    else:
        m = layers.mlp_forward(lp["mlp"], h2, cfg)
    return x + m, new_cache, aux


def _ssm_layer_fwd(
    cfg, lp: dict, x: jax.Array, *, state: dict | None = None
) -> tuple[jax.Array, dict | None]:
    h = layers.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if state is None:
        out = mamba2.mamba2_forward(lp["mixer"], h, cfg)
        return x + out, None
    out, new_state = mamba2.mamba2_prefill(lp["mixer"], h, cfg, state)
    return x + out, new_state


# ---------------------------------------------------------------------------
# Single-layer forwards (decode mode: one token)
# ---------------------------------------------------------------------------


def _attn_layer_decode(
    cfg,
    lp: dict,
    x: jax.Array,  # (B, 1, d)
    cache: dict,
    cross_cache: dict | None = None,
) -> tuple[jax.Array, dict]:
    h = layers.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if cfg.attn == "mla":
        a, new_cache = attention.mla_decode(lp["attn"], h, cfg, cache)
    else:
        a, new_cache = attention.gqa_decode(lp["attn"], h, cfg, cache)
    x = x + a
    if cross_cache is not None:
        hx = layers.rmsnorm(x, lp["norm_x"], cfg.norm_eps)
        ax, _ = attention.gqa_decode(lp["xattn"], hx, cfg, cache, cross_cache=cross_cache)
        x = x + ax
    h2 = layers.rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if cfg.n_experts:
        m, _ = moe.moe_forward(lp["moe"], h2, cfg)
    else:
        m = layers.mlp_forward(lp["mlp"], h2, cfg)
    return x + m, new_cache


def _ssm_layer_decode(cfg, lp: dict, x: jax.Array, state: dict) -> tuple[jax.Array, dict]:
    h = layers.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    out, new_state = mamba2.mamba2_decode(lp["mixer"], h, cfg, state)
    return x + out, new_state


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, *, abstract: bool = False) -> dict:
    """Stacked per-layer decode caches for the whole model."""
    hd = cfg.resolved_head_dim

    def kv(b, s):
        if abstract:
            return kvcache.kv_cache_abstract(
                b, s, cfg.n_kv_heads, hd, cfg.dtype, quant=cfg.kv_quant)
        return kvcache.init_kv_cache(
            b, s, cfg.n_kv_heads, hd, cfg.dtype, quant=cfg.kv_quant)

    def mla(b, s):
        if abstract:
            return kvcache.mla_cache_abstract(b, s, cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.dtype)
        return kvcache.init_mla_cache(b, s, cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.dtype)

    def ssm(b):
        if abstract:
            return kvcache.ssm_state_abstract(b, cfg)
        return kvcache.init_ssm_state(b, cfg)

    def stack(tree_fn, n):
        """Add a leading layer axis to each cache leaf."""
        proto = tree_fn()
        if abstract:
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), proto
            )
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), proto)

    caches: dict[str, Any] = {}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        mk = (lambda: mla(batch, max_seq)) if cfg.attn == "mla" else (lambda: kv(batch, max_seq))
        caches["layers"] = stack(mk, cfg.n_layers)
    elif fam == "ssm":
        caches["layers"] = stack(lambda: ssm(batch), cfg.n_layers)
    elif fam == "hybrid":
        caches["layers"] = stack(lambda: ssm(batch), cfg.n_layers)
        n_sites = cfg.n_layers // cfg.attn_every
        caches["shared"] = stack(lambda: kv(batch, max_seq), n_sites)
    elif fam == "encdec":
        caches["layers"] = stack(lambda: kv(batch, max_seq), cfg.n_layers)
        # cross-attention K/V computed once at prefill from encoder output
        # (seq-major layout, matching the decode cache — §Perf C1)
        ek = (batch, cfg.n_kv_heads, cfg.n_frontend_tokens, hd)
        if abstract:
            caches["cross"] = {
                "k": jax.ShapeDtypeStruct((cfg.n_layers, *ek), cfg.dtype),
                "v": jax.ShapeDtypeStruct((cfg.n_layers, *ek), cfg.dtype),
                "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
            }
        else:
            caches["cross"] = {
                "k": jnp.zeros((cfg.n_layers, *ek), cfg.dtype),
                "v": jnp.zeros((cfg.n_layers, *ek), cfg.dtype),
                "lengths": jnp.zeros((batch,), jnp.int32),
            }
    else:
        raise ValueError(fam)
    return caches


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axis pytree matching ``init_caches`` output."""

    def add_layer(tree):
        return jax.tree.map(
            lambda axes: ("layers", *axes), tree, is_leaf=lambda x: isinstance(x, tuple)
        )

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        base = (kvcache.mla_cache_axes() if cfg.attn == "mla"
                else kvcache.kv_cache_axes(quant=cfg.kv_quant))
        return {"layers": add_layer(base)}
    if fam == "ssm":
        return {"layers": add_layer(kvcache.ssm_state_axes())}
    if fam == "hybrid":
        return {
            "layers": add_layer(kvcache.ssm_state_axes()),
            "shared": add_layer(kvcache.kv_cache_axes(quant=cfg.kv_quant)),
        }
    if fam == "encdec":
        return {
            "layers": add_layer(kvcache.kv_cache_axes()),
            "cross": add_layer(kvcache.kv_cache_axes()),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, frames=None):
    """tokens: (B, S) int32. frames: optional (B, Sf, d) stub modality
    embeddings — VLM patches overwrite the first Sf token positions."""
    x = layers.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm" and frames is not None:
        sf = frames.shape[1]
        mask = (jnp.arange(tokens.shape[1]) < sf)[None, :, None]
        fpad = jnp.pad(frames.astype(x.dtype), ((0, 0), (0, x.shape[1] - sf), (0, 0)))
        x = jnp.where(mask, fpad, x)
    return x


def _run_encoder(cfg, params, frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])

    def body(x, lp):
        x, _, _ = _attn_layer_fwd(cfg, lp, x, pos, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train forward (full sequence, no caches, remat over layers)
# ---------------------------------------------------------------------------


def train_forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    frames: jax.Array | None = None,  # (B, Sf, d) for vlm/encdec stub frontends
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), moe_aux_loss)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed(cfg, params, tokens, frames)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, frames)

    fam = cfg.family

    if fam in ("dense", "vlm", "moe", "encdec"):

        def body(carry, lp):
            x, aux = carry
            x, _, a = _attn_layer_fwd(cfg, lp, x, positions, causal=True, enc_out=enc_out)
            return (x, aux + a), None

        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    elif fam == "ssm":

        def body(x, lp):
            x, _ = _ssm_layer_fwd(cfg, lp, x)
            return x, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif fam == "hybrid":
        k = cfg.attn_every
        groups = cfg.n_layers // k
        grouped = jax.tree.map(lambda p: p.reshape(groups, k, *p.shape[1:]), params["layers"])

        def group_body(x, glp):
            def inner(x, lp):
                x, _ = _ssm_layer_fwd(cfg, lp, x)
                return x, None

            x, _ = jax.lax.scan(inner, x, glp)
            x, _, _ = _attn_layer_fwd(cfg, params["shared"], x, positions, causal=True)
            return x, None

        group_body = jax.checkpoint(group_body) if cfg.remat else group_body
        x, _ = jax.lax.scan(group_body, x, grouped)
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(fam)

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill (full prompt -> caches + last-position logits)
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    caches: dict,
    frames: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Returns (next-token ids (B,), filled caches)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _embed(cfg, params, tokens, frames)
    fam = cfg.family
    new_caches = dict(caches)

    if fam in ("dense", "vlm", "moe"):

        def body(x, inp):
            lp, cache_l = inp
            x, new_c, _ = _attn_layer_fwd(cfg, lp, x, positions, causal=True, cache=cache_l)
            return x, new_c

        x, layer_caches = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = layer_caches
    elif fam == "ssm":

        def body(x, inp):
            lp, st = inp
            x, new_st = _ssm_layer_fwd(cfg, lp, x, state=st)
            return x, new_st

        x, layer_states = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = layer_states
    elif fam == "hybrid":
        k = cfg.attn_every
        groups = cfg.n_layers // k
        grouped = jax.tree.map(lambda p: p.reshape(groups, k, *p.shape[1:]), params["layers"])
        gstates = jax.tree.map(lambda c: c.reshape(groups, k, *c.shape[1:]), caches["layers"])

        def group_body(x, inp):
            glp, gst, shared_cache = inp

            def inner(x, i2):
                lp, st = i2
                x, new_st = _ssm_layer_fwd(cfg, lp, x, state=st)
                return x, new_st

            x, new_gst = jax.lax.scan(inner, x, (glp, gst))
            x, new_sc, _ = _attn_layer_fwd(
                cfg, params["shared"], x, positions, causal=True, cache=shared_cache
            )
            return x, (new_gst, new_sc)

        x, (new_states, new_shared) = jax.lax.scan(
            group_body, x, (grouped, gstates, caches["shared"])
        )
        new_caches["layers"] = jax.tree.map(
            lambda c: c.reshape(cfg.n_layers, *c.shape[2:]), new_states
        )
        new_caches["shared"] = new_shared
    elif fam == "encdec":
        enc_out = _run_encoder(cfg, params, frames)
        enc_len = jnp.full((b,), enc_out.shape[1], jnp.int32)

        def body(x, inp):
            lp, cache_l = inp
            # precompute this layer's cross K/V from encoder output
            kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            x, new_c, _ = _attn_layer_fwd(
                cfg, lp, x, positions, causal=True, cache=cache_l,
                enc_out=enc_out, enc_lengths=enc_len,
            )
            # store cross K/V seq-major (B, KV, S, D) for transpose-free decode
            return x, (
                new_c,
                kx.transpose(0, 2, 1, 3).astype(cfg.dtype),
                vx.transpose(0, 2, 1, 3).astype(cfg.dtype),
            )

        x, (layer_caches, kxs, vxs) = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = layer_caches
        new_caches["cross"] = {"k": kxs, "v": vxs, "lengths": enc_len}
    else:
        raise ValueError(fam)

    x = layers.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    logits = layers.vocab_mask_logits(logits.astype(jnp.float32), cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches


# ---------------------------------------------------------------------------
# Decode (one token per sequence against the caches)
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    params: dict,
    last_tokens: jax.Array,  # (B,) int32
    caches: dict,
) -> tuple[jax.Array, dict]:
    """One auto-regressive step.  Returns (next-token ids (B,), caches)."""
    x = layers.embed_tokens(params["embed"], last_tokens[:, None], cfg)
    fam = cfg.family
    new_caches = dict(caches)

    if fam in ("dense", "vlm", "moe"):

        def body(x, inp):
            lp, cache_l = inp
            x, new_c = _attn_layer_decode(cfg, lp, x, cache_l)
            return x, new_c

        x, layer_caches = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = layer_caches
    elif fam == "ssm":

        def body(x, inp):
            lp, st = inp
            x, new_st = _ssm_layer_decode(cfg, lp, x, st)
            return x, new_st

        x, layer_states = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = layer_states
    elif fam == "hybrid":
        k = cfg.attn_every
        groups = cfg.n_layers // k
        grouped = jax.tree.map(lambda p: p.reshape(groups, k, *p.shape[1:]), params["layers"])
        gstates = jax.tree.map(lambda c: c.reshape(groups, k, *c.shape[1:]), caches["layers"])

        def group_body(x, inp):
            glp, gst, shared_cache = inp

            def inner(x, i2):
                lp, st = i2
                x, new_st = _ssm_layer_decode(cfg, lp, x, st)
                return x, new_st

            x, new_gst = jax.lax.scan(inner, x, (glp, gst))
            x, new_sc = _attn_layer_decode(cfg, params["shared"], x, shared_cache)
            return x, (new_gst, new_sc)

        x, (new_states, new_shared) = jax.lax.scan(
            group_body, x, (grouped, gstates, caches["shared"])
        )
        new_caches["layers"] = jax.tree.map(
            lambda c: c.reshape(cfg.n_layers, *c.shape[2:]), new_states
        )
        new_caches["shared"] = new_shared
    elif fam == "encdec":

        def body(x, inp):
            lp, cache_l, kx, vx = inp
            cross = {"k": kx, "v": vx, "lengths": caches["cross"]["lengths"]}
            x, new_c = _attn_layer_decode(cfg, lp, x, cache_l, cross_cache=cross)
            return x, new_c

        x, layer_caches = jax.lax.scan(
            body, x, (params["layers"], caches["layers"], caches["cross"]["k"], caches["cross"]["v"])
        )
        new_caches["layers"] = layer_caches
    else:
        raise ValueError(fam)

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    logits = layers.vocab_mask_logits(logits.astype(jnp.float32), cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches


# ---------------------------------------------------------------------------
# Layer-range execution — BlitzScale's fine-grained serving abstraction
# ---------------------------------------------------------------------------


def forward_layers_range(
    cfg: ModelConfig,
    stacked_layers: dict,
    x: jax.Array,  # (B, S, d) activation entering layer `lo`
    lo: jax.Array | int,
    hi: jax.Array | int,
    positions: jax.Array,
    shared: dict | None = None,
) -> jax.Array:
    """Execute layers ``[lo, hi)`` of the main stack with dynamic bounds.

    This is the compute primitive behind live autoscaling: a scaling
    instance with ``k`` loaded layers runs ``forward_layers_range(0, k)``
    and ships the activation to the source instance which runs
    ``forward_layers_range(k, L)``.  Implemented as a masked scan so the
    bounds can be traced values (no per-k recompilation).
    """
    fam = cfg.family
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)

    def body(x, inp):
        i, lp = inp
        active = (i >= lo) & (i < hi)
        if fam in ("ssm", "hybrid"):
            y, _ = _ssm_layer_fwd(cfg, lp, x)
        else:
            y, _, _ = _attn_layer_fwd(cfg, lp, x, positions, causal=True)
        x = jnp.where(active, y, x)
        if fam == "hybrid" and shared is not None:
            site = (i % cfg.attn_every) == (cfg.attn_every - 1)
            ys, _, _ = _attn_layer_fwd(cfg, shared, x, positions, causal=True)
            x = jnp.where(active & site, ys, x)
        return x, None

    idx = jnp.arange(cfg.n_layers)
    x, _ = jax.lax.scan(body, x, (idx, stacked_layers))
    return x


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    labels: jax.Array,  # (B, S) — -100 = ignored
    frames: jax.Array | None = None,
) -> jax.Array:
    """Cross-entropy computed *vocab-sharded*: the (B, S, V) logits stay
    partitioned on the model axis; logsumexp reduces locally then all-reduces
    a (B, S) stat, and the gold logit is extracted with a fused iota-compare
    reduction instead of ``take_along_axis`` (a gather on a sharded dim would
    all-gather the full logits — 12.9 GiB/chip for granite train_4k)."""
    logits, aux = train_forward(cfg, params, tokens, frames)
    logits = shard(logits, "batch", "seq", "act_vocab")
    logits = layers.vocab_mask_logits(logits, cfg)
    valid = labels != -100
    safe = jnp.where(valid, labels, 0)

    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1)  # (B, S) — cross-shard max is a tiny all-reduce
    lse = jnp.log(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)) + m
    vocab_idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(vocab_idx == safe[..., None], lf, 0.0), axis=-1)
    nll = lse - gold
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux
