"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM backbones;
the per-arch files in ``repro/configs`` instantiate it with the exact
published hyperparameters plus a ``reduced()`` variant for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None
    mlp: str = "swiglu"  # 'swiglu' | 'relu2' | 'gelu'
    attn: str = "gqa"  # 'gqa' | 'mla' | 'none'
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # GShard dispatch group size: per-expert capacity C = cf*k*group/E, and
    # the dispatch einsum costs 2*T*(E*C)*d = 2.5*k*T*group*d FLOPs — small
    # groups keep it negligible when the expert axis cannot shard (grok: 8
    # experts on a 16-way axis -> replicated dispatch; §Perf B4)
    moe_group_size: int = 4096

    # --- MLA (multi-head latent attention; minicpm3 / deepseek family) -----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 128

    # --- hybrid (zamba2): one shared attn+mlp block every `attn_every` ssm
    # layers; the shared block's params are reused at each invocation site.
    attn_every: int = 0

    # --- enc-dec (whisper) --------------------------------------------------
    n_enc_layers: int = 0

    # --- modality frontend (stub provides precomputed embeddings) ----------
    frontend: str = "none"  # 'none' | 'audio_stub' | 'patch_stub'
    n_frontend_tokens: int = 0  # patch/frame token count for vlm prefill mix

    max_seq: int = 131_072
    dtype: Any = jnp.bfloat16
    # lockstep decode positions: a scalar-index cache append partitions in
    # place — but ONLY when the cache seq dim is unsharded (head-sharded
    # caches: olmoe, zamba2).  On seq-SHARDED caches GSPMD lowers a scalar
    # DUS through its "last resort" replication path and the step REGRESSES
    # (granite decode_32k: 37.5 -> 55.2 ms, §Perf C2 — refuted there), so
    # the default stays on the masked-where append.
    uniform_decode: bool = False
    # int8 KV cache with per-(token, kv-head) absmax scales (§Perf C3):
    # halves cache-proportional HBM traffic and doubles KV capacity; the
    # dequant converts fuse into the attention dot reads on TPU.
    kv_quant: bool = False

    # sharding rule overrides for this arch (e.g. FSDP for >=100B)
    sharding_overrides: Mapping[str, Any] | None = None
    # remat / grad-accum defaults used by the training step at scale
    remat: bool = True
    microbatches: int = 1

    # ------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 512 (Megatron practice) so the
        embedding/unembedding and the training logits always divide the
        16-way model axis (and 32-way model x pod products).  Logits in the
        padded tail are masked to -inf in the loss and in decode argmax."""
        return -(-self.vocab_size // 512) * 512

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM/hybrid) — eligible for the
        long_500k shape cell."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def gated_mlp(self) -> bool:
        return self.mlp == "swiglu"

    @property
    def mla_qk_head_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count used for multicast volume & roofline MODEL_FLOPS.
    def approx_params(self) -> int:
        from repro.models.transformer import param_template

        from repro.distributed.sharding import param_count

        return param_count(param_template(self))

    def approx_active_params(self) -> int:
        """Active parameters per token (MoE: routed experts only)."""
        total = self.approx_params()
        if self.n_experts and self.top_k:
            from repro.models.transformer import param_template
            from repro.distributed.sharding import param_count

            # expert params scale by top_k / n_experts
            tmpl = param_template(self)
            expert = tmpl["layers"].get("moe") if isinstance(tmpl.get("layers"), dict) else None
            if expert is not None:
                e_count = param_count(expert)
                total = total - e_count + (e_count * self.top_k) // self.n_experts
        return total
