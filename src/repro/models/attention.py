"""Attention modules: GQA (llama/granite/qwen/grok/...) and MLA (minicpm3).

Each module provides a parameter template plus three entry points:
  * ``*_prefill``  — full-sequence attention, returns output + filled cache,
  * ``*_decode``   — one-token attention against the cache, returns output +
                     updated cache,
  * used by both train (no cache) and serve paths.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import TensorSpec, seq_sharded, shard
from repro.models import kvcache
from repro.models.layers import (
    apply_rope,
    attention_reference,
    chunked_attention,
    decode_attention_reference,
    rmsnorm,
    rope_for,
)

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_template(cfg) -> dict[str, TensorSpec]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    t = {
        "wq": TensorSpec((d, h, hd), ("d_model", "heads", "head_dim"), dtype=cfg.dtype),
        "wk": TensorSpec((d, kv, hd), ("d_model", "kv_heads", "head_dim"), dtype=cfg.dtype),
        "wv": TensorSpec((d, kv, hd), ("d_model", "kv_heads", "head_dim"), dtype=cfg.dtype),
        "wo": TensorSpec((h, hd, d), ("heads", "head_dim", "d_model"), dtype=cfg.dtype),
    }
    if cfg.qkv_bias:
        t["bq"] = TensorSpec((h, hd), ("heads", "head_dim"), init="zeros", dtype=cfg.dtype)
        t["bk"] = TensorSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros", dtype=cfg.dtype)
        t["bv"] = TensorSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros", dtype=cfg.dtype)
    return t


def _gqa_qkv(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = shard(q, "batch", "seq", "act_heads", None)
    k = shard(k, "batch", "seq", "act_heads", None)
    v = shard(v, "batch", "seq", "act_heads", None)
    return q, k, v


def gqa_prefill(
    params: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    cfg,
    *,
    causal: bool = True,
    use_rope: bool = True,
    cache: dict | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
) -> tuple[jax.Array, dict | None]:
    hd = cfg.resolved_head_dim
    q, k, v = _gqa_qkv(params, x, cfg)
    if kv_override is not None:
        k, v = kv_override
    elif use_rope:
        cos, sin = rope_for(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if seq_sharded():
        # sequence-parallel attention (§Perf A2): q stays a local seq shard
        # (no q-chunk scan — scanning a sharded axis makes GSPMD replicate),
        # k/v are gathered once per layer (replicated over the model axis)
        q = shard(q, "batch", "seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        out = chunked_attention(q, k, v, causal=causal, q_chunk=q.shape[1])
    else:
        out = chunked_attention(q, k, v, causal=causal)
    new_cache = None
    if cache is not None and kv_override is None:
        lengths = positions[:, -1] + 1
        new_cache = kvcache.write_prompt_kv(cache, k, v, lengths)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard(out, "batch", "seq", "act_d_model"), new_cache


def gqa_decode(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    cfg,
    cache: dict,
    *,
    use_rope: bool = True,
    cross_cache: dict | None = None,  # whisper cross-attn: static k/v, no append
) -> tuple[jax.Array, dict]:
    hd = cfg.resolved_head_dim
    q, k, v = _gqa_qkv(params, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B, H/KV, D)

    if cross_cache is not None:
        out = decode_attention_reference(
            q, cross_cache["k"], cross_cache["v"], cross_cache["lengths"]
        )
        new_cache = cache
    else:
        if use_rope:
            pos = cache["lengths"][:, None]  # (B, 1)
            cos, sin = rope_for(pos, hd, cfg.rope_theta)
            q = apply_rope(q[:, None], cos, sin)[:, 0]
            k = apply_rope(k[:, None], cos, sin)[:, 0]
        append = kvcache.append_kv_uniform if cfg.uniform_decode else kvcache.append_kv
        new_cache = append(cache, k, v)
        out = decode_attention_reference(
            q, new_cache["k"], new_cache["v"], new_cache["lengths"],
            k_scale=new_cache.get("k_scale"), v_scale=new_cache.get("v_scale"),
        )
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return shard(out, "batch", "seq", "act_d_model"), new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — minicpm3 / deepseek-v2 family
# ---------------------------------------------------------------------------
#
# q  = W_uq · rmsnorm(W_dq · x)            -> (H, nope+rope)
# c  = rmsnorm(W_dkv · x)                  -> kv_lora_rank   (cached)
# kr = rope(W_kr · x)                      -> qk_rope_dim    (cached, shared)
# k  = [W_uk · c  (per head), kr] ; v = W_uv · c
#
# Decode uses the *absorbed* form: q_nope is pushed through W_uk^T so the
# score is an inner product in latent space against the cached ``c`` directly
# — O(kv_lora_rank) per cached token instead of O(H * head_dim).  Prefill
# expands k/v (standard form) for throughput.


def mla_template(cfg) -> dict[str, TensorSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": TensorSpec((d, qlr), ("d_model", "lora"), dtype=cfg.dtype),
        "q_norm": TensorSpec((qlr,), ("lora",), init="ones", dtype=cfg.dtype),
        "w_uq": TensorSpec((qlr, h, nope + rope_d), ("lora", "heads", "head_dim"), dtype=cfg.dtype),
        "w_dkv": TensorSpec((d, kvlr), ("d_model", "lora"), dtype=cfg.dtype),
        "kv_norm": TensorSpec((kvlr,), ("lora",), init="ones", dtype=cfg.dtype),
        "w_kr": TensorSpec((d, rope_d), ("d_model", "head_dim"), dtype=cfg.dtype),
        "w_uk": TensorSpec((kvlr, h, nope), ("lora", "heads", "head_dim"), dtype=cfg.dtype),
        "w_uv": TensorSpec((kvlr, h, vdim), ("lora", "heads", "head_dim"), dtype=cfg.dtype),
        "wo": TensorSpec((h, vdim, d), ("heads", "head_dim", "d_model"), dtype=cfg.dtype),
    }


def _mla_q(params: dict, x: jax.Array, positions: jax.Array, cfg):
    cq = rmsnorm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim :]
    cos, sin = rope_for(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_ckv(params: dict, x: jax.Array, positions: jax.Array, cfg):
    c = rmsnorm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    kr = (x @ params["w_kr"])[:, :, None, :]  # (B, S, 1, rope)
    cos, sin = rope_for(positions, cfg.qk_rope_dim, cfg.rope_theta)
    kr = apply_rope(kr, cos, sin)[:, :, 0]  # (B, S, rope)
    return c, kr


def mla_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    *,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c, kr = _mla_ckv(params, x, positions, cfg)
    # expand keys/values per head (standard form for prefill)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c, params["w_uv"])
    h = cfg.n_heads
    k_rope = jnp.broadcast_to(kr[:, :, None, :], (*kr.shape[:2], h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    scale = 1.0 / np.sqrt(cfg.mla_qk_head_dim)
    # v_head_dim may differ from qk dim — pad v to qk dim for the shared
    # attention helper, then slice back.
    vdim = cfg.v_head_dim
    qk_dim = cfg.mla_qk_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - vdim))) if vdim < qk_dim else v
    if seq_sharded():
        # sequence-parallel attention (§Perf A2) — see gqa_prefill
        q = shard(q, "batch", "seq", None, None)
        k = shard(k, "batch", None, None, None)
        v_p = shard(v_p, "batch", None, None, None)
        out = chunked_attention(
            q, k, v_p, causal=True, softmax_scale=scale, q_chunk=q.shape[1]
        )
    else:
        out = chunked_attention(q, k, v_p, causal=True, softmax_scale=scale)
    out = out[..., :vdim]
    new_cache = None
    if cache is not None:
        lengths = positions[:, -1] + 1
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], c.astype(cache["ckv"].dtype), (0, 0, 0)
            ),
            "krope": jax.lax.dynamic_update_slice(
                cache["krope"], kr.astype(cache["krope"].dtype), (0, 0, 0)
            ),
            "lengths": lengths.astype(jnp.int32),
        }
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard(out, "batch", "seq", "act_d_model"), new_cache


def mla_decode(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    cfg,
    cache: dict,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    pos = cache["lengths"][:, None]  # (B, 1)
    q_nope, q_rope = _mla_q(params, x, pos, cfg)  # (B, 1, H, ·)
    c_new, kr_new = _mla_ckv(params, x, pos, cfg)
    append = kvcache.append_mla_uniform if cfg.uniform_decode else kvcache.append_mla
    new_cache = append(cache, c_new[:, 0], kr_new[:, 0])

    # absorbed decode: score = q_nope·(W_uk·c) + q_rope·kr
    #                        = (q_nope·W_uk)·c + q_rope·kr
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["w_uk"])  # (B, H, kvlr)
    scale = 1.0 / np.sqrt(cfg.mla_qk_head_dim)
    s_latent = jnp.einsum(
        "bhr,bsr->bhs", q_abs.astype(jnp.float32), new_cache["ckv"].astype(jnp.float32)
    )
    s_rope = jnp.einsum(
        "bhk,bsk->bhs", q_rope[:, 0].astype(jnp.float32), new_cache["krope"].astype(jnp.float32)
    )
    s = (s_latent + s_rope) * scale
    smax = new_cache["ckv"].shape[1]
    valid = jnp.arange(smax)[None, :] < new_cache["lengths"][:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # out = p · v = p · (W_uv·c): absorb on the output side too
    ctx = jnp.einsum("bhs,bsr->bhr", p, new_cache["ckv"].astype(jnp.float32))  # (B, H, kvlr)
    out = jnp.einsum("bhr,rhk->bhk", ctx, params["w_uv"].astype(jnp.float32))
    out = jnp.einsum("bhk,hkd->bd", out.astype(x.dtype), params["wo"])[:, None]
    return shard(out, "batch", "seq", "act_d_model"), new_cache
