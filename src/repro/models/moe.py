"""Mixture-of-experts layer (grok-1: 8e top-2; olmoe: 64e top-8).

Token-choice top-k routing with per-group capacity dispatch:

  tokens are processed in groups (bounded live memory), each group scatters
  its tokens into an (E, C, d) buffer via positions computed from a cumsum
  over the routing one-hot, experts run as one grouped einsum
  (E, C, d) x (E, d, f) — the EP-shardable pattern (experts on the 'model'
  mesh axis; XLA turns the scatter/gather into an all-to-all under EP) —
  and results are combined back with the routing probabilities.

Capacity drops (tokens beyond C per expert per group) match standard
practice; the router aux loss (load-balance) is returned for training.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import TensorSpec, shard
from repro.models.layers import mlp_forward


def moe_template(cfg) -> dict[str, TensorSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    t = {
        "router": TensorSpec((d, e), ("d_model", "experts"), dtype=jnp.float32),
        "w_up": TensorSpec((e, d, f), ("experts", "d_model", "d_ff"), dtype=cfg.dtype),
        "w_down": TensorSpec((e, f, d), ("experts", "d_ff", "d_model"), dtype=cfg.dtype),
    }
    if cfg.gated_mlp:
        t["w_gate"] = TensorSpec((e, d, f), ("experts", "d_model", "d_ff"), dtype=cfg.dtype)
    return t


def _expert_ffn(params: dict, x: jax.Array, cfg) -> jax.Array:
    """x: (G, E, C, d) -> (G, E, C, d) via grouped einsum (E stays on the
    expert-parallel mesh axis; no collective touches the FFN)."""
    up = jnp.einsum("gecd,edf->gecf", x, params["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("gecd,edf->gecf", x, params["w_gate"])
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    hidden = shard(hidden, "batch", "experts", None, "act_d_ff")
    return jnp.einsum("gecf,efd->gecd", hidden, params["w_down"])


def moe_forward(
    params: dict,
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    group_size: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar).

    GShard-style one-hot einsum dispatch (§Perf B2): tokens are reshaped to
    (G groups x S tokens); a dispatch tensor (G,S,E,C) built from routing
    one-hots scatters tokens into per-group per-expert capacity buffers via
    a single einsum.  Every contraction is a matmul GSPMD partitions cleanly
    (G on the data axis, E on the model axis) — the previous `.at[].set`
    scatter onto an expert-sharded buffer made GSPMD all-gather/all-reduce
    the buffers (measured 5.17 TB of all-reduce per olmoe train step)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]

    logits = (tokens.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)  # (T, k)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # (E,)
    assign = jax.nn.one_hot(topk_i[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(assign, axis=0)
    aux = e * jnp.sum(fe * me) * cfg.router_aux_coef

    # group tokens: G groups of S_g tokens; G rides the data axis like batch
    g_sz = min(group_size or cfg.moe_group_size, t)
    n_groups = -(-t // g_sz)
    pad = n_groups * g_sz - t
    tk = jnp.pad(tokens, ((0, pad), (0, 0))).reshape(n_groups, g_sz, d)
    pi = jnp.pad(topk_p, ((0, pad), (0, 0))).reshape(n_groups, g_sz, k)
    ii = jnp.pad(topk_i, ((0, pad), (0, 0))).reshape(n_groups, g_sz, k)
    vm = jnp.pad(jnp.ones((t,), bool), ((0, pad),),
                 constant_values=False).reshape(n_groups, g_sz)

    cap = max(int(np.ceil(cfg.capacity_factor * g_sz * k / e)), 1)

    # position of each (token, choice) within its expert: exclusive cumsum
    # over the flattened (S*k) routing one-hots, per group
    onehot = jax.nn.one_hot(ii, e, dtype=jnp.float32)  # (G, S, k, E)
    flat = onehot.reshape(n_groups, g_sz * k, e)
    pos_f = jnp.cumsum(flat, axis=1) - flat  # (G, S*k, E)
    pos = jnp.einsum("gse,gse->gs", pos_f, flat).reshape(n_groups, g_sz, k)
    keep = (pos < cap) & (pi > 0) & vm[..., None]  # (G, S, k)

    oc = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (G,S,k,C)
    oc = oc * keep[..., None]
    # dispatch (0/1) and combine (routing-prob-weighted) tensors
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, oc)  # (G, S, E, C)
    combine = jnp.einsum("gske,gskc->gsec", onehot * pi[..., None], oc)
    dispatch = shard(dispatch, "batch", None, "experts", None)
    combine = shard(combine, "batch", None, "experts", None)

    buf = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), tk)  # (G,E,C,d)
    buf = shard(buf, "batch", "experts", None, None)
    out_buf = _expert_ffn(params, buf, cfg)
    y = jnp.einsum("gsec,gecd->gsd", combine,
                   out_buf.astype(jnp.float32))  # (G, S, d)
    out = y.reshape(n_groups * g_sz, d)[:t].reshape(b, s, d).astype(x.dtype)
    return shard(out, "batch", "seq", "act_d_model"), aux
