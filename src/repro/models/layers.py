"""Core neural-net layers shared across all architecture families.

Everything is a pure function over parameter pytrees built from
:class:`repro.distributed.sharding.TensorSpec` templates.  The attention
implementation is *memory-bounded* (online-softmax over KV chunks, scanned
over Q chunks) so that 32k-token prefills lower with O(block) live memory —
this is also the pure-jnp oracle the Pallas kernels are validated against.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import TensorSpec, shard

# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, dim//2)."""
    inv_freq = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D). cos/sin: broadcastable (..., S, 1, D//2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def rope_for(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """Convenience: (B, S) positions -> (B, S, 1, D//2) cos/sin for heads."""
    cos, sin = rope_cos_sin(positions, head_dim, theta)
    return cos[:, :, None, :], sin[:, :, None, :]


# ---------------------------------------------------------------------------
# Attention math: memory-bounded online-softmax (the FA oracle)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, KV*n_rep, D) by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d))
    return k.reshape(b, s, kv * n_rep, d)


def attention_reference(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,  # (B,) valid kv lengths
    softmax_scale: float | None = None,
) -> jax.Array:
    """Naive O(Sq*Sk) attention — the numerical oracle for kernels/tests."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
    mask = jnp.broadcast_to(mask[None, None], (b, 1, sq, sk))
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]
        mask = mask & valid[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Flash-attention-style exact attention with O(q_chunk*kv_chunk) live
    score memory: scan over Q chunks, inner scan over KV chunks carrying
    running (max, denominator, accumulator).

    This is what the 32k prefill lowers to on the production mesh; the Pallas
    kernel implements the same loop structure in VMEM.
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    n_rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # pad to multiples
    sq_pad = int(np.ceil(sq / q_chunk)) * q_chunk
    sk_pad = int(np.ceil(sk / kv_chunk)) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    nq, nk = sq_pad // q_chunk, sk_pad // kv_chunk

    if kv_len is None:
        kv_len_arr = jnp.full((b,), sk, jnp.int32)
    else:
        kv_len_arr = kv_len.astype(jnp.int32)

    # (nq, B, C, H, D)
    qs = qp.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    ks = kp.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 2, 3, 4)

    def q_body(_, q_in):
        qi, qc = q_in  # chunk index, (B, Cq, H, D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset  # (Cq,)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            ki, kc, vc = kv_in
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)  # (Ck,)
            kr = _repeat_kv(kc, n_rep)  # (B, Ck, H, D)
            vr = _repeat_kv(vc, n_rep)
            s = (
                jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32), kr.astype(jnp.float32))
                * scale
            )
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
            valid = k_pos[None, :] < kv_len_arr[:, None]  # (B, Ck)
            full_mask = mask[None, None] & valid[:, None, None, :]
            s = jnp.where(full_mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vr.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)  # (B, Cq, H, D)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_pad, h, d)[:, :sq]
    return out.astype(q.dtype)


def decode_attention_reference(
    q: jax.Array,  # (B, H, D) — one new token per sequence
    k_cache: jax.Array,  # (B, KV, Smax, D)  — seq-major cache layout
    v_cache: jax.Array,  # (B, KV, Smax, D)
    lengths: jax.Array,  # (B,) number of valid cache entries (incl. new token)
    *,
    softmax_scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, KV, Smax) int8-cache dequant
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-token GQA decode against a (padded) KV cache.

    Grouped-query form: the ``n_rep`` query heads sharing a KV head contract
    against it directly — no materialized ``repeat_kv`` (which would read the
    cache ``n_rep`` x from HBM).  The (B, KV, S, D) cache layout matches the
    dot's batch dims, so no transpose copy of the cache is needed (§Perf C1);
    bf16 operands + f32 accumulation via preferred_element_type avoid a
    materialized f32 cache copy."""
    b, h, d = q.shape
    _, kvh, smax, _ = k_cache.shape
    rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, kvh, rep, d)
    quant = k_cache.dtype == jnp.int8
    kc = k_cache.astype(q.dtype) if quant else k_cache
    s = jnp.einsum(
        "bgrd,bgsd->bgrs", qg, kc, preferred_element_type=jnp.float32
    ) * scale
    if k_scale is not None:
        s = s * k_scale[:, :, None, :]  # per-(b, kv-head, token) dequant
    valid = jnp.arange(smax)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale[:, :, None, :]  # fold V dequant into the probs
    vc = v_cache.astype(q.dtype) if quant else v_cache
    out = jnp.einsum(
        "bgrs,bgsd->bgrd", p.astype(vc.dtype), vc,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_template(cfg) -> dict[str, TensorSpec]:
    d, f = cfg.d_model, cfg.d_ff
    t = {
        "w_up": TensorSpec((d, f), ("d_model", "d_ff"), dtype=cfg.dtype),
        "w_down": TensorSpec((f, d), ("d_ff", "d_model"), dtype=cfg.dtype),
    }
    if cfg.gated_mlp:
        t["w_gate"] = TensorSpec((d, f), ("d_model", "d_ff"), dtype=cfg.dtype)
    return t


def mlp_forward(params: dict, x: jax.Array, cfg) -> jax.Array:
    """x: (..., d_model)."""
    up = x @ params["w_up"]
    if cfg.mlp == "swiglu":
        gate = x @ params["w_gate"]
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(up)
        hidden = r * r
    elif cfg.mlp == "gelu":
        hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(cfg.mlp)
    hidden = shard(hidden, "batch", "seq", "act_d_ff")
    return hidden @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embedding_template(cfg) -> dict[str, TensorSpec]:
    pv = cfg.padded_vocab_size
    t = {"tok": TensorSpec((pv, cfg.d_model), ("vocab", "d_model"), dtype=cfg.dtype)}
    if not cfg.tie_embeddings:
        t["unembed"] = TensorSpec(
            (cfg.d_model, pv), ("d_model", "vocab"), dtype=cfg.dtype
        )
    return t


def vocab_mask_logits(logits: jax.Array, cfg) -> jax.Array:
    """-inf the padded vocab tail so softmax/argmax ignore it."""
    pv = cfg.padded_vocab_size
    if pv == cfg.vocab_size:
        return logits
    valid = jnp.arange(pv) < cfg.vocab_size
    return jnp.where(valid, logits, NEG_INF)


def embed_tokens(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    out = jnp.take(params["tok"], tokens, axis=0)
    return shard(out, "batch", "seq", "act_d_model")


def unembed(params: dict, x: jax.Array, cfg) -> jax.Array:
    w = params["tok"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w
    return logits
