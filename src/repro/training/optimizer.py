"""AdamW optimizer, ZeRO-shardable, dtype-configurable moments.

Built from scratch (no optax) so the moment tensors are declared through the
same :class:`TensorSpec` template machinery as the parameters: each moment
leaf inherits the *parameter's* logical axes, so under the FSDP overlay
(>=100B archs) the Adam states are automatically ZeRO-sharded over
``("data", "model")`` — resident optimizer bytes scale with the full chip
count, which is what makes nemotron-340b / grok-314b trainable on a
16 GB-per-chip v5e pod.

Moments can be held in bf16 (``moment_dtype``) for the >=100B archs: the
update math is always done in f32 (cast up, cast back), costing <0.1% loss
quality in practice while halving optimizer memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32  # bf16 for >=100B archs


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    """Moments mirror the parameter pytree (same shapes => same shardings)."""
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_abstract(params_abstract: Any, cfg: AdamWConfig) -> dict:
    """ShapeDtypeStruct mirror for the dry-run."""
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(sds, params_abstract),
        "v": jax.tree.map(sds, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Any,
    grads: Any,
    opt_state: dict,
    cfg: AdamWConfig,
) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
