"""Distributed train step: loss -> grad -> AdamW, with grad-accumulation
microbatching and remat-over-layers.

``build_train_step`` returns a pure function

    (params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit`` with explicit in/out shardings (the launcher and
the dry-run both consume it).  Microbatching is a ``lax.scan`` over
``cfg.microbatches`` slices of the global batch: activation memory is one
microbatch, gradients accumulate in f32.  Remat happens inside the model's
scan-over-layers (``cfg.remat``), so live activations are one layer x one
microbatch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as TF
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainState:
    """Bundled (params, opt_state, step) helper for drivers/checkpointing."""

    params: Any
    opt_state: dict

    @property
    def step(self) -> int:
        return int(self.opt_state["step"])


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B//n, ...) for each leaf."""

    def re(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(re, batch)


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int | None = None,
    loss_fn: Callable | None = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"tokens": (B, S) i32, "labels": (B, S) i32, ["frames": (B, Sf, d)]}
    """
    n_micro = microbatches if microbatches is not None else max(cfg.microbatches, 1)
    base_loss = loss_fn or (
        lambda p, mb: TF.lm_loss(cfg, p, mb["tokens"], mb["labels"], mb.get("frames"))
    )

    def train_step(params: Any, opt_state: dict, batch: dict):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(base_loss)(params, batch)
        else:
            micro = _split_microbatches(batch, n_micro)

            def body(acc, mb):
                loss_sum, g_acc = acc
                l, g = jax.value_and_grad(base_loss)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g
                )
                return (loss_sum + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros(()), g0), micro)
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: (g / n_micro), g_sum)

        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def make_batch_abstract(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct batch for the dry-run (deliverable e)."""
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family in ("vlm", "encdec"):
        nf = cfg.n_frontend_tokens or 64
        out["frames"] = jax.ShapeDtypeStruct((batch, nf, cfg.d_model), cfg.dtype)
    return out


def batch_axes(cfg: ModelConfig) -> dict:
    """Logical-axis pytree for the batch (consumed by shardings_for_axes)."""
    out = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
    }
    if cfg.family in ("vlm", "encdec"):
        out["frames"] = ("batch", "seq", "act_d_model")
    return out
