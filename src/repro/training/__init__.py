from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.training.train_step import build_train_step, TrainState
from repro.training.checkpoint import save_checkpoint, restore_checkpoint, latest_step
