"""Distributed checkpointing: atomic, resumable, shard-aware.

Fault-tolerance contract (the training half of the paper's App. A.1 story —
the serving half is the parameter pool's >=1-copy invariant):

  * **atomic**: a checkpoint directory is written under ``step_N.tmp`` and
    renamed to ``step_N`` only after every leaf + the manifest have been
    fsynced — a crash mid-write never corrupts the latest checkpoint;
  * **restart**: ``restore_checkpoint(dir)`` returns the newest complete
    step; the train driver resumes from it after any node failure;
  * **shard-aware**: each leaf is saved via ``jax.device_get`` of its
    *addressable* shards and restored with ``jax.device_put`` against the
    target sharding, so a restore can change mesh shape (elastic restart:
    e.g. a 512-chip job resuming on 256 chips after losing a pod).

Storage is a flat ``.npy`` file per leaf keyed by the pytree path, plus a
JSON manifest (structure, shapes, dtypes, step) — no external deps.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically write ``tree`` as checkpoint ``step``; prune old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": {}}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        store = arr
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8...) round-trip as unsigned views —
            # np.load in a fresh process would otherwise see raw void bytes
            store = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        fname = key.replace("/", "__") + ".npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, store)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # prune
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    target: Any,
    *,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``, when given (a matching pytree of
    NamedShardings), re-shards each leaf for the *current* mesh — this is
    what makes restarts elastic across mesh shapes."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    keys = [k for k, _ in _flatten_with_paths(target)]
    leaves_t, treedef = jax.tree_util.tree_flatten(target)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_t)
    )
    new_leaves = []
    for key, tgt, shd in zip(keys, leaves_t, shard_leaves):
        rec = manifest["leaves"][key]
        arr = np.load(os.path.join(path, rec["file"]))
        if str(arr.dtype) != rec["dtype"]:
            arr = arr.view(jnp.dtype(rec["dtype"]))  # undo the storage view
        if shd is not None:
            new_leaves.append(jax.device_put(arr, shd))
        else:
            new_leaves.append(jnp.asarray(arr))
    return treedef.unflatten(new_leaves), step
