"""Committed-baseline support: grandfathered findings, individually justified.

The baseline is a JSON file checked into the repo root.  Entries match
findings on ``(rule, path, symbol)`` — deliberately line-number-free so
unrelated edits to a file do not rot the baseline — and every entry MUST
carry a non-empty ``justification``; the loader rejects entries without
one, so "baseline it and move on" is never silent.

``--update-baseline`` rewrites the file from the current findings with
placeholder justifications that still have to be filled in by hand (the
placeholder fails the next load).
"""

from __future__ import annotations

import dataclasses
import json

from repro.analysis.core import Finding

__all__ = ["Baseline", "PLACEHOLDER"]

PLACEHOLDER = "TODO: justify or fix"


@dataclasses.dataclass
class Baseline:
    entries: list[dict]

    @classmethod
    def empty(cls) -> "Baseline":
        return cls(entries=[])

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
        if not isinstance(data, dict) or data.get("version") != 1:
            raise ValueError(f"{path}: not a v1 simcheck baseline")
        entries = data.get("entries", [])
        for e in entries:
            missing = {"rule", "path", "symbol"} - e.keys()
            if missing:
                raise ValueError(f"{path}: baseline entry missing {sorted(missing)}")
            just = e.get("justification", "").strip()
            if not just or just == PLACEHOLDER:
                raise ValueError(
                    f"{path}: entry {e['rule']}:{e['path']}:{e['symbol']!r} "
                    "has no justification — every grandfathered finding "
                    "must say why it is allowed to stay"
                )
        return cls(entries=list(entries))

    def save(self, path: str) -> None:
        data = {"version": 1, "entries": self.entries}
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(data, fh, indent=2, sort_keys=True)
            fh.write("\n")

    # -- matching ------------------------------------------------------------
    def _keys(self) -> set[tuple[str, str, str]]:
        return {(e["rule"], e["path"], e["symbol"]) for e in self.entries}

    def split(
        self, findings: list[Finding]
    ) -> tuple[list[Finding], list[Finding], list[dict]]:
        """-> (new findings, baselined findings, stale entries).

        Stale entries — baseline lines whose finding no longer fires —
        are reported so a fixed violation gets its entry deleted instead
        of lingering as a free pass for a future regression.
        """
        keys = self._keys()
        new = [f for f in findings if f.key() not in keys]
        old = [f for f in findings if f.key() in keys]
        live = {f.key() for f in findings}
        stale = [
            e
            for e in self.entries
            if (e["rule"], e["path"], e["symbol"]) not in live
        ]
        return new, old, stale

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        entries = [
            {
                "rule": f.rule,
                "path": f.path,
                "symbol": f.symbol,
                "justification": PLACEHOLDER,
            }
            for f in sorted(findings, key=lambda f: f.key())
        ]
        # dedupe identical keys (same symbol can fire on several lines)
        seen: set[tuple[str, str, str]] = set()
        uniq = []
        for e in entries:
            k = (e["rule"], e["path"], e["symbol"])
            if k not in seen:
                seen.add(k)
                uniq.append(e)
        return cls(entries=uniq)
