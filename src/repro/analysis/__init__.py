"""simcheck — repo-specific static analysis.

The repo's correctness story rests on invariants no off-the-shelf linter
knows about: bit-for-bit deterministic replay (goldens, byte-deterministic
incident bundles, the incremental-vs-full FlowSim oracle), a strict import
DAG, exact-float discipline around ``flow_done_eps``, and FlowSim
subscription callbacks that react to failures *inside* the event without
re-entrantly mutating the engine.  ``repro.analysis`` is an AST /
import-graph checker that enforces them:

  * ``determinism``      — no wall-clock / unseeded global RNG in the
                           simulation core;
  * ``set-iteration``    — no order-dependent iteration over sets (or
                           dicts built from sets) in the event path;
  * ``layering``         — imports follow the declarative allowed-edges
                           DAG (``repro.net`` never imports ``repro.obs``,
                           …);
  * ``exact-float``      — ``==``/``!=`` between floats in ``repro.net``
                           goes through ``flow_done_eps`` or carries an
                           explicit pragma;
  * ``event-reentrancy`` — FlowSim ``subscribe`` callbacks never reach
                           mutating engine internals except through the
                           sanctioned reaction APIs.

Run it::

    PYTHONPATH=src python -m repro.analysis.check src/repro \
        --baseline analysis_baseline.json

Suppress a single finding with a trailing pragma on the offending line
(``# simcheck: disable=RULE[,RULE2]``; ``# simcheck: exact-float`` is a
shorthand for the float rule), a whole file with ``# simcheck:
disable-file=RULE`` in its first comment block, or grandfather it with a
justified entry in the committed baseline.
"""

from repro.analysis.core import (
    AnalysisContext,
    Finding,
    Rule,
    SourceUnit,
    all_rules,
    load_tree,
    register,
    run_rules,
)
from repro.analysis.baseline import Baseline
from repro.analysis.config import AnalysisConfig, default_config

__all__ = [
    "AnalysisConfig",
    "AnalysisContext",
    "Baseline",
    "Finding",
    "Rule",
    "SourceUnit",
    "all_rules",
    "default_config",
    "load_tree",
    "register",
    "run_rules",
]
