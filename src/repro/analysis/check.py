"""simcheck CLI — ``python -m repro.analysis.check [paths...]``.

Exit codes: 0 = clean (or every finding baselined), 1 = findings (or
stale baseline entries), 2 = usage/config error.  ``--format json`` /
``--json-out`` emit a machine-readable report (CI uploads it as an
artifact); ``--rule`` filters for local iteration; ``--fix-sorted``
attaches ready-to-apply ``sorted(...)`` patches to iteration-order
findings (printed, never applied); ``--import-graph dot|json`` dumps the
actual import graph instead of checking.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.baseline import Baseline
from repro.analysis.config import default_config
from repro.analysis.core import AnalysisContext, Finding, all_rules, load_tree, run_rules
from repro.analysis.rules.layering import graph_to_dot, graph_to_json, import_graph

__all__ = ["main", "run_check"]


def run_check(
    paths: list[str],
    *,
    config=None,
    baseline: Baseline | None = None,
    only: list[str] | None = None,
    fix_sorted: bool = False,
) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Library entry: -> (new findings, baselined findings, stale entries)."""
    units = load_tree(paths)
    ctx = AnalysisContext(
        config=config if config is not None else default_config(),
        units=units,
        fix_sorted=fix_sorted,
    )
    findings = run_rules(ctx, only=only)
    bl = baseline if baseline is not None else Baseline.empty()
    return bl.split(findings)


def _text_report(
    new: list[Finding], old: list[Finding], stale: list[dict], out
) -> None:
    for f in new:
        print(f.format(), file=out)
        if f.suggestion:
            for line in f.suggestion.splitlines():
                print(f"    {line}", file=out)
    for f in old:
        print(f"{f.format()}  [baselined]", file=out)
    for e in stale:
        print(
            f"stale baseline entry (finding no longer fires — delete it): "
            f"{e['rule']}:{e['path']}:{e['symbol']!r}",
            file=out,
        )
    n_rules = len({f.rule for f in new})
    if new or stale:
        print(
            f"simcheck: {len(new)} finding(s) across {n_rules} rule(s), "
            f"{len(stale)} stale baseline entr(ies)",
            file=out,
        )
    else:
        extra = f" ({len(old)} baselined)" if old else ""
        print(f"simcheck: clean{extra}", file=out)


def _json_report(new, old, stale) -> dict:
    return {
        "findings": [f.as_dict() for f in new],
        "baselined": [f.as_dict() for f in old],
        "stale_baseline_entries": stale,
        "counts": {"new": len(new), "baselined": len(old), "stale": len(stale)},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.check",
        description="repo-specific static analysis: determinism, layering, "
        "set-iteration, exact-float and event-reentrancy invariants",
    )
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/directories to scan (default: src/repro)")
    ap.add_argument("--baseline", help="committed baseline JSON (grandfathered findings)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from current findings "
                    "(justifications must then be filled in by hand)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="RULE",
                    help="run only this rule (repeatable); see --list-rules")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--fix-sorted", action="store_true",
                    help="attach sorted(...) rewrite patches to "
                    "set-iteration findings (printed, not applied)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--json-out", metavar="FILE",
                    help="also write the JSON report to FILE")
    ap.add_argument("--import-graph", choices=("dot", "json"),
                    help="dump the actual import graph and exit")
    ap.add_argument("--import-graph-out", metavar="FILE",
                    help="write the import-graph dump to FILE instead of stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in sorted(all_rules().items()):
            print(f"{rid:18s} {rule.summary}")
        return 0

    if args.import_graph:
        units = load_tree(args.paths)
        graph = import_graph(units)
        text = graph_to_dot(graph) if args.import_graph == "dot" else graph_to_json(graph)
        if args.import_graph_out:
            with open(args.import_graph_out, "w", encoding="utf-8") as fh:
                fh.write(text)
            print(f"wrote {args.import_graph_out}")
        else:
            sys.stdout.write(text)
        return 0

    baseline = Baseline.empty()
    if args.baseline and not args.update_baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except FileNotFoundError:
            print(f"simcheck: baseline {args.baseline} not found", file=sys.stderr)
            return 2
        except ValueError as e:
            print(f"simcheck: {e}", file=sys.stderr)
            return 2

    try:
        new, old, stale = run_check(
            args.paths,
            baseline=baseline,
            only=args.rules,
            fix_sorted=args.fix_sorted,
        )
    except KeyError as e:
        print(f"simcheck: {e.args[0]}", file=sys.stderr)
        return 2

    if args.update_baseline:
        if not args.baseline:
            print("simcheck: --update-baseline requires --baseline", file=sys.stderr)
            return 2
        Baseline.from_findings(new + old).save(args.baseline)
        print(
            f"simcheck: wrote {len(new + old)} entr(ies) to {args.baseline} — "
            "fill in every justification before committing"
        )
        return 0

    report = _json_report(new, old, stale)
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.format == "json":
        json.dump(report, sys.stdout, indent=2, sort_keys=True)
        print()
    else:
        _text_report(new, old, stale, sys.stdout)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    raise SystemExit(main())
