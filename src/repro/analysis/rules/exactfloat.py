"""Rule ``exact-float`` — no bare ``==``/``!=`` between floats in repro.net.

Flow completion in the data plane is an epsilon discipline: the live
engine and ``estimate_transfer_time`` share ``flow_done_eps`` so the
incremental and full solvers settle the same flow at the same instant.
A bare float equality anywhere else in ``repro.net`` is either a logic
bug waiting for an FMA-rounding difference, or a deliberate sentinel
compare — in which case it carries ``# simcheck: exact-float`` (the
shorthand pragma) and the reviewer knows it was deliberate.

Float-typedness is inferred heuristically, no type checker required:
float literals, ``float(...)`` / ``math.inf`` / ``math.nan``, true
division results, names and ``self.X`` attributes annotated ``float``
(function params, locals, dataclass fields of classes in the same file),
and calls to same-file functions annotated ``-> float``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import AnalysisContext, Finding, Rule, SourceUnit, register

__all__ = ["ExactFloatRule"]


def _annotation_is_float(node: ast.expr | None) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Name):
        return node.id == "float"
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.strip() == "float"
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return _annotation_is_float(node.left) or _annotation_is_float(node.right)
    return False


class _FloatEnv:
    """Names/attributes/functions inferred float-typed within one file."""

    def __init__(self, unit: SourceUnit):
        self.float_attrs: set[str] = set()  # dataclass/class fields
        self.float_funcs: set[str] = set()  # same-file defs returning float
        for node in ast.walk(unit.tree):
            if isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, ast.AnnAssign) and isinstance(
                        item.target, ast.Name
                    ):
                        if _annotation_is_float(item.annotation):
                            self.float_attrs.add(item.target.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _annotation_is_float(node.returns):
                    self.float_funcs.add(node.name)

    def scope_names(self, fn: ast.AST) -> set[str]:
        """Float-annotated params and locals of one function."""
        names: set[str] = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = fn.args
            for a in [*args.posonlyargs, *args.args, *args.kwonlyargs]:
                if _annotation_is_float(a.annotation):
                    names.add(a.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
                if _annotation_is_float(node.annotation):
                    names.add(node.target.id)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                if isinstance(node.targets[0], ast.Name) and self._value_is_float(
                    node.value, set()
                ):
                    names.add(node.targets[0].id)
        return names

    def _value_is_float(self, node: ast.expr, local_names: set[str]) -> bool:
        """Expression is float-typed (conservative heuristic)."""
        if isinstance(node, ast.Constant):
            return isinstance(node.value, float)
        if isinstance(node, ast.Name):
            return node.id in local_names
        if isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name) and node.value.id == "math":
                return node.attr in {"inf", "nan", "pi", "e", "tau"}
            return node.attr in self.float_attrs
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name):
                return fn.id == "float" or fn.id in self.float_funcs
            if isinstance(fn, ast.Attribute):
                return fn.attr in self.float_funcs
            return False
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.Div):
                return True  # true division is float-valued
            return self._value_is_float(node.left, local_names) or self._value_is_float(
                node.right, local_names
            )
        if isinstance(node, ast.UnaryOp):
            return self._value_is_float(node.operand, local_names)
        if isinstance(node, ast.IfExp):
            return self._value_is_float(node.body, local_names) or self._value_is_float(
                node.orelse, local_names
            )
        return False


@register
class ExactFloatRule(Rule):
    id = "exact-float"
    summary = "float ==/!= must use flow_done_eps helpers or carry a pragma"

    def check_file(self, unit: SourceUnit, ctx: AnalysisContext) -> Iterator[Finding]:
        cfg = ctx.config
        if not cfg.in_scope(unit.module, cfg.float_eq_scopes):
            return
        env = _FloatEnv(unit)
        # comparisons live inside some enclosing scope; find that scope's
        # float-annotated names once per function
        scopes: list[tuple[ast.AST, set[str]]] = [(unit.tree, set())]
        for node in ast.walk(unit.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node, env.scope_names(node)))
        for scope, names in scopes:
            for node in self._own_compares(scope):
                for op, left, right in self._eq_pairs(node):
                    if env._value_is_float(left, names) or env._value_is_float(
                        right, names
                    ):
                        sym = ast.get_source_segment(unit.text, node) or "<cmp>"
                        yield Finding(
                            rule=self.id,
                            path=unit.path,
                            line=node.lineno,
                            col=node.col_offset,
                            symbol=sym.split("\n")[0][:80],
                            message=(
                                f"exact float {op} in {sym.split(chr(10))[0][:60]!r} — "
                                f"compare through {'/'.join(cfg.float_eq_helpers)} "
                                "(<= eps) or mark the sentinel compare with "
                                "'# simcheck: exact-float'"
                            ),
                        )
                        break  # one finding per comparison chain

    @staticmethod
    def _own_compares(scope: ast.AST):
        """Compare nodes belonging to this scope (not nested functions)."""
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Compare):
                yield node
            stack.extend(ast.iter_child_nodes(node))

    @staticmethod
    def _eq_pairs(cmp: ast.Compare):
        operands = [cmp.left, *cmp.comparators]
        for i, op in enumerate(cmp.ops):
            if isinstance(op, (ast.Eq, ast.NotEq)):
                sym = "==" if isinstance(op, ast.Eq) else "!="
                yield sym, operands[i], operands[i + 1]
