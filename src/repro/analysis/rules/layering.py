"""Rule ``layering`` — imports follow the declarative allowed-edges DAG.

The config (``repro.analysis.config.ALLOWED_EDGES``) maps each package
prefix to the package prefixes it may import from ``repro``; the most
specific source prefix wins, a module's own package is always allowed,
and ``*`` marks unconstrained entrypoint layers.  Both module-level and
function-level (lazy) imports are checked — a lazy import is still a
dependency; the pragma mechanism exists for the rare sanctioned ones
(e.g. ``repro.obs.report``'s ``--sim`` CLI mode driving the simulator it
normally only observes).

The same import scan feeds ``--import-graph dot|json`` dumps so the
*actual* DAG is documentable (see docs/import-graph.dot).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import AnalysisContext, Finding, Rule, SourceUnit, register

__all__ = ["LayeringRule", "collect_imports", "import_graph", "graph_to_dot", "graph_to_json"]


def collect_imports(unit: SourceUnit) -> list[tuple[str, int, int, bool]]:
    """Repro-internal imports of one unit:
    ``(imported module, line, col, is_module_level)``."""
    out: list[tuple[str, int, int, bool]] = []
    toplevel = set(unit.tree.body)
    for node in ast.walk(unit.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.append((a.name, node.lineno, node.col_offset, node in toplevel))
        elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            if node.module == "repro" or node.module.startswith("repro."):
                # record per-alias targets: ``from repro.core import
                # multicast`` depends on repro.core.multicast, not on all
                # of repro.core (symbol imports over-qualify — e.g.
                # repro.core.topology.Topology — which prefix matching
                # absorbs)
                for a in node.names:
                    target = (
                        node.module if a.name == "*" else f"{node.module}.{a.name}"
                    )
                    out.append((target, node.lineno, node.col_offset, node in toplevel))
    return out


def _match_prefix(module: str, prefixes) -> str | None:
    """Longest configured prefix that covers ``module``."""
    best = None
    for p in prefixes:
        if module == p or module.startswith(p + "."):
            if best is None or len(p) > len(best):
                best = p
    return best


@register
class LayeringRule(Rule):
    id = "layering"
    summary = "imports must follow the declarative allowed-edges DAG"

    def check_file(self, unit: SourceUnit, ctx: AnalysisContext) -> Iterator[Finding]:
        edges = ctx.config.allowed_edges
        src_pkg = _match_prefix(unit.module, edges.keys())
        if src_pkg is None:
            return  # module outside any configured layer: unconstrained
        allowed = tuple(edges[src_pkg])
        if "*" in allowed:
            return
        for target, line, col, toplevel in collect_imports(unit):
            if target == src_pkg or target.startswith(src_pkg + "."):
                continue  # intra-package
            if _match_prefix(target, allowed) is not None:
                continue
            kind = "import" if toplevel else "lazy (function-level) import"
            yield Finding(
                rule=self.id,
                path=unit.path,
                line=line,
                col=col,
                symbol=f"{unit.module} -> {target}",
                message=(
                    f"{kind} of {target!r} from layer {src_pkg!r} violates "
                    f"the import DAG (allowed: "
                    f"{', '.join(allowed) if allowed else 'nothing from repro'})"
                ),
            )


# ---------------------------------------------------------------------------
# import-graph dumps
# ---------------------------------------------------------------------------


def import_graph(units: list[SourceUnit]) -> dict:
    """Actual module-level import graph over the scanned units."""
    nodes = sorted({u.module for u in units})

    def collapse(target: str) -> str:
        # map symbol-level targets back onto scanned modules so the graph
        # stays module-granular (repro.net.flows.Flow -> repro.net.flows)
        best = None
        for n in nodes:
            if target == n or target.startswith(n + "."):
                if best is None or len(n) > len(best):
                    best = n
        return best if best is not None else target

    edges = []
    for u in sorted(units, key=lambda u: u.module):
        seen: set[tuple[str, bool]] = set()
        for target, _line, _col, toplevel in collect_imports(u):
            dst = collapse(target)
            k = (dst, toplevel)
            if k in seen or dst == u.module:
                continue
            seen.add(k)
            edges.append({"src": u.module, "dst": dst, "toplevel": toplevel})
    edges.sort(key=lambda e: (e["src"], e["dst"], not e["toplevel"]))
    return {"nodes": nodes, "edges": edges}


def graph_to_json(graph: dict) -> str:
    import json

    return json.dumps(graph, indent=2, sort_keys=True) + "\n"


def graph_to_dot(graph: dict) -> str:
    """Graphviz dump, one cluster per top-level package; dashed = lazy
    (function-level) edges."""
    def pkg(m: str) -> str:
        parts = m.split(".")
        return ".".join(parts[:2]) if len(parts) > 1 else m

    clusters: dict[str, list[str]] = {}
    for n in graph["nodes"]:
        clusters.setdefault(pkg(n), []).append(n)
    lines = ["digraph imports {", "  rankdir=LR;", "  node [shape=box, fontsize=10];"]
    for i, (p, members) in enumerate(sorted(clusters.items())):
        lines.append(f'  subgraph "cluster_{i}" {{')
        lines.append(f'    label="{p}";')
        for m in sorted(members):
            lines.append(f'    "{m}";')
        lines.append("  }")
    for e in graph["edges"]:
        style = "" if e["toplevel"] else " [style=dashed]"
        lines.append(f'  "{e["src"]}" -> "{e["dst"]}"{style};')
    lines.append("}")
    return "\n".join(lines) + "\n"
