"""Rule modules self-register on import (see ``core.register``)."""

from repro.analysis.rules import (  # noqa: F401
    determinism,
    exactfloat,
    iteration,
    layering,
    reentrancy,
)
