"""Rule ``determinism`` — no wall-clock or unseeded global RNG in the core.

A single ``time.time()`` or ``random.random()`` inside the simulation
core breaks every golden trace, every byte-deterministic incident bundle
and the incremental-vs-full FlowSim differential oracle at once — and
does so silently, because nothing diffs against wall-clock.  Banned in
the configured scopes:

  * wall-clock reads (``time.time/perf_counter/monotonic/...``,
    ``datetime.now`` and friends);
  * the global ``random`` module (``random.Random(seed)`` is fine);
  * ``numpy.random`` module-level functions (``np.random.rand`` draws
    from hidden global state) and seedable constructors called WITHOUT a
    seed (``np.random.default_rng()`` seeds from the OS).

Planner modules that report real plan-generation cost as metadata are
allowlisted in the config with a justification.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import AnalysisContext, Finding, Rule, SourceUnit, register

__all__ = ["DeterminismRule"]


@register
class DeterminismRule(Rule):
    id = "determinism"
    summary = "no wall-clock / unseeded global RNG in simulation-core packages"

    def check_file(self, unit: SourceUnit, ctx: AnalysisContext) -> Iterator[Finding]:
        cfg = ctx.config
        if not cfg.in_scope(unit.module, cfg.determinism_scopes):
            return
        if unit.module in cfg.determinism_allowlist:
            return
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            name = unit.dotted_name(node.func)
            if name is None:
                continue
            bad = self._classify(name, node, cfg)
            if bad is not None:
                yield Finding(
                    rule=self.id,
                    path=unit.path,
                    line=node.lineno,
                    col=node.col_offset,
                    symbol=name,
                    message=bad,
                )

    @staticmethod
    def _classify(name: str, call: ast.Call, cfg) -> str | None:
        if name in cfg.wall_clock_calls:
            return (
                f"wall-clock read {name}() in simulation core — goldens and "
                "differential oracles replay on the simulation clock only"
            )
        if name in cfg.seeded_rng_constructors:
            if not call.args and not call.keywords:
                return (
                    f"{name}() without an explicit seed draws entropy from "
                    "the OS — pass a seed so runs replay bit-for-bit"
                )
            return None
        if name.startswith("random."):
            return (
                f"global-state RNG {name}() — use a seeded "
                "numpy.random.default_rng / random.Random instance instead"
            )
        if name.startswith("numpy.random."):
            return (
                f"{name}() draws from numpy's hidden global RNG — use a "
                "seeded numpy.random.default_rng(seed) generator"
            )
        return None
