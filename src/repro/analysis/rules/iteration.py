"""Rule ``set-iteration`` — no order-dependent iteration over sets.

Set iteration order is a function of element hashes and insertion
history; it is NOT part of the repo's replay contract.  In the net /
simulator packages an unsorted ``for x in some_set`` that feeds event
ordering, heap pushes or float accumulation changes goldens between
CPython builds and between logically-equivalent runs.  Flagged:

  * ``for``-loops and comprehension generators whose iterable is
    set-typed (set/frozenset constructors and literals, names inferred
    set-typed from annotations or assignments, unions/intersections of
    sets, ``list()/tuple()/iter()`` of a set — order passthrough);
  * iteration over dicts *built from* sets (``dict.fromkeys(s)``, dict
    comprehensions over a set) including their ``.keys()/.values()/
    .items()`` views.

Not flagged: membership tests, set-typed arguments to order-insensitive
reducers (``sorted/min/max/sum/any/all/len/set/frozenset``), and set
comprehensions (the result carries no order of its own — iterating it
later is what gets flagged).

``--fix-sorted`` attaches a ready-to-apply ``sorted(...)`` rewrite to
each finding (printed, never applied).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import AnalysisContext, Finding, Rule, SourceUnit, register

__all__ = ["SetIterationRule"]

_SETISH = {"set", "frozenset", "Set", "FrozenSet", "AbstractSet", "MutableSet"}


def _annotation_is_set(node: ast.expr | None) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Name):
        return node.id in _SETISH
    if isinstance(node, ast.Attribute):  # typing.Set etc.
        return node.attr in _SETISH
    if isinstance(node, ast.Subscript):
        return _annotation_is_set(node.value)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):  # X | None
        return _annotation_is_set(node.left) or _annotation_is_set(node.right)
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split("[")[0].strip() in _SETISH
    return False


def _target_name(node: ast.expr) -> str | None:
    """``x`` or ``self.x`` as a dotted string; None for anything fancier."""
    if isinstance(node, ast.Name):
        return node.id
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return f"self.{node.attr}"
    return None


class _Env:
    """Names inferred set-typed (or dict-built-from-set) in a scope."""

    def __init__(self, cfg):
        self.names: set[str] = set()
        self.cfg = cfg

    def is_set_expr(self, node: ast.expr) -> bool:
        cfg = self.cfg
        if isinstance(node, ast.Set):
            return True
        if isinstance(node, ast.Name) or isinstance(node, ast.Attribute):
            name = _target_name(node)
            if name is not None and name in self.names:
                return True
            # dict-view of a tracked dict-from-set: self.d.keys() handled
            # in the Call branch below
            return False
        if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)
        ):
            return self.is_set_expr(node.left) or self.is_set_expr(node.right)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name):
                if fn.id in {"set", "frozenset"}:
                    return True
                if (
                    fn.id in cfg.order_passthrough_calls
                    and len(node.args) == 1
                    and self.is_set_expr(node.args[0])
                ):
                    return True
                return False
            if isinstance(fn, ast.Attribute):
                # dict.fromkeys(S) keeps S's arbitrary order
                if (
                    fn.attr == "fromkeys"
                    and node.args
                    and self.is_set_expr(node.args[0])
                ):
                    return True
                # d.keys()/.values()/.items() of a dict built from a set
                if fn.attr in {"keys", "values", "items"} and not node.args:
                    return self.is_set_expr(fn.value)
                # s.union(...)/intersection/difference/copy of a set
                if fn.attr in {
                    "union",
                    "intersection",
                    "difference",
                    "symmetric_difference",
                    "copy",
                }:
                    return self.is_set_expr(fn.value)
            return False
        return False

    def absorb(self, stmt: ast.stmt) -> None:
        """Record set-typed names from one statement."""
        if isinstance(stmt, ast.AnnAssign):
            name = _target_name(stmt.target)
            if name is not None and (
                _annotation_is_set(stmt.annotation)
                or (stmt.value is not None and self.is_set_expr(stmt.value))
            ):
                self.names.add(name)
        elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            name = _target_name(stmt.targets[0])
            if name is not None and self.is_set_expr(stmt.value):
                self.names.add(name)
        elif isinstance(stmt, ast.AugAssign) and isinstance(
            stmt.op, (ast.BitOr, ast.BitAnd)
        ):
            name = _target_name(stmt.target)
            if name is not None and self.is_set_expr(stmt.value):
                self.names.add(name)


def _collect_env(fn: ast.AST, cfg, seed: set[str] | None = None) -> _Env:
    """Set-typed names visible inside ``fn`` (params + every assignment
    anywhere in the body, two passes for forward references)."""
    env = _Env(cfg)
    if seed:
        env.names |= seed
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        args = fn.args
        for a in [
            *args.posonlyargs,
            *args.args,
            *args.kwonlyargs,
            *([args.vararg] if args.vararg else []),
            *([args.kwarg] if args.kwarg else []),
        ]:
            if _annotation_is_set(a.annotation):
                env.names.add(a.arg)
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.stmt):
                env.absorb(node)
    return env


def _scope_walk(scope: ast.AST):
    """Walk a scope's own statements: a Module yields only module-level
    nodes (defs and classes have their own env passes); a function yields
    its whole body except nested ClassDef interiors (their methods are
    dispatched with the class env instead)."""
    if isinstance(scope, ast.Module):
        skip = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        stack = [s for s in scope.body if not isinstance(s, skip)]
    else:
        stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(scope, ast.Module) and isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                continue
            if isinstance(child, ast.ClassDef):
                continue  # nested class methods get their own pass
            stack.append(child)


def _class_self_sets(cls: ast.ClassDef, cfg) -> set[str]:
    """``self.X`` names any method assigns a set to (class-wide view)."""
    env = _Env(cfg)
    for _ in range(2):
        for node in ast.walk(cls):
            if isinstance(node, ast.stmt):
                env.absorb(node)
    return {n for n in env.names if n.startswith("self.")}


@register
class SetIterationRule(Rule):
    id = "set-iteration"
    summary = "iteration over sets / set-built dicts must go through sorted()"

    def check_file(self, unit: SourceUnit, ctx: AnalysisContext) -> Iterator[Finding]:
        cfg = ctx.config
        if not cfg.in_scope(unit.module, cfg.iteration_scopes):
            return
        # module scope: module-level statements only
        yield from self._check_scope(unit, ctx, unit.tree, seed=None)
        # every method gets its class's self.X set-env; top-level functions
        # stand alone; functions nested in functions ride the outer walk
        for node in ast.walk(unit.tree):
            if isinstance(node, ast.ClassDef):
                self_sets = _class_self_sets(node, cfg)
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        yield from self._check_scope(unit, ctx, item, seed=self_sets)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if isinstance(unit.parents.get(node), ast.Module):
                    yield from self._check_scope(unit, ctx, node, seed=None)

    # -- scope check ---------------------------------------------------------
    def _check_scope(
        self, unit: SourceUnit, ctx: AnalysisContext, scope: ast.AST, seed
    ) -> Iterator[Finding]:
        env = _collect_env(scope, ctx.config, seed)
        for node in _scope_walk(scope):
            if isinstance(node, ast.For):
                if env.is_set_expr(node.iter):
                    yield self._finding(unit, ctx, node.iter)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
                if self._inside_order_insensitive(unit, ctx, node):
                    continue
                for gen in node.generators:
                    if env.is_set_expr(gen.iter):
                        yield self._finding(unit, ctx, gen.iter)
            elif isinstance(node, ast.Call):
                # order-sensitive reducers consuming a set directly:
                # sum(float_set) accumulates in hash order
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ctx.config.order_sensitive_reducers
                    and len(node.args) >= 1
                    and env.is_set_expr(node.args[0])
                ):
                    yield self._finding(unit, ctx, node.args[0])

    def _inside_order_insensitive(
        self, unit: SourceUnit, ctx: AnalysisContext, comp: ast.AST
    ) -> bool:
        parent = unit.parents.get(comp)
        return (
            isinstance(parent, ast.Call)
            and isinstance(parent.func, ast.Name)
            and parent.func.id in ctx.config.order_insensitive_calls
            and len(parent.args) == 1
            and parent.args[0] is comp
        )

    def _finding(self, unit: SourceUnit, ctx: AnalysisContext, iter_node: ast.expr) -> Finding:
        seg = ast.get_source_segment(unit.text, iter_node) or "<expr>"
        suggestion = None
        if ctx.fix_sorted and iter_node.lineno == getattr(iter_node, "end_lineno", -1):
            line = unit.line_text(iter_node.lineno)
            patched = (
                line[: iter_node.col_offset]
                + f"sorted({seg})"
                + line[iter_node.end_col_offset :]
            )
            suggestion = (
                f"--- {unit.path}:{iter_node.lineno}\n- {line.strip()}\n+ {patched.strip()}"
            )
        return Finding(
            rule=self.id,
            path=unit.path,
            line=iter_node.lineno,
            col=iter_node.col_offset,
            symbol=seg,
            message=(
                f"iteration over set-ordered {seg!r} — wrap in sorted(...) "
                "(set order is hash/insertion dependent and breaks replay)"
            ),
            suggestion=suggestion,
        )
