"""Rule ``event-reentrancy`` — subscription callbacks must not mutate the
engine except through the sanctioned reaction APIs.

``FlowSim._emit`` runs subscriber callbacks synchronously, *inside* the
event, after aborts have settled but mid-way through the engine's own
bookkeeping.  The repo's whole failure story depends on what those
callbacks are allowed to do: the FleetScheduler re-grants and the
ClusterRuntime re-plans INSIDE the event — but only through the
designed surface (``start``/``start_many``/``remove``, the multicast
execution's ``launch``/``cancel`` wrappers, read-only estimates).  A
callback that reaches ``_evict_failed``, a capacity mutation
(``fail_device`` / ``degrade_link`` / ...), or any solver internal
re-enters the settle loop and corrupts the event stream — the kind of
bug no unit test catches until a golden diverges three PRs later.

This rule finds every callable passed to ``*.subscribe(...)`` across the
scanned tree and walks the call graph from it (name-based, conservative:
``self.m()`` resolves within the class, ``self.attr.m()`` through
constructor assignments, other ``obj.m()`` by unique method name across
the universe, unresolvable calls are opaque).  Sanctioned APIs are
DFS-opaque — passing *through* them is the contract; reaching a
forbidden name any other way is a finding, reported at the offending
call site with the full call path from the callback.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from repro.analysis.core import AnalysisContext, Finding, Rule, SourceUnit, register

__all__ = ["EventReentrancyRule"]


@dataclasses.dataclass
class _Method:
    unit: SourceUnit
    cls: str | None  # None = module-level function
    name: str
    node: ast.AST  # FunctionDef | Lambda


class _Universe:
    """Name-indexed view of every class/method/function in the tree."""

    def __init__(self, units: list[SourceUnit]):
        self.classes: dict[str, dict[str, _Method]] = {}
        self.attr_classes: dict[tuple[str, str], str] = {}  # (cls, attr) -> cls
        self.functions: dict[tuple[str, str], _Method] = {}  # (module, name)
        self.methods_by_name: dict[str, list[_Method]] = {}
        for unit in units:
            for node in ast.walk(unit.tree):
                if isinstance(node, ast.ClassDef):
                    methods = self.classes.setdefault(node.name, {})
                    for item in node.body:
                        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                            m = _Method(unit, node.name, item.name, item)
                            methods[item.name] = m
                            self.methods_by_name.setdefault(item.name, []).append(m)
                    # self.X = ClassName(...) constructor assignments
                    for sub in ast.walk(node):
                        if (
                            isinstance(sub, ast.Assign)
                            and len(sub.targets) == 1
                            and isinstance(sub.targets[0], ast.Attribute)
                            and isinstance(sub.targets[0].value, ast.Name)
                            and sub.targets[0].value.id == "self"
                            and isinstance(sub.value, ast.Call)
                            and isinstance(sub.value.func, ast.Name)
                        ):
                            self.attr_classes[(node.name, sub.targets[0].attr)] = (
                                sub.value.func.id
                            )
                elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # module-level only (class methods handled above)
                    pass
            for stmt in unit.tree.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.functions[(unit.module, stmt.name)] = _Method(
                        unit, None, stmt.name, stmt
                    )

    def resolve_method(self, cls: str | None, name: str) -> _Method | None:
        if cls is not None and name in self.classes.get(cls, {}):
            return self.classes[cls][name]
        cands = self.methods_by_name.get(name, [])
        if len(cands) == 1:
            return cands[0]
        return None


@register
class EventReentrancyRule(Rule):
    id = "event-reentrancy"
    summary = "subscribe callbacks reach engine mutators only via sanctioned APIs"

    def check_project(self, ctx: AnalysisContext) -> Iterator[Finding]:
        uni = _Universe(ctx.units)
        for unit in ctx.units:
            for entry, entry_desc in self._entries(unit, uni, ctx):
                yield from self._walk(entry, entry_desc, uni, ctx)

    # -- entry points --------------------------------------------------------
    def _entries(self, unit: SourceUnit, uni: _Universe, ctx: AnalysisContext):
        sub = ctx.config.subscribe_method
        for node in ast.walk(unit.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == sub
                and node.args
            ):
                continue
            arg = node.args[0]
            cls = self._enclosing_class(unit, node)
            if isinstance(arg, ast.Lambda):
                yield _Method(unit, cls, "<lambda>", arg), f"{unit.module}:<lambda>"
            elif isinstance(arg, ast.Attribute) and isinstance(arg.value, ast.Name):
                if arg.value.id == "self" and cls is not None:
                    m = uni.classes.get(cls, {}).get(arg.attr)
                    if m is None:
                        # instance attribute holding a callable object
                        target_cls = uni.attr_classes.get((cls, arg.attr))
                        if target_cls is not None:
                            m = uni.classes.get(target_cls, {}).get("__call__")
                    if m is not None:
                        yield m, f"{cls}.{arg.attr}"
            elif isinstance(arg, ast.Name):
                m = uni.functions.get((unit.module, arg.id))
                if m is not None:
                    yield m, f"{unit.module}.{arg.id}"

    @staticmethod
    def _enclosing_class(unit: SourceUnit, node: ast.AST) -> str | None:
        cur = node
        while cur is not None:
            cur = unit.parents.get(cur)
            if isinstance(cur, ast.ClassDef):
                return cur.name
        return None

    # -- reachability --------------------------------------------------------
    def _walk(
        self, entry: _Method, entry_desc: str, uni: _Universe, ctx: AnalysisContext
    ) -> Iterator[Finding]:
        cfg = ctx.config
        visited: set[tuple[str | None, str]] = set()
        # stack of (method, path-so-far)
        stack: list[tuple[_Method, tuple[str, ...]]] = [(entry, (entry_desc,))]
        while stack:
            method, path = stack.pop()
            key = (method.cls, method.name)
            if key in visited:
                continue
            visited.add(key)
            for call in self._own_calls(method.node):
                callee = self._callee_name(call)
                if callee is None:
                    continue
                if callee in cfg.reentrancy_sanctioned:
                    continue  # the supported in-event surface: opaque
                if callee in cfg.reentrancy_forbidden:
                    chain = " -> ".join(path + (callee,))
                    yield Finding(
                        rule=self.id,
                        path=method.unit.path,
                        line=call.lineno,
                        col=call.col_offset,
                        symbol=chain,
                        message=(
                            f"subscribe callback reaches engine mutator "
                            f"{callee!r} (path: {chain}) — react through the "
                            f"sanctioned APIs "
                            f"({', '.join(sorted(cfg.reentrancy_sanctioned))}) "
                            "or defer to the next tick"
                        ),
                    )
                    continue
                nxt = self._resolve(call, method, uni)
                if nxt is not None and (nxt.cls, nxt.name) not in visited:
                    label = f"{nxt.cls}.{nxt.name}" if nxt.cls else nxt.name
                    stack.append((nxt, path + (label,)))

    @staticmethod
    def _own_calls(scope: ast.AST):
        """Call nodes in this function, not in defs nested inside it."""
        roots = (
            [scope.body]
            if isinstance(scope, ast.Lambda)
            else list(ast.iter_child_nodes(scope))
        )
        stack = list(roots)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            if isinstance(node, ast.Call):
                yield node
            stack.extend(ast.iter_child_nodes(node))

    @staticmethod
    def _callee_name(call: ast.Call) -> str | None:
        if isinstance(call.func, ast.Attribute):
            return call.func.attr
        if isinstance(call.func, ast.Name):
            return call.func.id
        return None

    def _resolve(self, call: ast.Call, caller: _Method, uni: _Universe) -> _Method | None:
        fn = call.func
        if isinstance(fn, ast.Attribute):
            recv = fn.value
            # self.m(...)
            if isinstance(recv, ast.Name) and recv.id == "self" and caller.cls:
                m = uni.classes.get(caller.cls, {}).get(fn.attr)
                if m is not None:
                    return m
                # self.attr(...) — callable attribute set to Class(...)
                tcls = uni.attr_classes.get((caller.cls, fn.attr))
                if tcls is not None:
                    return uni.classes.get(tcls, {}).get("__call__")
            # self.attr.m(...)
            if (
                isinstance(recv, ast.Attribute)
                and isinstance(recv.value, ast.Name)
                and recv.value.id == "self"
                and caller.cls
            ):
                tcls = uni.attr_classes.get((caller.cls, recv.attr))
                if tcls is not None:
                    m = uni.classes.get(tcls, {}).get(fn.attr)
                    if m is not None:
                        return m
            # any other receiver: unique method name across the universe
            return uni.resolve_method(None, fn.attr)
        if isinstance(fn, ast.Name):
            # same-module function, else a class constructor
            m = uni.functions.get((caller.unit.module, fn.id))
            if m is not None:
                return m
            if fn.id in uni.classes:
                return uni.classes[fn.id].get("__init__")
        return None
