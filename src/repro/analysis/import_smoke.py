"""Import smoke — byte-compile and import every module, executing nothing.

``python -m repro.analysis.import_smoke src benchmarks examples`` walks
each root, byte-compiles every ``*.py`` (syntax rot fails immediately,
even in files no test touches) and then imports each module by dotted
name (dead imports, moved symbols and circular-import regressions in
non-tier-1 files fail fast instead of three PRs later).  "No execution"
means no ``main()`` runs: modules are imported exactly once, so anything
with import-time side effects beyond definitions is itself a bug this
check is designed to surface.

Exit codes: 0 = everything compiles and imports, 1 = failures (each
listed with its traceback tail), 2 = usage error.
"""

from __future__ import annotations

import argparse
import importlib
import os
import py_compile
import sys
import traceback

__all__ = ["main", "iter_modules"]


def iter_modules(root: str) -> list[tuple[str, str]]:
    """-> sorted [(file path, dotted module name)] under ``root``.

    For an ``src``-style root the dotted name starts below the root
    (``src/repro/net/flows.py`` -> ``repro.net.flows``); plain package
    dirs like ``benchmarks`` keep the root dir as the package name.
    """
    out: list[tuple[str, str]] = []
    root = root.rstrip("/")
    # `src` itself is a search path, not a package
    prefix_parent = root if os.path.basename(root) == "src" else os.path.dirname(root)
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, prefix_parent)
            parts = rel[:-3].split(os.sep)
            if parts[-1] == "__init__":
                parts.pop()
            if not parts:
                continue
            out.append((path, ".".join(parts)))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.import_smoke",
        description="byte-compile and import every module under the given "
        "roots (no execution)",
    )
    ap.add_argument("roots", nargs="+", help="e.g. src benchmarks examples")
    args = ap.parse_args(argv)

    failures: list[tuple[str, str, str]] = []  # (stage, target, error)
    n_compiled = n_imported = 0
    for root in args.roots:
        if not os.path.isdir(root):
            print(f"import-smoke: no such directory: {root}", file=sys.stderr)
            return 2
        # make both `src`-style roots and sibling packages importable
        search = root if os.path.basename(root) == "src" else os.path.dirname(root) or "."
        if search not in sys.path:
            sys.path.insert(0, search)
        for path, module in iter_modules(root):
            try:
                py_compile.compile(path, doraise=True)
                n_compiled += 1
            except py_compile.PyCompileError as e:
                failures.append(("compile", path, str(e)))
                continue
            try:
                importlib.import_module(module)
                n_imported += 1
            except Exception:
                tail = traceback.format_exc().strip().splitlines()[-1]
                failures.append(("import", module, tail))

    for stage, target, err in failures:
        print(f"import-smoke: {stage} FAILED {target}: {err}")
    print(
        f"import-smoke: {n_compiled} compiled, {n_imported} imported, "
        f"{len(failures)} failure(s)"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
