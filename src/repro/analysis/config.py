"""Declarative simcheck configuration for THIS repo.

Everything the rules treat as policy lives here — scopes, the allowed
import edges, determinism allowlists, the sanctioned event-reaction APIs —
so a reviewer can audit the repo's invariants in one place without reading
rule implementations.  Tests inject custom configs to drive fixtures.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

__all__ = ["AnalysisConfig", "default_config", "ALLOWED_EDGES"]


# ---------------------------------------------------------------------------
# layering: the import DAG, as allowed edges
# ---------------------------------------------------------------------------
# Key = source package prefix (most specific match wins); value = target
# package prefixes modules under the key may import from ``repro``.  A
# module's own matched package is always allowed (intra-package imports).
# ``*`` = unconstrained (entrypoint layers).
#
# The constraints the repo's history made load-bearing:
#   * repro.net never imports repro.obs / repro.serving (PR 6 duck-typed
#     the tracer rather than add the edge);
#   * repro.obs never imports repro.serving or repro.core.simulator (the
#     observer must not depend on the observed);
#   * repro.core never imports repro.serving (PR 10 moved the trace
#     generators to repro.workloads to kill the last such edge);
#   * repro.workloads is the bottom: no repro imports at all.
ALLOWED_EDGES: dict[str, tuple[str, ...]] = {
    "repro.workloads": (),
    "repro.distributed": (),
    "repro.data": (),
    "repro.analysis": (),
    "repro.models": ("repro.distributed",),
    "repro.configs": ("repro.models", "repro.distributed"),
    "repro.kernels": ("repro.models",),
    "repro.training": ("repro.models", "repro.distributed"),
    "repro.net": ("repro.core.topology", "repro.core.multicast"),
    "repro.obs": ("repro.net", "repro.workloads"),
    "repro.core": (
        "repro.net",
        "repro.obs",
        "repro.models",
        "repro.configs",
        "repro.workloads",
        "repro.distributed",
    ),
    "repro.serving": (
        "repro.core",
        "repro.net",
        "repro.obs",
        "repro.models",
        "repro.configs",
        "repro.workloads",
        "repro.distributed",
    ),
    # entrypoints: may import anything
    "repro.launch": ("*",),
}


@dataclasses.dataclass
class AnalysisConfig:
    # -- determinism ---------------------------------------------------------
    #: packages whose code must be wall-clock- and global-RNG-free
    determinism_scopes: tuple[str, ...] = (
        "repro.net",
        "repro.core",
        "repro.obs",
        "repro.serving",
    )
    #: module -> justification.  These measure REAL planning time as
    #: metadata (never simulation time), mirroring the paper's reported
    #: plan-generation costs.
    determinism_allowlist: Mapping[str, str] = dataclasses.field(
        default_factory=lambda: {
            "repro.core.multicast": "planner wall-clock gen_seconds metadata "
            "(Algorithm-11 generation cost, not simulation time)",
            "repro.core.zigzag": "ILP plan-generation wall-clock ms metadata",
        }
    )
    #: call prefixes that are wall-clock reads
    wall_clock_calls: tuple[str, ...] = (
        "time.time",
        "time.time_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.process_time",
        "time.process_time_ns",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.date.today",
    )
    #: np.random constructors that are fine WHEN given an explicit seed
    seeded_rng_constructors: tuple[str, ...] = (
        "numpy.random.default_rng",
        "numpy.random.Generator",
        "numpy.random.SeedSequence",
        "numpy.random.PCG64",
        "numpy.random.Philox",
        "numpy.random.MT19937",
        "random.Random",
    )

    # -- set-iteration -------------------------------------------------------
    #: packages where event ordering is fed by iteration order
    iteration_scopes: tuple[str, ...] = ("repro.net", "repro.core.simulator")
    #: order-insensitive consumers: a set used as the sole iterable of
    #: these calls cannot leak ordering.  ``sum`` is deliberately NOT here:
    #: float addition is non-associative, so summing a set of floats in
    #: hash order is exactly the replay hazard this rule exists to catch.
    order_insensitive_calls: frozenset[str] = frozenset(
        {"sorted", "len", "min", "max", "any", "all", "set", "frozenset"}
    )
    #: calls that preserve their argument's (arbitrary) iteration order
    order_passthrough_calls: frozenset[str] = frozenset({"list", "tuple", "iter"})
    #: reducers whose result depends on consumption order even without a
    #: visible loop (non-associative float accumulation)
    order_sensitive_reducers: frozenset[str] = frozenset({"sum"})

    # -- layering ------------------------------------------------------------
    allowed_edges: Mapping[str, Sequence[str]] = dataclasses.field(
        default_factory=lambda: dict(ALLOWED_EDGES)
    )

    # -- exact-float ---------------------------------------------------------
    float_eq_scopes: tuple[str, ...] = ("repro.net",)
    #: epsilon helpers whose *call sites* establish sanctioned tolerance
    #: comparisons (==/!= touching their results is still flagged — the
    #: helpers are used with <=, never ==)
    float_eq_helpers: tuple[str, ...] = ("flow_done_eps",)

    # -- event-reentrancy ----------------------------------------------------
    #: method name registering a callback on the engine
    subscribe_method: str = "subscribe"
    #: engine internals a subscription callback must never reach: capacity
    #: mutations re-enter the full solve and re-emit events; underscore
    #: internals assume the settle loop's intermediate state
    reentrancy_forbidden: frozenset[str] = frozenset(
        {
            "_evict_failed",
            "_recompute",
            "_recompute_component",
            "_settle",
            "_set_path",
            "_cal_push",
            "_cal_pop",
            "_emit",
            "fail_link",
            "fail_device",
            "fail_leaf",
            "degrade_link",
            "recover_link",
            "recover_device",
        }
    )
    #: sanctioned reaction APIs — safe re-entry points the engine defines
    #: for use INSIDE an event.  The reachability walk treats them as
    #: opaque: calls *through* them are the supported contract.
    reentrancy_sanctioned: frozenset[str] = frozenset(
        {
            # FlowSim's in-event surface: starting/removing flows during a
            # failure event is the designed reaction path (aborts have
            # settled by emission time); estimates never mutate
            "start",
            "start_many",
            "remove",
            "estimate_transfer_time",
            # multicast execution wrappers over the same surface
            "launch",
            "cancel",
        }
    )

    # -- suffix match helpers ------------------------------------------------
    def in_scope(self, module: str, scopes: Sequence[str]) -> bool:
        return any(module == s or module.startswith(s + ".") for s in scopes)


def default_config() -> AnalysisConfig:
    return AnalysisConfig()
