"""simcheck framework core: source model, pragmas, rule registry, driver.

Everything here is stdlib-only on purpose — the CI static-analysis job
runs the checker before any heavy dependency is installed, so a layering
violation fails in seconds, not after a full environment build.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Callable, Iterable, Iterator, Mapping

__all__ = [
    "Finding",
    "SourceUnit",
    "AnalysisContext",
    "Rule",
    "register",
    "all_rules",
    "load_tree",
    "run_rules",
    "module_name_for",
]


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``symbol`` is a line-number-independent handle (the offending call /
    import / method chain) so baseline entries survive unrelated edits to
    the same file.
    """

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    symbol: str = ""
    suggestion: str | None = None  # e.g. --fix-sorted patch text

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)

    def as_dict(self) -> dict:
        d = {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "symbol": self.symbol,
            "message": self.message,
        }
        if self.suggestion:
            d["suggestion"] = self.suggestion
        return d

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

_PRAGMA_RE = re.compile(r"#\s*simcheck:\s*(?P<body>[^#]*)")
#: rule names are kebab-case words (or ``*``); a ``-- free text`` tail on
#: the pragma is a human-facing justification, not part of the rule list
_DISABLE_RE = re.compile(
    r"disable(?P<scope>-file)?\s*=\s*(?P<rules>(?:[\w*-]+)(?:\s*,\s*[\w*-]+)*)"
)
#: bare shorthands: ``# simcheck: exact-float`` == ``disable=exact-float``
_SHORTHAND_RULES = frozenset({"exact-float"})


def _parse_pragma(comment: str) -> tuple[frozenset[str], frozenset[str]]:
    """-> (line-disabled rules, file-disabled rules); ``*`` disables all."""
    m = _PRAGMA_RE.search(comment)
    if m is None:
        return frozenset(), frozenset()
    body = m.group("body").strip()
    line_rules: set[str] = set()
    file_rules: set[str] = set()
    matched = False
    for dm in _DISABLE_RE.finditer(body):
        matched = True
        rules = {r.strip() for r in dm.group("rules").split(",") if r.strip()}
        (file_rules if dm.group("scope") else line_rules).update(rules)
    if not matched:
        # shorthand form: the body is a bare rule name (before any "--"
        # free-text justification)
        name = body.split("--")[0].strip()
        if name in _SHORTHAND_RULES:
            line_rules.add(name)
    return frozenset(line_rules), frozenset(file_rules)


def _collect_pragmas(text: str) -> tuple[dict[int, frozenset[str]], frozenset[str]]:
    """Map line -> rules disabled on that line, plus file-wide disables.

    A standalone pragma comment (nothing but the comment on its line)
    applies to the *next* source line, so multi-line statements can carry
    a pragma without fighting formatters.
    """
    per_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return {}, frozenset()
    lines = text.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        line_rules, file_rules = _parse_pragma(tok.string)
        file_wide |= file_rules
        if not line_rules:
            continue
        row = tok.start[0]
        src_line = lines[row - 1] if row - 1 < len(lines) else ""
        standalone = src_line.strip().startswith("#")
        target = row + 1 if standalone else row
        per_line.setdefault(target, set()).update(line_rules)
        # a pragma on the first line of a multi-line statement covers the
        # statement's header line either way
        per_line.setdefault(row, set()).update(line_rules)
    return {k: frozenset(v) for k, v in per_line.items()}, frozenset(file_wide)


# ---------------------------------------------------------------------------
# source units
# ---------------------------------------------------------------------------


def module_name_for(path: str) -> str:
    """Dotted module name for a file path.

    Rooted at the last path component named ``repro`` (the import root
    this repo uses), falling back to the bare stem for out-of-tree files
    such as test fixtures.
    """
    parts = list(os.path.normpath(path).split(os.sep))
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts.pop()
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return ".".join(parts[i:])
    return parts[-1] if parts else ""


class SourceUnit:
    """One parsed python file plus its pragma map and import-alias table."""

    def __init__(self, path: str, text: str, module: str | None = None):
        self.path = path.replace(os.sep, "/")
        self.text = text
        self.module = module if module is not None else module_name_for(path)
        self.tree = ast.parse(text, filename=path)
        self.line_pragmas, self.file_pragmas = _collect_pragmas(text)
        self._parents: dict[ast.AST, ast.AST] | None = None
        self._aliases: dict[str, str] | None = None

    # -- pragma queries ------------------------------------------------------
    def disabled(self, rule: str, line: int) -> bool:
        if rule in self.file_pragmas or "*" in self.file_pragmas:
            return True
        rules = self.line_pragmas.get(line, frozenset())
        return rule in rules or "*" in rules

    # -- structure helpers ---------------------------------------------------
    @property
    def parents(self) -> Mapping[ast.AST, ast.AST]:
        """Child node -> parent node map (built lazily)."""
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    @property
    def aliases(self) -> Mapping[str, str]:
        """Local name -> canonical dotted path from import statements.

        ``import numpy as np`` -> ``{"np": "numpy"}``; ``from time import
        perf_counter as pc`` -> ``{"pc": "time.perf_counter"}``.
        """
        if self._aliases is None:
            out: dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        out[a.asname or a.name.split(".")[0]] = (
                            a.name if a.asname else a.name.split(".")[0]
                        )
                        if a.asname:
                            out[a.asname] = a.name
                elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                    for a in node.names:
                        if a.name == "*":
                            continue
                        out[a.asname or a.name] = f"{node.module}.{a.name}"
            self._aliases = out
        return self._aliases

    def dotted_name(self, node: ast.AST) -> str | None:
        """Canonical dotted path of a Name/Attribute chain, resolving
        import aliases (``np.random.rand`` -> ``numpy.random.rand``)."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])

    def line_text(self, line: int) -> str:
        lines = self.text.splitlines()
        return lines[line - 1] if 0 < line <= len(lines) else ""


def load_tree(
    roots: Iterable[str], *, exclude: Iterable[str] = ("__pycache__",)
) -> list[SourceUnit]:
    """Parse every ``*.py`` under each root (or a single file root) into
    SourceUnits, sorted by path for deterministic reports."""
    excl = set(exclude)
    files: list[str] = []
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames if d not in excl)
            files.extend(
                os.path.join(dirpath, f) for f in filenames if f.endswith(".py")
            )
    units = []
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            units.append(SourceUnit(f, fh.read()))
    return units


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalysisContext:
    """Everything a rule sees: the config and the full unit universe (the
    project-level rules — layering, reentrancy — need cross-file state)."""

    config: "AnalysisConfig"  # noqa: F821 - repro.analysis.config
    units: list[SourceUnit]
    fix_sorted: bool = False  # iteration rule: emit rewrite suggestions

    def unit_by_module(self, module: str) -> SourceUnit | None:
        for u in self.units:
            if u.module == module:
                return u
        return None


class Rule:
    """Base class.  ``check_file`` runs per unit; ``check_project`` runs
    once over the whole universe.  Findings on pragma-disabled lines are
    filtered by the driver, not the rule."""

    id: str = ""
    summary: str = ""

    def check_file(self, unit: SourceUnit, ctx: AnalysisContext) -> Iterator[Finding]:
        return iter(())

    def check_project(self, ctx: AnalysisContext) -> Iterator[Finding]:
        return iter(())


_REGISTRY: dict[str, Rule] = {}


def register(rule_cls: type[Rule]) -> type[Rule]:
    """Class decorator adding a rule to the global registry."""
    inst = rule_cls()
    if not inst.id:
        raise ValueError(f"rule {rule_cls.__name__} has no id")
    _REGISTRY[inst.id] = inst
    return rule_cls


def all_rules() -> dict[str, Rule]:
    # import for side effect: rule modules self-register
    from repro.analysis import rules as _rules  # noqa: F401

    return dict(_REGISTRY)


def run_rules(
    ctx: AnalysisContext,
    *,
    only: Iterable[str] | None = None,
) -> list[Finding]:
    """Run (a filtered set of) registered rules; returns pragma-filtered
    findings sorted by (path, line, rule)."""
    rules = all_rules()
    wanted = set(only) if only is not None else None
    if wanted is not None:
        unknown = wanted - rules.keys()
        if unknown:
            raise KeyError(f"unknown rule(s): {', '.join(sorted(unknown))}")
    findings: list[Finding] = []
    units_by_path = {u.path: u for u in ctx.units}
    for rid in sorted(rules):
        if wanted is not None and rid not in wanted:
            continue
        rule = rules[rid]
        produced: list[Finding] = []
        for unit in ctx.units:
            produced.extend(rule.check_file(unit, ctx))
        produced.extend(rule.check_project(ctx))
        for f in produced:
            unit = units_by_path.get(f.path)
            if unit is not None and unit.disabled(f.rule, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.symbol))
    return findings
