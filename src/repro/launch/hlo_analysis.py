"""Loop-aware HLO analysis for the roofline terms.

``compiled.cost_analysis()`` visits each ``while`` body ONCE (verified
empirically: a scan of 10 matmuls reports ~1 matmul of FLOPs), but our models
are scans over layers/microbatches, so every roofline quantity must be
multiplied by loop trip counts.  This module parses the post-SPMD HLO text
(per-device program, two-phase: tokenize all computations, then analyze with
cross-computation knowledge) and reports:

  * ``dot_flops``          — 2 * prod(result) * prod(contracting dims) per
                             ``dot`` (MXU work; elementwise flops are <2% for
                             these models and are excluded — documented);
  * ``collective_bytes``   — sum of *operand* bytes of all-reduce /
                             all-gather / reduce-scatter / all-to-all /
                             collective-permute (incl. ``-start`` forms),
                             i.e. per-device bytes offered to the ICI;
  * ``hbm_bytes``          — an HBM-traffic proxy: operand+result bytes of
                             every materializing top-level instruction, with
                             three aliasing-aware corrections (below);
  * per-collective-op byte/count breakdowns (drives §Perf hypotheses).

HBM corrections (all verified against granite decode_32k where the naive
proxy overcounted 80x):
  1. ``dynamic-update-slice`` aliases its target in place -> traffic is
     2 x update bytes, not the full loop-carried buffer;
  2. a fusion whose ROOT is a dynamic-update-slice writes only the update
     region (XLA's in-place DUS fusion) -> result write = update bytes;
  3. a fusion operand that the fused computation consumes ONLY through
     ``dynamic-slice`` ops is read at the slice size, not the full buffer
     (scan bodies receive whole stacked caches but touch one layer);
  4. ``convert`` instructions (and pure-convert wrapped fusions) are CPU
     dtype legalization — the CPU backend has no bf16 MXU, so it casts whole
     stacked caches/weights to f32 around every dot.  On TPU these fuse into
     the consumer, so converts are skipped and operand sizes resolve
     *through* them to the original (bf16) buffer;
  5. ``copy`` of an entry parameter is a donation artifact (TPU aliases
     donated buffers through the while body in place) — skipped in ENTRY.

Trip counts come from each while-condition's ``constant(N)`` pattern, which
is what ``lax.scan`` emits.  HLO instructions reference operands by NAME, so
a per-computation symbol table resolves operand byte sizes.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_IO = {
    "parameter", "constant", "tuple", "get-tuple-element", "while",
    "conditional", "call", "bitcast", "after-all", "partition-id",
    "replica-id", "rng-get-and-update-state", "iota", "custom-call",
    "opt-barrier",
}

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_ROOT_RE = re.compile(r"^\s*ROOT\s")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _type_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _parse_type(s: str, start: int) -> tuple[str, int]:
    if start < len(s) and s[start] == "(":
        depth = 0
        for i in range(start, len(s)):
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
                if depth == 0:
                    return s[start : i + 1], i + 1
        return s[start:], len(s)
    m = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", s[start:])
    if m:
        return m.group(0), start + m.end()
    return "", start


def _matching_paren(s: str, open_idx: int) -> int:
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_text: str
    operands: list  # operand names
    operand_text: str
    attrs: str
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list = dataclasses.field(default_factory=list)
    symtab: dict = dataclasses.field(default_factory=dict)
    max_const: int = 1


@dataclasses.dataclass
class HloReport:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    coll_by_op: dict
    coll_count: dict
    raw_flops: float | None = None
    raw_bytes: float | None = None

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "coll_by_op": dict(self.coll_by_op),
            "coll_count": dict(self.coll_count),
            "raw_flops": self.raw_flops,
            "raw_bytes": self.raw_bytes,
        }


# ---------------------------------------------------------------------------
# Phase 1: tokenize
# ---------------------------------------------------------------------------


def tokenize(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
            m = _COMP_HEADER.match(s)
            if m:
                cur = comps.setdefault(m.group(2), Computation(m.group(2)))
                if m.group(1):
                    entry = m.group(2)
                continue
        if cur is None:
            continue
        im = _INSTR_RE.match(s)
        if im is None:
            continue
        name = im.group(1)
        type_text, pos = _parse_type(s, im.end())
        if not type_text:
            continue
        om = re.match(r"\s*([\w\-]+)\s*\(", s[pos:])
        if om is None:
            continue
        op = om.group(1)
        open_idx = pos + om.end() - 1
        close_idx = _matching_paren(s, open_idx)
        operand_text = s[open_idx + 1 : close_idx]
        attrs = s[close_idx + 1 :]
        for c in _CONST_RE.findall(s):
            cur.max_const = max(cur.max_const, int(c))
        instr = Instr(
            name=name,
            op=op,
            type_text=type_text,
            operands=_OPERAND_NAME_RE.findall(operand_text),
            operand_text=operand_text,
            attrs=attrs,
            is_root=bool(_ROOT_RE.match(s)),
        )
        cur.instrs.append(instr)
        cur.symtab[name] = type_text
    return comps, entry


# ---------------------------------------------------------------------------
# Phase 2: analyze
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _FusionInfo:
    """How a fused computation touches its parameters + what it writes."""

    # param index -> bytes actually read (slice-aware); missing = full
    param_read: dict = dataclasses.field(default_factory=dict)
    # ROOT dynamic-update-slice -> bytes written (update size); None = full
    root_write: int | None = None
    root_target_idx: int | None = None  # fusion operand aliased by the DUS
    dot_flops: float = 0.0
    pure_convert: bool = False  # body is just parameter(s) + convert


def _fusion_info(comp: Computation) -> _FusionInfo:
    info = _FusionInfo()
    param_of: dict[str, int] = {}
    instr_by_name = {i.name: i for i in comp.instrs}
    uses: dict[str, list[Instr]] = defaultdict(list)
    for ins in comp.instrs:
        if ins.op == "parameter":
            # the true index is in the instruction text: parameter(N)
            m = re.match(r"\s*(\d+)", ins.operand_text)
            if m:
                param_of[ins.name] = int(m.group(1))
        for o in ins.operands:
            uses[o].append(ins)
    for pname, idx in param_of.items():
        consumers = uses.get(pname, [])
        if consumers and all(
            c.op == "dynamic-slice" and c.operands and c.operands[0] == pname
            for c in consumers
        ):
            info.param_read[idx] = sum(_type_bytes(c.type_text) for c in consumers)

    def trace_param(name: str, depth: int = 0) -> int | None:
        if depth > 6:
            return None
        ins = instr_by_name.get(name)
        if ins is None:
            return None
        if ins.op == "parameter":
            return param_of.get(name)
        if ins.op in ("convert", "bitcast", "copy") and ins.operands:
            return trace_param(ins.operands[0], depth + 1)
        return None

    def eff_local(name: str, depth: int = 0) -> int:
        """Bytes of `name` resolved through convert/bitcast chains (min)."""
        ins = instr_by_name.get(name)
        if ins is None or depth > 8:
            return _type_bytes(comp.symtab.get(name, ""))
        own = _type_bytes(ins.type_text)
        if ins.op in ("convert", "bitcast", "copy") and ins.operands:
            return min(own, eff_local(ins.operands[0], depth + 1))
        return own

    # the effective root: descend through convert/bitcast wrappers (CPU
    # legalization round-trips bf16 caches through f32 around the DUS)
    root = next((i for i in comp.instrs if i.is_root), None)
    depth = 0
    while (
        root is not None
        and root.op in ("convert", "bitcast", "copy")
        and root.operands
        and depth < 8
    ):
        root = instr_by_name.get(root.operands[0])
        depth += 1
    if root is not None and root.op == "dynamic-update-slice" and len(root.operands) >= 2:
        info.root_write = eff_local(root.operands[1])
        # the aliased target: trace operand 0 back to its parameter index so
        # the caller reads only the update region of that operand
        info.root_target_idx = trace_param(root.operands[0])
    body_ops = {i.op for i in comp.instrs if i.op != "parameter"}
    # layout/dtype-only fusion: converts, transposes, copies — on TPU these
    # fold into layout assignment / dot operands rather than HBM round-trips
    info.pure_convert = bool(body_ops) and body_ops <= {
        "convert", "copy", "bitcast", "transpose", "reshape", "broadcast",
        "constant",
    }
    info.dot_flops = _comp_dot_flops(comp)
    return info


def _comp_dot_flops(comp: Computation) -> float:
    total = 0.0
    for ins in comp.instrs:
        if ins.op != "dot":
            continue
        cm = _LHS_CONTRACT_RE.search(ins.attrs)
        if cm is None or not ins.operands:
            continue
        lhs_type = comp.symtab.get(ins.operands[0], "")
        lm = _SHAPE_RE.search(lhs_type)
        rm = _SHAPE_RE.search(ins.type_text)
        if not (lm and rm):
            continue
        lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
        r_elems = 1
        if rm.group(2):
            for d in rm.group(2).split(","):
                r_elems *= int(d)
        c_elems = 1
        for ci in (cm.group(1).split(",") if cm.group(1) else []):
            if int(ci) < len(lhs_dims):
                c_elems *= lhs_dims[int(ci)]
        total += 2.0 * r_elems * c_elems
    return total


def analyze(text: str, entry: str | None = None, debug_sink: list | None = None) -> HloReport:
    comps, entry_name = tokenize(text)
    if not comps:
        return HloReport(0.0, 0.0, 0.0, {}, {})
    entry = entry or entry_name
    if entry is None:
        called: set[str] = set()
        for comp in comps.values():
            for ins in comp.instrs:
                for c in _CALLS_RE.findall(ins.attrs):
                    called.add(c)
                wm = _COND_BODY_RE.search(ins.attrs)
                if wm:
                    called.update(wm.groups())
        entries = [n for n in comps if n not in called]
        entry = entries[-1] if entries else next(iter(comps))

    # pre-compute fusion info for every computation used as a fusion body
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion" or ins.op == "conditional":
                fusion_bodies.update(_CALLS_RE.findall(ins.attrs))
    finfo = {n: _fusion_info(comps[n]) for n in fusion_bodies if n in comps}

    memo: dict[str, tuple] = {}
    visiting: set[str] = set()

    def comp_local(comp: Computation, is_entry: bool = False) -> tuple:
        """(flops, hbm, coll, by_op, cnt, calls) for one computation body."""
        f = h = c = 0.0
        by_op: dict[str, float] = {}
        cnt: dict[str, int] = {}
        calls: list[tuple[str, str]] = []  # (callee, kind)

        # effective bytes per instruction: resolve through converts / pure-
        # convert fusions so bf16 tensors legalized to f32 on CPU count at
        # their TPU (bf16) size
        instr_by_name = {i.name: i for i in comp.instrs}
        eff_cache: dict[str, int] = {}

        def eff(name: str) -> int:
            if name in eff_cache:
                return eff_cache[name]
            ins = instr_by_name.get(name)
            if ins is None:
                eff_cache[name] = _type_bytes(comp.symtab.get(name, ""))
                return eff_cache[name]
            own = _type_bytes(ins.type_text)
            eff_cache[name] = own  # guard cycles
            if ins.op in ("convert", "bitcast", "copy") and ins.operands:
                own = min(own, eff(ins.operands[0]))
            elif ins.op == "fusion" and ins.operands:
                callee = (_CALLS_RE.findall(ins.attrs) or [None])[0]
                fi = finfo.get(callee)
                if fi is not None and fi.pure_convert:
                    own = min(own, eff(ins.operands[0]))
            eff_cache[name] = own
            return own

        def is_param_alias(name: str, depth: int = 0) -> bool:
            """True if `name` aliases a (donated) entry parameter or a while
            result — entry-level copies of loop-carried state are buffer-
            aliasing artifacts the TPU backend elides with donation."""
            if depth > 4:
                return False
            ins = instr_by_name.get(name)
            if ins is None:
                return False
            if ins.op in ("parameter", "while"):
                return True
            if ins.op in ("get-tuple-element", "bitcast", "copy") and ins.operands:
                return is_param_alias(ins.operands[0], depth + 1)
            return False

        def note(ins, bytes_):
            if debug_sink is not None and bytes_ > 1e6:
                debug_sink.append((bytes_, comp.name, ins.op, ins.name, ins.type_text[:48]))

        for ins in comp.instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if ins.op.endswith("-done"):
                continue
            if base == "convert":
                continue  # fuses into the consumer on TPU (correction 4)
            if base == "copy" and is_entry and ins.operands and is_param_alias(ins.operands[0]):
                continue  # donation artifact (correction 5)
            if base == "fusion":
                callee0 = (_CALLS_RE.findall(ins.attrs) or [None])[0]
                fi0 = finfo.get(callee0)
                if fi0 is not None and fi0.pure_convert:
                    continue  # wrapped convert — fuses on TPU
            operand_bytes = sum(eff(o) for o in ins.operands)
            result_bytes = _type_bytes(ins.type_text)

            if base in _COLLECTIVES:
                c += operand_bytes
                by_op[base] = by_op.get(base, 0.0) + operand_bytes
                cnt[base] = cnt.get(base, 0) + 1
                h += operand_bytes + result_bytes
                note(ins, operand_bytes + result_bytes)
                continue
            if base == "dot":
                f += _dot_flops_one(comp, ins)
                h += operand_bytes + result_bytes
                note(ins, operand_bytes + result_bytes)
                continue
            if base == "while":
                wm = _COND_BODY_RE.search(ins.attrs)
                if wm:
                    calls.append((wm.group(1), "cond"))
                    calls.append((wm.group(2), "body"))
                continue
            if base == "fusion":
                for callee in _CALLS_RE.findall(ins.attrs):
                    calls.append((callee, "fusion"))
                    fi = finfo.get(callee)
                    if fi is None:
                        h += operand_bytes + result_bytes
                        note(ins, operand_bytes + result_bytes)
                        continue
                    # slice-aware operand reads; the DUS-aliased target is
                    # read only over the update region (read-modify-write)
                    read = 0
                    for i_op, oname in enumerate(ins.operands):
                        if fi.root_write is not None and i_op == fi.root_target_idx:
                            read += fi.root_write
                        else:
                            read += fi.param_read.get(i_op, eff(oname))
                    write = fi.root_write if fi.root_write is not None else result_bytes
                    h += read + write
                    note(ins, read + write)
                continue
            if base == "dynamic-update-slice":
                upd = eff(ins.operands[1]) if len(ins.operands) > 1 else 0
                h += 2 * upd
                note(ins, 2 * upd)
                continue
            if base == "dynamic-slice":
                h += 2 * result_bytes
                note(ins, 2 * result_bytes)
                continue
            if base == "conditional":
                for callee in _CALLS_RE.findall(ins.attrs):
                    calls.append((callee, "fusion"))
                continue
            if base not in _SKIP_IO:
                h += operand_bytes + result_bytes
                note(ins, operand_bytes + result_bytes)
        return f, h, c, by_op, cnt, calls

    def _dot_flops_one(comp: Computation, ins: Instr) -> float:
        cm = _LHS_CONTRACT_RE.search(ins.attrs)
        if cm is None or not ins.operands:
            return 0.0
        lm = _SHAPE_RE.search(comp.symtab.get(ins.operands[0], ""))
        rm = _SHAPE_RE.search(ins.type_text)
        if not (lm and rm):
            return 0.0
        lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
        r_elems = 1
        if rm.group(2):
            for d in rm.group(2).split(","):
                r_elems *= int(d)
        c_elems = 1
        for ci in (cm.group(1).split(",") if cm.group(1) else []):
            if int(ci) < len(lhs_dims):
                c_elems *= lhs_dims[int(ci)]
        return 2.0 * r_elems * c_elems

    def total(name: str) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in visiting:
            return (0.0, 0.0, 0.0, {}, {})
        visiting.add(name)
        comp = comps[name]
        f, h, c, by_op, cnt, calls = comp_local(comp, is_entry=(name == entry))
        # fused computations contribute their internal dot flops
        for callee, kind in calls:
            if kind == "cond":
                continue
            if kind == "fusion":
                fi = finfo.get(callee)
                if fi is not None:
                    f += fi.dot_flops
                    continue
            cf, ch, cc, cb, ccnt = total(callee)
            mult = 1
            if kind == "body":
                idx = calls.index((callee, "body"))
                cond = calls[idx - 1][0] if idx > 0 else None
                if cond in comps:
                    mult = max(comps[cond].max_const, 1)
            f += cf * mult
            h += ch * mult
            c += cc * mult
            for k, v in cb.items():
                by_op[k] = by_op.get(k, 0.0) + v * mult
            for k, v in ccnt.items():
                cnt[k] = cnt.get(k, 0) + v * mult
        visiting.discard(name)
        memo[name] = (f, h, c, by_op, cnt)
        return memo[name]

    f, h, c, by_op, cnt = total(entry)
    return HloReport(f, h, c, by_op, cnt)
