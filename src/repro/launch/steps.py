"""Step-artifact builders shared by the dry-run, the drivers and benchmarks.

For every (architecture x assigned shape) cell this module produces the
jit-able step function plus abstract inputs (ShapeDtypeStructs — never
allocated) and explicit in/out shardings for the production mesh:

  train_4k     -> train_step(params, opt_state, batch)
  prefill_32k  -> prefill_step(params, tokens[, frames], caches)
  decode_32k   -> serve_step(params, last_tokens, caches)   (one new token)
  long_500k    -> serve_step with a 524288-token state (SSM/hybrid only)

`input_specs(arch, shape)` is the deliverable-(e) entry point.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.distributed import sharding as sh
from repro.models import transformer as TF
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_abstract
from repro.training.train_step import batch_axes, build_train_step, make_batch_abstract

BIG_PARAMS = 100e9  # >=100B: bf16 Adam moments (memory budget, DESIGN §5)


def make_rules(cfg: ModelConfig, mesh) -> sh.ShardingRules:
    return sh.ShardingRules(mesh).with_overrides(cfg.sharding_overrides)


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    big = cfg.approx_params() >= BIG_PARAMS
    return AdamWConfig(moment_dtype=jnp.bfloat16 if big else jnp.float32)


@dataclasses.dataclass
class StepArtifacts:
    """Everything needed to lower one cell."""

    fn: Callable
    args: tuple  # abstract ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...] = ()


def _named(rules: sh.ShardingRules, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def params_abstract(cfg: ModelConfig):
    return sh.abstract_from_template(TF.param_template(cfg))


def input_specs(arch: str, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell
    (deliverable e.2) — weak-type-correct, shardable, no device allocation."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    if sp.kind == "train":
        return make_batch_abstract(cfg, sp.global_batch, sp.seq_len)
    if sp.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((sp.global_batch, sp.seq_len), jnp.int32)}
        if cfg.family in ("vlm", "encdec"):
            nf = cfg.n_frontend_tokens or 64
            out["frames"] = jax.ShapeDtypeStruct((sp.global_batch, nf, cfg.d_model), cfg.dtype)
        return out
    # decode kinds: one new token against a seq_len cache
    return {
        "last_tokens": jax.ShapeDtypeStruct((sp.global_batch,), jnp.int32),
        "caches": TF.init_caches(cfg, sp.global_batch, sp.seq_len, abstract=True),
    }


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


def build_train_artifacts(cfg: ModelConfig, sp: ShapeSpec, rules: sh.ShardingRules) -> StepArtifacts:
    opt_cfg = opt_config_for(cfg)
    tmpl = TF.param_template(cfg)
    p_abs = sh.abstract_from_template(tmpl)
    p_spec = sh.specs_from_template(tmpl, rules)
    o_abs = adamw_abstract(p_abs, opt_cfg)
    o_spec = {"m": p_spec, "v": p_spec, "step": P()}
    b_abs = make_batch_abstract(cfg, sp.global_batch, sp.seq_len)
    b_spec = sh.specs_for_axes(b_abs, batch_axes(cfg), rules)

    raw_step = build_train_step(cfg, opt_cfg)

    def train_step(params, opt_state, batch):
        with sh.use_sharding_rules(rules):
            return raw_step(params, opt_state, batch)

    metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return StepArtifacts(
        fn=train_step,
        args=(p_abs, o_abs, b_abs),
        in_shardings=(_named(rules, p_spec), _named(rules, o_spec), _named(rules, b_spec)),
        out_shardings=(
            _named(rules, p_spec),
            _named(rules, o_spec),
            _named(rules, metrics_spec),
        ),
        donate=(0, 1),
    )


def build_prefill_artifacts(cfg: ModelConfig, sp: ShapeSpec, rules: sh.ShardingRules) -> StepArtifacts:
    tmpl = TF.param_template(cfg)
    p_abs = sh.abstract_from_template(tmpl)
    p_spec = sh.specs_from_template(tmpl, rules)
    c_abs = TF.init_caches(cfg, sp.global_batch, sp.seq_len, abstract=True)
    c_spec = sh.specs_for_axes(c_abs, TF.cache_axes(cfg), rules)
    ins = input_specs(cfg.name, sp.name)
    tok_spec = rules.spec_for_shape(ins["tokens"].shape, ("batch", "seq"))
    frames = ins.get("frames")

    if frames is not None:
        f_spec = rules.spec_for_shape(frames.shape, ("batch", "seq", "act_d_model"))

        def prefill_step(params, tokens, frames, caches):
            with sh.use_sharding_rules(rules):
                return TF.prefill(cfg, params, tokens, caches, frames)

        args = (p_abs, ins["tokens"], frames, c_abs)
        in_sh = (
            _named(rules, p_spec),
            NamedSharding(rules.mesh, tok_spec),
            NamedSharding(rules.mesh, f_spec),
            _named(rules, c_spec),
        )
    else:

        def prefill_step(params, tokens, caches):
            with sh.use_sharding_rules(rules):
                return TF.prefill(cfg, params, tokens, caches)

        args = (p_abs, ins["tokens"], c_abs)
        in_sh = (
            _named(rules, p_spec),
            NamedSharding(rules.mesh, tok_spec),
            _named(rules, c_spec),
        )

    next_spec = rules.spec_for_shape((sp.global_batch,), ("batch",))
    return StepArtifacts(
        fn=prefill_step,
        args=args,
        in_shardings=in_sh,
        out_shardings=(NamedSharding(rules.mesh, next_spec), _named(rules, c_spec)),
        donate=(len(args) - 1,),
    )


def build_decode_artifacts(cfg: ModelConfig, sp: ShapeSpec, rules: sh.ShardingRules) -> StepArtifacts:
    tmpl = TF.param_template(cfg)
    p_abs = sh.abstract_from_template(tmpl)
    p_spec = sh.specs_from_template(tmpl, rules)
    c_abs = TF.init_caches(cfg, sp.global_batch, sp.seq_len, abstract=True)
    c_spec = sh.specs_for_axes(c_abs, TF.cache_axes(cfg), rules)
    last_abs = jax.ShapeDtypeStruct((sp.global_batch,), jnp.int32)
    last_spec = rules.spec_for_shape((sp.global_batch,), ("batch",))

    def serve_step(params, last_tokens, caches):
        with sh.use_sharding_rules(rules):
            return TF.decode_step(cfg, params, last_tokens, caches)

    return StepArtifacts(
        fn=serve_step,
        args=(p_abs, last_abs, c_abs),
        in_shardings=(
            _named(rules, p_spec),
            NamedSharding(rules.mesh, last_spec),
            _named(rules, c_spec),
        ),
        out_shardings=(NamedSharding(rules.mesh, last_spec), _named(rules, c_spec)),
        donate=(2,),
    )


def build_cell(arch: str, shape: str, mesh, *, cfg_overrides: dict | None = None) -> StepArtifacts:
    """cfg_overrides: §Perf variant knobs (e.g. {"kv_quant": True}) applied
    on top of the registered config — baselines never set this."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    sp = SHAPES[shape]
    rules = make_rules(cfg, mesh)
    if sp.kind == "train":
        return build_train_artifacts(cfg, sp, rules)
    if sp.kind == "prefill":
        return build_prefill_artifacts(cfg, sp, rules)
    if sp.kind in ("decode", "long_decode"):
        return build_decode_artifacts(cfg, sp, rules)
    raise ValueError(sp.kind)
