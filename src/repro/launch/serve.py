"""Serving driver: BlitzScale autoscaling end-to-end on real JAX engines.

Demonstrates the paper's full loop at laptop scale: a trace of requests hits
one engine; the load monitor detects the burst; the scale planner builds a
multicast chain plan; a second engine "loads" parameter blocks layer-by-layer
at the plan's modelled bandwidth; live cooperative execution (ZigZag order)
serves requests across the pair while loading; the pair rebalances once
loading completes.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 24

With ``--disagg`` the same burst runs on the PD-disaggregated runtime
(repro.serving.disagg): prefill and decode engine pools, per-request
KVCache migration between them, decode pre-scaling and prefill→decode
instance mutation per the paper's §5.4 policy:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --disagg --requests 24

With ``--maas`` the fleet serves SEVERAL models on one shared topology: the
MaaS control plane (repro.serving.maas) arbitrates free devices between
per-model runtimes by SLO pressure x queue depth, parks idle models at zero
accelerators (only the O(1) host copy survives) and cold-starts them back
via multicast when requests arrive:

  PYTHONPATH=src python -m repro.launch.serve --maas \
      --models granite-8b,qwen1.5-4b,minicpm3-4b --requests 24

This is the runnable counterpart of the cluster-scale *simulator*
(repro.core.simulator), which reproduces the paper's figures; here every
forward pass is a real jitted model execution.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import multicast as mc
from repro.core import topology as topo_mod
from repro.core.live_scaling import LiveSession
from repro.core.parameter_pool import ParameterPool
from repro.models import transformer as TF
from repro.serving.engine import InstanceEngine, ServeRequest
from repro.serving.router import Router


def run_disagg(args) -> None:
    """PD-disaggregated serving: prefill pool → KV migration → decode pool,
    autoscaled with decode pre-scaling + prefill→decode mutation (§5.4)."""
    from repro.core.autoscaler import PolicyConfig
    from repro.serving.disagg import ClusterRuntime

    cfg = get_config(args.arch, reduced=True)
    # network model (live-scale + KV-migration volumes) uses the FULL
    # architecture footprint; compute runs the reduced config
    model_bytes = get_config(args.arch).approx_params() * 2
    key = jax.random.PRNGKey(args.seed)
    params = TF.init_params(key, cfg)
    rng = np.random.default_rng(args.seed)
    max_seq = args.prompt_len + args.gen_len + 8

    topo = topo_mod.add_host_sources(topo_mod.make_cluster(2, 4, bw_gbps=100.0))
    policy = PolicyConfig(max_instances=4, kv_upper=0.5, scale_down_timeout_s=0.5)
    rt = ClusterRuntime(
        cfg,
        params,
        topo=topo,
        policy=policy,
        n_prefill=args.n_prefill,
        n_decode=args.n_decode,
        n_slots=args.n_slots,
        max_seq=max_seq,
        model_bytes=model_bytes,
        prefill_capacity_tps=2000.0,
        decode_capacity_tps=200.0,
        verbose=True,
    )

    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        rt.submit(prompt, args.gen_len, clock())
    print(f"[monitor] burst of {args.requests} requests hit the prefill pool")
    completed_all = rt.run_until_done(clock)

    rep = rt.router.slo_report()
    handoffs, gapped = rt.router.handoff_report()
    s = rt.stats
    print(
        f"[disagg] served {rep.n} requests in {clock():.2f}s  "
        f"mean_ttft {rep.mean_ttft*1e3:.0f}ms p99_ttft {rep.p99_ttft*1e3:.0f}ms "
        f"mean_tbt {rep.mean_tbt*1e3:.1f}ms attainment {rep.attainment:.0%}"
    )
    print(
        f"[disagg] {s.migrations} KV migrations ({s.migrated_bytes/1e6:.1f} MB modelled), "
        f"{s.mutations} prefill->decode mutation(s) ({s.mutation_param_bytes} param bytes), "
        f"{s.live_scaled_prefill} replacement prefill + {s.direct_decode_scales} "
        f"direct decode live-scale(s) ({s.live_scale_param_bytes/1e9:.1f} GB "
        f"modelled param traffic), {s.prescaled_decodes} decode instance(s) pre-scaled"
    )
    # outstanding counts requests lost anywhere post-submit (including ones
    # that prefilled but never finished decode — invisible to rep.n)
    dropped = rt.n_outstanding + gapped
    print(
        f"[disagg] handoffs completed {handoffs}/{s.migrations}, "
        f"dropped or token-gapped requests: {dropped}"
    )
    if not completed_all or dropped != 0:
        raise SystemExit(f"FAIL: {dropped} request(s) dropped or token-gapped")


def run_maas(args) -> None:
    """Serverless multi-model MaaS: N models on one shared topology, devices
    arbitrated by the fleet scheduler, idle models scaled to zero and
    cold-started back via multicast from the O(1) host copy."""
    from repro.core.autoscaler import PolicyConfig
    from repro.serving import traces
    from repro.serving.maas import FleetPolicy, FleetScheduler, ZERO

    archs = [m.strip() for m in args.models.split(",") if m.strip()]
    if len(archs) < 2:
        raise SystemExit("--maas needs at least two models (--models a,b,...)")
    max_seq = args.prompt_len + args.gen_len + 8

    topo = topo_mod.add_host_sources(topo_mod.make_cluster(2, 4, bw_gbps=100.0))
    fleet = FleetScheduler(
        topo, policy=FleetPolicy(idle_to_zero_s=1.5), verbose=True
    )
    cfgs = {}
    for i, arch in enumerate(archs):
        cfg = get_config(arch, reduced=True)
        params = TF.init_params(jax.random.PRNGKey(args.seed + i), cfg)
        cfgs[cfg.name] = cfg
        fleet.add_model(
            cfg,
            params,
            n_prefill=1,
            n_decode=1,
            n_slots=args.n_slots,
            max_seq=max_seq,
            model_bytes=get_config(arch).approx_params() * 2,
            prefill_capacity_tps=2000.0,
            decode_capacity_tps=200.0,
            policy=PolicyConfig(max_instances=3, kv_upper=0.5, scale_down_timeout_s=0.5),
        )

    # Zipf-skewed, burst-staggered arrivals compressed to a few wall seconds;
    # the cold tail should spend part of the run parked at zero devices
    mix = traces.multi_model_mix(
        list(cfgs), duration=60.0, total_rate=1.0, seed=args.seed
    )
    # subsample evenly across the horizon (keeping late arrivals preserves
    # the scale-to-zero -> cold-start cycle) and compress to ~10 wall seconds
    step = max(1, len(mix) // args.requests)
    scale = 10.0 / 60.0
    arrivals = [(t * scale, m) for t, m, _, _ in mix[::step][: args.requests]]

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    pending = sorted(arrivals)
    for _ in range(200_000):
        if not pending and fleet.n_outstanding == 0:
            break
        now = clock()
        while pending and pending[0][0] <= now:
            _, model = pending.pop(0)
            prompt = rng.integers(0, cfgs[model].vocab_size, size=args.prompt_len)
            fleet.submit(model, prompt.astype(np.int32), args.gen_len, now)
        fleet.tick(now)
        assert fleet.param_pool.invariant_ok()
    else:
        raise SystemExit(f"FAIL: tick budget exhausted, {fleet.n_outstanding} outstanding")

    dropped = 0
    print()
    for name, t in fleet.tenants.items():
        rep = t.runtime.router.slo_report()
        _, gapped = t.runtime.router.handoff_report()
        dropped += t.runtime.n_outstanding + gapped
        print(
            f"[maas] {name}: {rep.n} served  mean_ttft {rep.mean_ttft*1e3:.0f}ms "
            f"attainment {rep.attainment:.0%}  cold_starts {t.runtime.stats.cold_starts} "
            f"scaled_to_zero {t.stats.scaled_to_zero} "
            f"gpu_seconds {t.stats.gpu_seconds:.2f} "
            f"{'(at zero now)' if t.state == ZERO else ''}"
        )
    s = fleet.stats
    print(
        f"[maas] fleet: {s.grants} grants, {s.cold_starts} cold starts, "
        f"{s.scale_to_zero_events} scale-to-zero, {s.preemptions} preemptions, "
        f"{s.gpu_seconds:.2f} GPU-seconds occupied"
    )
    if dropped:
        raise SystemExit(f"FAIL: {dropped} request(s) dropped or token-gapped")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--disagg", action="store_true",
                    help="run the PD-disaggregated runtime (prefill/decode pools)")
    ap.add_argument("--n-prefill", type=int, default=2)
    ap.add_argument("--n-decode", type=int, default=1)
    ap.add_argument("--maas", action="store_true",
                    help="serve several models on one fleet (MaaS control plane)")
    ap.add_argument("--models", default="granite-8b,qwen1.5-4b,minicpm3-4b",
                    help="comma-separated arch ids for --maas")
    args = ap.parse_args()

    if args.maas:
        run_maas(args)
        return
    if args.disagg:
        run_disagg(args)
        return

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(args.seed)
    params = TF.init_params(key, cfg)
    rng = np.random.default_rng(args.seed)

    # --- cluster state: topology + O(1) parameter pool --------------------
    topo = topo_mod.make_cluster(2, 4, bw_gbps=100.0)
    topo = topo_mod.add_host_sources(topo)
    pool = ParameterPool(topo)
    model_bytes = cfg.approx_params() * 2
    pool.register(cfg.name, model_bytes)
    pool.deploy(cfg.name, [0])
    topo.device(0).role = topo_mod.Role.COLOCATED

    # --- engine 0 serves; burst arrives ------------------------------------
    eng0 = InstanceEngine(cfg, params, n_slots=args.n_slots, max_seq=args.prompt_len + args.gen_len + 8)
    router = Router()
    t0 = time.perf_counter()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        rid = router.submit(args.prompt_len, args.gen_len, time.perf_counter() - t0)
        r = ServeRequest(rid, prompt, args.gen_len)
        reqs.append(r)
        eng0.submit(r)

    # --- load monitor trips -> plan a scale-out ----------------------------
    queue_depth = len(eng0.queue)
    print(f"[monitor] queue depth {queue_depth} > slots {args.n_slots} -> scale")
    gpu_srcs, host = pool.sources(cfg.name)
    spare = [d.id for d in topo.spares()][:1]
    plan = mc.plan_multicast(topo, gpu_srcs or [topo.devices[-1].id], spare, 1)
    errs = mc.validate_plan(topo, plan)
    assert not errs, errs
    t_load = plan.transfer_seconds(model_bytes)
    print(
        f"[planner] {len(plan.chains)} chain(s), modelled transfer "
        f"{t_load*1e3:.0f} ms for {model_bytes/1e6:.0f} MB "
        f"(gen {plan.gen_seconds*1e3:.2f} ms)"
    )

    # --- live scaling: engine 1 starts with 0 layers, gains them over time -
    eng1 = InstanceEngine(cfg, params, n_slots=args.n_slots, max_seq=args.prompt_len + args.gen_len + 8)
    eng1.set_loaded_layers(0)
    session = LiveSession(
        n_layers=cfg.n_layers,
        layer_bytes=model_bytes // max(cfg.n_layers, 1),
        link_bytes_per_s=model_bytes / max(t_load, 1e-6),
        started_at=time.perf_counter(),
    )

    done = 0
    steps = 0
    while done < args.requests and steps < 10_000:
        steps += 1
        now = time.perf_counter()
        k = session.layers_loaded(now)
        eng1.set_loaded_layers(k)
        mult = session.throughput_multiplier(now)
        # cooperative phase: redirect half the queue once eng1 can serve alone
        if eng1.can_serve_alone() and eng0.queue:
            while len(eng0.queue) > len(eng1.queue):
                eng1.submit(eng0.queue.pop())
        for eng in (eng0, eng1) if eng1.can_serve_alone() else (eng0,):
            for r in eng.step():
                done += 1
                router.note_first_token(r.rid, now - t0)
                router.note_done(r.rid)
        if steps % 20 == 0:
            print(
                f"[live] step {steps} loaded {k}/{cfg.n_layers} layers "
                f"boost x{mult:.2f} done {done}/{args.requests} phase={session.phase.value}"
            )

    rep = router.slo_report()
    print(
        f"served {rep.n} requests in {time.perf_counter()-t0:.2f}s  "
        f"mean_ttft {rep.mean_ttft*1e3:.0f}ms attainment {rep.attainment:.0%}"
    )


if __name__ == "__main__":
    main()
