"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization and only then builds the mesh.

Mesh geometry (TPU v5e targets):
  single-pod : (16, 16)    axes ("data", "model")      = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"model" is the tensor-parallel axis = the paper's *instance* granularity
(one TP group of chips serves one model replica); "data"/"pod" enumerate
instances and batch shards.  The autoscaling data plane multicasts parameter
blocks along the data axis (chains of collective_permutes) and Fig.14
sharded transfers use the model axis as the scale-up domain.
"""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where the installed
    jax supports them (``AxisType`` landed after 0.4.x; older versions only
    have auto axes, so omitting the kwarg is equivalent)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("pod", "data", "model")[1:]
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int | None = None) -> jax.sharding.Mesh:
    """A small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return make_mesh_compat((n // model, model), ("data", "model"))
