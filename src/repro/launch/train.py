"""Training driver: end-to-end distributed training with fault tolerance.

Runs any registered architecture at any scale:

  # CPU smoke (reduced config, 1 device)
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \\
      --steps 50 --batch 8 --seq 128

  # production mesh (on a real pod; here only --dry-run lowering works)
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b \\
      --batch 256 --seq 4096 --mesh production

Fault tolerance: checkpoints every --ckpt-every steps (atomic, pruned);
on start the driver resumes from the newest complete checkpoint, and the
data pipeline (deterministic in step) replays from exactly that step — a
killed-and-restarted run produces the same loss trajectory as an unkilled
one (tested in tests/test_train.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokenPipeline, make_batch
from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as TF
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "host", "production"], default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1), total_steps=args.steps
    )

    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh == "production":
        mesh = make_production_mesh()
    rules = sh.ShardingRules(mesh).with_overrides(cfg.sharding_overrides)

    key = jax.random.PRNGKey(args.seed)
    with sh.use_sharding_rules(rules if mesh else None):
        params = TF.init_params(key, cfg)
    opt_state = adamw_init(params, opt_cfg)

    start_step = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        state, start_step = restore_checkpoint(args.ckpt_dir, state)
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start_step}")

    raw_step = build_train_step(cfg, opt_cfg, microbatches=args.microbatches)

    def step_fn(p, o, b):
        with sh.use_sharding_rules(rules if mesh else None):
            return raw_step(p, o, b)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M batch={args.batch} seq={args.seq}")

    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in make_batch(cfg, args.batch, args.seq, step=step, seed=args.seed).items()
        }
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.perf_counter() - t0
            tok_s = (step - start_step + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d} loss {loss:.4f} grad_norm {gn:.3f} tok/s {tok_s:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
