import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture x input-shape) cell on the production meshes and extract the
roofline terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the 512 placeholder host devices exist only for this
script — smoke tests and benchmarks see 1 device.

Per cell this script records (one JSON per cell under --out):
  * per-device parameter/cache/argument/temp bytes (memory_analysis → proves
    the program fits the 16 GB/chip v5e budget),
  * XLA cost_analysis flops/bytes (raw) and loop-corrected dot FLOPs, HBM
    bytes and collective bytes (repro.launch.hlo_analysis — XLA counts scan
    bodies once, so raw numbers undercount by ~n_layers),
  * the collective op/byte breakdown (drives §Perf),
  * the three §Roofline terms against TPU v5e constants.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch import steps as steps_mod
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, input_specs  # noqa: F401 (public API)

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _sharded_bytes(abstract_tree, sharding_tree) -> int:
    """Per-device bytes of an abstract pytree under the given shardings."""
    total = 0
    leaves = jax.tree.leaves(abstract_tree)
    shards = jax.tree.leaves(sharding_tree, is_leaf=lambda x: hasattr(x, "spec"))
    for sds, sh in zip(leaves, shards):
        shape = sh.shard_shape(sds.shape) if hasattr(sh, "shard_shape") else sds.shape
        total += int(np.prod(shape)) * sds.dtype.itemsize
    return total


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N_active*D for inference (global)."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    n_active = cfg.approx_active_params()
    if sp.kind == "train":
        return 6.0 * n_active * sp.seq_len * sp.global_batch
    if sp.kind == "prefill":
        return 2.0 * n_active * sp.seq_len * sp.global_batch
    # decode: one token per sequence
    return 2.0 * n_active * sp.global_batch


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: str, *, force: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    path = os.path.join(outdir, f"{arch}__{shape}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips}
    t0 = time.perf_counter()
    try:
        art = build_cell(arch, shape, mesh)
        with mesh:
            jitted = jax.jit(
                art.fn,
                in_shardings=art.in_shardings,
                out_shardings=art.out_shardings,
                donate_argnums=art.donate,
            )
            lowered = jitted.lower(*art.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            rec[attr] = int(getattr(mem, attr, 0) or 0)
        cost = compiled.cost_analysis() or {}
        rec["raw_flops"] = float(cost.get("flops", 0.0))
        rec["raw_bytes"] = float(cost.get("bytes accessed", 0.0))

        rep = analyze(compiled.as_text())
        rec["dot_flops_per_dev"] = rep.dot_flops
        rec["hbm_bytes_per_dev"] = rep.hbm_bytes
        rec["collective_bytes_per_dev"] = rep.collective_bytes
        rec["coll_by_op"] = dict(rep.coll_by_op)
        rec["coll_count"] = {k: int(v) for k, v in rep.coll_count.items()}

        # per-device input footprints (weights / caches)
        rec["param_bytes_per_dev"] = _sharded_bytes(art.args[0], art.in_shardings[0])
        if SHAPES[shape].kind in ("decode", "long_decode"):
            rec["cache_bytes_per_dev"] = _sharded_bytes(art.args[2], art.in_shardings[2])
        elif SHAPES[shape].kind == "prefill":
            rec["cache_bytes_per_dev"] = _sharded_bytes(art.args[-1], art.in_shardings[-1])

        # roofline terms (seconds per step, per chip)
        rec["t_compute"] = rep.dot_flops / PEAK_FLOPS
        rec["t_memory"] = rep.hbm_bytes / HBM_BW
        rec["t_collective"] = rep.collective_bytes / ICI_BW
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        mf = model_flops(arch, shape)
        rec["model_flops_global"] = mf
        hlo_global = rep.dot_flops * n_chips
        rec["useful_flop_frac"] = mf / hlo_global if hlo_global else 0.0
        rec["lower_s"] = t_lower
        rec["compile_s"] = t_compile
        rec["ok"] = True
    except Exception as e:  # record the failure — dry-run failures are bugs
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _fmt(rec: dict) -> str:
    if not rec.get("ok"):
        return (f"FAIL {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:6s} "
                f"{rec.get('error', '?')[:90]}")
    return (
        f"ok   {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:6s} "
        f"args/dev={rec['argument_size_in_bytes']/2**30:6.2f}GiB "
        f"temp/dev={rec['temp_size_in_bytes']/2**30:6.2f}GiB "
        f"t_comp={rec['t_compute']*1e3:8.2f}ms t_mem={rec['t_memory']*1e3:8.2f}ms "
        f"t_coll={rec['t_collective']*1e3:8.2f}ms [{rec['bottleneck']}] "
        f"compile={rec['compile_s']:.0f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + ["all"],
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true", help="list cells and exit")
    args = ap.parse_args()

    grid = [
        (a, s)
        for a, s, ok in cells()
        if (args.arch in (None, "all", a)) and (args.shape in (None, "all", s))
    ]
    if args.list:
        for a, s in grid:
            print(a, s)
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for a, s in grid:
        for mp in meshes:
            rec = run_cell(a, s, mp, args.out, force=args.force)
            print(_fmt(rec), flush=True)
            n_fail += 0 if rec.get("ok") else 1
    print(f"\n{len(grid) * len(meshes) - n_fail}/{len(grid) * len(meshes)} cells passed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
