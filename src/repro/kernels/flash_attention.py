"""Blocked causal flash attention (GQA) as a Pallas TPU kernel.

TPU adaptation notes (vs. the CUDA flash-attention the serving papers use):

  * The grid is ``(batch, q_heads, q_blocks, kv_blocks)``; TPU executes the
    grid *sequentially* with the last dimension fastest, so the kv axis is an
    accumulation axis: running (max, denom, acc) live in VMEM scratch and the
    output block is emitted on the final kv step.  This replaces the CUDA
    pattern of a thread-block-local loop with warp shuffles.
  * Block shapes are MXU-aligned: ``block_q x head_dim`` and
    ``block_k x head_dim`` tiles with 128-multiples on the matmul dims, so
    the two einsums per step map onto 128x128 systolic passes.
  * GQA is folded into the BlockSpec index maps: query head ``h`` reads KV
    head ``h // (H/KV)`` — no materialized repeat_kv, no extra HBM traffic
    (the CUDA kernels do the same via pointer arithmetic).
  * VMEM working set per grid step:
    ``(block_q + 2*block_k) * head_dim * 2B + block_q*block_k*4B`` ---
    128/512 blocks with D=128 use ~0.6 MB, well under the ~16 MB/core VMEM
    budget, leaving room for XLA to double-buffer the HBM->VMEM streams.

Causality is enforced with an in-kernel mask on global positions; fully
masked kv blocks short-circuit via ``pl.when`` (no MXU work), which for long
sequences halves the executed steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, block_q, 1, D)
    k_ref,  # (1, block_k, 1, D)
    v_ref,  # (1, block_k, 1, D)
    o_ref,  # (1, block_q, 1, D)
    m_ref,  # scratch (block_q,)   f32
    l_ref,  # scratch (block_q,)   f32
    acc_ref,  # scratch (block_q, D) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the causal diagonal (no MXU work)
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    softmax_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    n_rep = h // kv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))

    grid = (b, h, sq_pad // block_q, sk_pad // block_k)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            block_q=block_q,
            block_k=block_k,
            seq_q=sq,
            seq_k=sk,
            causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // n_rep, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // n_rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_pad, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),  # running max
            pltpu.VMEM((block_q,), jnp.float32),  # running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
