"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose, running
the kernels in ``interpret=True`` mode on CPU).  The attention oracles are
shared with the model code (``repro.models.layers``) so the model's compute
path and the kernel contract are definitionally identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (  # noqa: F401  (re-exported oracles)
    attention_reference,
    chunked_attention,
    decode_attention_reference,
)


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    softmax_scale: float | None = None,
) -> jax.Array:
    return attention_reference(q, k, v, causal=causal, softmax_scale=softmax_scale)


def decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    k_cache: jax.Array,  # (B, KV, S, D)
    v_cache: jax.Array,  # (B, KV, S, D)
    lengths: jax.Array,  # (B,)
    *,
    softmax_scale: float | None = None,
) -> jax.Array:
    return decode_attention_reference(q, k_cache, v_cache, lengths, softmax_scale=softmax_scale)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
