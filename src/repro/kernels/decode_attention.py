"""Single-token GQA decode attention over a padded KV cache (flash-decoding
style) as a Pallas TPU kernel.

This is the serving hot loop: one query token per sequence against a long
cache.  It is *memory-bound* (every cache byte is read once per step), so the
kernel's job is to stream K/V HBM->VMEM at full bandwidth while the online
softmax rides along:

  * grid ``(batch, kv_heads, seq_blocks)`` — the seq axis is the
    accumulation axis (TPU sequential grid), carrying running
    (max, denom, acc) per *query-head group* in VMEM scratch;
  * GQA handled by blocking queries per KV head: the ``n_rep`` query heads
    that share one KV head are processed together as a (n_rep, D) tile, so
    each cache block is read once for all of them — the exact arithmetic-
    intensity trick GPU flash-decoding uses, expressed as a tile shape;
  * per-sequence valid ``lengths`` mask instead of padding-aware gather —
    the tail block is masked, not branched.

VMEM per step: ``2 * block_s * D * 2B`` cache tile + ``n_rep x block_s``
f32 scores — ~0.3 MB at block_s=512, D=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # (1, 1) int32 (SMEM-style small block)
    q_ref,  # (1, n_rep, 1, D)
    k_ref,  # (1, 1, block_s, D)  — seq-major (B, KV, S, D) cache layout
    v_ref,  # (1, 1, block_s, D)
    o_ref,  # (1, n_rep, 1, D)
    m_ref,  # scratch (n_rep,) f32
    l_ref,  # scratch (n_rep,) f32
    acc_ref,  # scratch (n_rep, D) f32
    *,
    scale: float,
    block_s: int,
):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]

    @pl.when(si * block_s < length)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (n_rep, D)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bs, D)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (n_rep, bs)
        pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, D) — one new token per sequence
    k_cache: jax.Array,  # (B, KV, S, D)  — seq-major cache layout (§Perf C1)
    v_cache: jax.Array,  # (B, KV, S, D)
    lengths: jax.Array,  # (B,) valid cache entries
    *,
    softmax_scale: float | None = None,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, d = q.shape
    _, kv, s, _ = k_cache.shape
    n_rep = h // kv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)

    block_s = min(block_s, max(s, 8))
    s_pad = -(-s // block_s) * block_s
    if s_pad != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))

    # (B, H, D) -> (B, KV, n_rep, D): group the q heads sharing one KV head
    qg = q.reshape(b, kv, n_rep, d).transpose(0, 2, 1, 3)  # (B, n_rep, KV, D)
    len2d = lengths.astype(jnp.int32).reshape(b, 1)

    grid = (b, kv, s_pad // block_s)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),
            pl.BlockSpec((1, n_rep, 1, d), lambda bi, hi, si: (bi, 0, hi, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_rep, 1, d), lambda bi, hi, si: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_rep, kv, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(len2d, qg, k_cache, v_cache)
    return out.transpose(0, 2, 1, 3).reshape(b, h, d)
