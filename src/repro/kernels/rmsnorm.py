"""Fused RMSNorm as a Pallas TPU kernel.

RMSNorm is invoked 2-4x per layer; unfused it costs three HBM round-trips
(read x for the square-mean, read x again for the scale, write out).  The
fused kernel streams each row tile HBM->VMEM once: square-reduce, rsqrt,
scale by the (VMEM-resident) weight vector, write — a pure bandwidth play,
~3x traffic reduction on the norm path.

Grid: ``(n_row_blocks,)`` over the flattened (tokens, d_model) view; the
weight vector rides in a ``(1, d)`` block pinned to block 0 of every step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_rmsnorm(
    x: jax.Array,  # (..., d)
    w: jax.Array,  # (d,)
    eps: float = 1e-5,
    *,
    block_n: int = 256,
    interpret: bool = True,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    n = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    x2 = x.reshape(n, d)

    block_n = min(block_n, max(n, 1))
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        x2 = jnp.pad(x2, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=interpret,
    )(x2, w.reshape(1, d))
    return out[:n].reshape(orig_shape)
