"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

``use_pallas()`` decides per-call: on TPU the Pallas kernels run compiled;
on CPU (this container) they run in ``interpret=True`` mode when explicitly
requested (tests/benchmarks) and otherwise fall back to the pure-jnp oracle,
which XLA fuses well on CPU and which lowers cleanly in the 512-device
dry-run.  The contract: every entry point is numerically interchangeable
with its ``ref.py`` oracle (validated in tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rmsnorm import fused_rmsnorm as _rmsnorm_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "impl", "block_q", "block_k"))
def flash_attention(
    q, k, v, *, causal: bool = True, impl: str = "auto", block_q: int = 128, block_k: int = 512
):
    """impl: 'auto' (pallas on TPU, oracle elsewhere) | 'pallas' | 'ref'."""
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=not on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("impl", "block_s"))
def decode_attention(q, k_cache, v_cache, lengths, *, impl: str = "auto", block_s: int = 512):
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths)
    return _decode_pallas(
        q, k_cache, v_cache, lengths, block_s=block_s, interpret=not on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("eps", "impl", "block_n"))
def rmsnorm(x, w, *, eps: float = 1e-5, impl: str = "auto", block_n: int = 256):
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return ref.rmsnorm_ref(x, w, eps)
    return _rmsnorm_pallas(x, w, eps, block_n=block_n, interpret=not on_tpu())
