"""Pallas TPU kernels for the serving hot spots (+ ops wrappers + oracles).

The paper's serving stack leans on FlashInfer GPU kernels (§6 "all our GPU
kernels for LLM come from FlashInfer"); the TPU-native equivalents live here:

  flash_attention.py  — blocked causal GQA prefill attention
  decode_attention.py — flash-decoding single-token GQA over the KV cache
  rmsnorm.py          — fused RMSNorm
  ops.py              — jit'd dispatch (pallas on TPU / oracle on CPU)
  ref.py              — pure-jnp oracles (shared with the model code)
"""

from repro.kernels import ops, ref
