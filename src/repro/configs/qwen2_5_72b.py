"""qwen2.5-72b — the paper's large evaluation model (TP=4 per instance)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp="swiglu",
    attn="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="qwen2.5-72b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    max_seq=256,
)
