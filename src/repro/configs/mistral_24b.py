"""mistral-24b — the paper's medium evaluation model (Mistral-Small-24B)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-24b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    mlp="swiglu",
    attn="gqa",
    rope_theta=100_000_000.0,
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="mistral-24b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    max_seq=256,
)
