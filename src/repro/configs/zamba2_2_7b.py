"""zamba2-2.7b — hybrid: Mamba2 backbone + one shared attention block.
[arXiv:2411.15242; hf]

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.

The shared attention+MLP block is a single parameter set invoked after every
``attn_every``-th Mamba2 layer (9 sites).  For BlitzScale this is the most
live-scaling-friendly arch: multicasting that one block unlocks 9 execution
sites at once (DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    mlp="swiglu",
    attn="gqa",
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    ssm_chunk=128,
    attn_every=6,
    # kv=32 divides the 16-way model axis -> head-sharded shared-attn cache
    sharding_overrides={"cache_kv_heads": "model", "cache_seq": None},
    uniform_decode=True,  # cache seq unsharded -> scalar-DUS append is in-place (C2)
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="zamba2-2.7b-reduced",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    attn_every=2,
    max_seq=256,
)
