"""llama3-8b — the paper's small evaluation model (Table 'models')."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    mlp="swiglu",
    attn="gqa",
    rope_theta=500_000.0,
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="llama3-8b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    max_seq=256,
)
