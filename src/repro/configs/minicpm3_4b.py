"""minicpm3-4b — dense with MLA (multi-head latent attention).
[hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA ranks per the HF config: q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32,
v_head 64.  The decode path uses the absorbed form so the per-token cache is
(kv_lora_rank + rope_dim) = 288 values — ~18x smaller than GQA at the same
width, which is why decode scaling pressure is low for this arch (§6.1 of the
paper applies more strongly).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    mlp="swiglu",
    attn="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    microbatches=16,
    # §Perf A1: 40 heads don't divide the 16-way model axis -> attention
    # would replicate 16x; shard the sequence dim instead (sequence
    # parallelism for the uneven-head archs — see EXPERIMENTS.md §Perf)
    sharding_overrides={"seq": "model"},
)

REDUCED = CONFIG.replace(
    sharding_overrides=None,
    microbatches=1,
    name="minicpm3-4b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    max_seq=256,
)
