"""nemotron-4-340b — dense GQA with squared-ReLU MLP. [arXiv:2402.16819]

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

At 340B the parameters alone are ~680 GB bf16 — far beyond 256 chips x 16 GB
without FSDP, so this arch carries the ZeRO-3 ``d_model -> data`` sharding
override (weights sharded over *both* mesh axes; XLA all-gathers per layer
inside the scan).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp="relu2",  # squared-ReLU, non-gated
    attn="gqa",
    sharding_overrides={"d_model": ("data",)},  # FSDP / ZeRO-3
    microbatches=32,
)

REDUCED = CONFIG.replace(
    name="nemotron-4-340b-reduced",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    max_seq=256,
    sharding_overrides=None,
    microbatches=1,
)
