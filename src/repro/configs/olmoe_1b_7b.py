"""olmoe-1b-7b — MoE, 64 experts top-8. [arXiv:2409.02060; hf]

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304.

64 experts shard 4-per-chip over the 16-way model axis (expert parallelism);
each expert's tiny d_ff=1024 stays unsharded.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    mlp="swiglu",
    attn="gqa",
    n_experts=64,
    top_k=8,
    # kv=16 divides the 16-way model axis exactly -> head-sharded KV cache
    # beats the default seq-sharded cache (no softmax-stat combine needed)
    sharding_overrides={"cache_kv_heads": "model", "cache_seq": None},
    uniform_decode=True,  # cache seq unsharded -> scalar-DUS append is in-place (C2)
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="olmoe-1b-7b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    n_experts=8,
    top_k=2,
    max_seq=256,
)
