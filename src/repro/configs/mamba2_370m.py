"""mamba2-370m — attention-free SSM (SSD). [arXiv:2405.21060]

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128, headdim=64, expand=2
(d_inner=2048, 32 SSM heads).  O(1) decode state makes this arch (with
zamba2) the long_500k-eligible family.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    ssm_chunk=128,
    tie_embeddings=True,
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="mamba2-370m-reduced",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    max_seq=256,
)
