"""qwen1.5-4b — dense with QKV bias. [hf:Qwen/Qwen1.5-4B]

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.

20 heads is not divisible by the 16-way model axis; head/kv dims rely on
GSPMD uneven (padded) sharding — verified by the dry-run.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    mlp="swiglu",
    attn="gqa",
    qkv_bias=True,
    microbatches=16,
    # §Perf A2: 20 heads don't divide the 16-way model axis -> sequence
    # parallelism instead of replicated attention (see EXPERIMENTS.md §Perf)
    sharding_overrides={"seq": "model"},
)

REDUCED = CONFIG.replace(
    microbatches=1,
    sharding_overrides=None,
    name="qwen1.5-4b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    max_seq=256,
)
