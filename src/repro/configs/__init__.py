"""Architecture registry: one module per assigned arch (+ the paper's own
models) and the assigned input-shape sets.

``get_config(name, reduced=False)`` resolves an arch id (dash or underscore
form) to its :class:`ModelConfig`; ``SHAPES``/``cells()`` enumerate the
assigned (arch x shape) dry-run grid.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator

from repro.models.config import ModelConfig

ARCH_IDS = [
    "granite-8b",
    "nemotron-4-340b",
    "qwen1.5-4b",
    "minicpm3-4b",
    "mamba2-370m",
    "pixtral-12b",
    "grok-1-314b",
    "olmoe-1b-7b",
    "whisper-large-v3",
    "zamba2-2.7b",
]

# the paper's own evaluation models (Table "Evaluated traces and models")
PAPER_IDS = ["llama3-8b", "mistral-24b", "qwen2.5-72b"]


def _modname(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(name)}")
    return mod.REDUCED if reduced else mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode' | 'long_decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k requires sub-quadratic sequence mixing (SSM/hybrid); all
    assigned archs have decoders, so every other cell runs."""
    if shape.kind == "long_decode":
        return cfg.supports_long_context
    return True


def cells(include_skipped: bool = False) -> Iterator[tuple[str, str, bool]]:
    """Yield (arch, shape, applicable) for the 40-cell assignment grid."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sh in SHAPES.items():
            ok = shape_applicable(cfg, sh)
            if ok or include_skipped:
                yield arch, sname, ok
