"""grok-1-314b — MoE, 8 experts top-2. [hf:xai-org/grok-1]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

8 experts cannot split a 16-way model axis, so this arch overrides the MoE
sharding to replicate the expert axis and tensor-parallel each expert's d_ff
instead (32768/16 = 2048 per chip).  Being >300B it also carries the FSDP
``d_model -> data`` override for training.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    mlp="swiglu",
    attn="gqa",
    n_experts=8,
    top_k=2,
    sharding_overrides={"experts": None, "d_model": ("data",)},
    # 8 experts replicate on the 16-way axis, so the GShard dispatch einsum
    # cannot shard over E — keep groups small so E*C stays negligible (B4)
    moe_group_size=512,
    microbatches=32,
)

REDUCED = CONFIG.replace(
    name="grok-1-314b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    sharding_overrides=None,
    microbatches=1,
    max_seq=256,
)
