"""pixtral-12b — VLM: pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.

Per the assignment the ViT frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, n_frontend_tokens, d_model) that overwrite
the first token positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    mlp="swiglu",
    attn="gqa",
    rope_theta=1_000_000_000.0,
    frontend="patch_stub",
    n_frontend_tokens=1024,
    microbatches=16,
)

REDUCED = CONFIG.replace(
    microbatches=1,
    name="pixtral-12b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    n_frontend_tokens=8,
    max_seq=256,
)
