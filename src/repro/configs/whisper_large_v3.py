"""whisper-large-v3 — encoder-decoder audio backbone. [arXiv:2212.04356]

32L (decoder; +32 encoder) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.

Per the assignment the conv/audio frontend is a STUB: ``input_specs()``
provides 1500 precomputed frame embeddings (30 s at 50 fps), which the
encoder stack consumes; decoder layers cross-attend to the encoder output.
Shape cells use the assignment's seq_len for the *decoder* stream.
Deviation note: positions use RoPE rather than whisper's learned absolute
embeddings — backbone-equivalent for system purposes (recorded in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp="gelu",
    attn="gqa",
    frontend="audio_stub",
    n_frontend_tokens=1500,
    microbatches=16,
    # §Perf A2: 20 heads don't divide the 16-way model axis -> sequence
    # parallelism instead of replicated attention (see EXPERIMENTS.md §Perf)
    sharding_overrides={"seq": "model"},
)

REDUCED = CONFIG.replace(
    microbatches=1,
    sharding_overrides=None,
    name="whisper-large-v3-reduced",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    n_frontend_tokens=8,
    max_seq=256,
)
