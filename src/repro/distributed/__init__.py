from repro.distributed.sharding import (
    ShardingRules,
    TensorSpec,
    abstract_from_template,
    current_rules,
    init_from_template,
    resolve_spec,
    shard,
    specs_from_template,
    use_sharding_rules,
)

__all__ = [
    "ShardingRules",
    "TensorSpec",
    "abstract_from_template",
    "current_rules",
    "init_from_template",
    "resolve_spec",
    "shard",
    "specs_from_template",
    "use_sharding_rules",
]
