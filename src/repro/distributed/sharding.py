"""Logical-axis sharding substrate.

Model code never mentions mesh axes directly.  Every tensor dimension is
tagged with a *logical* axis name ('batch', 'heads', 'd_ff', ...), and a
:class:`ShardingRules` object maps logical names onto the mesh axes that are
actually present ('pod', 'data', 'model').  The same model definition then
runs unsharded on one CPU device (smoke tests), TP-sharded on a single pod
(16x16), or pod+data+model sharded on the multi-pod mesh (2x16x16) — only the
rules change.

Three consumers:
  * ``init_from_template``     — materialize real parameter arrays,
  * ``abstract_from_template`` — ShapeDtypeStructs for the dry-run,
  * ``specs_from_template``    — PartitionSpecs for pjit in/out shardings.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

# Default logical-axis -> mesh-axis mapping.  A rule value may be a tuple of
# mesh axes (the logical axis is sharded over their product), a single mesh
# axis name, or None (replicated).  Axes absent from the active mesh are
# dropped at resolution time, so the same rules serve 1-device, single-pod and
# multi-pod meshes.
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_d_model": None,
    "act_heads": "model",
    "act_d_ff": "model",
    "act_vocab": "model",
    "kv_seq": None,
    # parameters (tensor-parallel pattern)
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "vocab": "model",
    "lora": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    # KV cache.  Default: shard the *sequence* dim over the model axis
    # (flash-decoding style: every chip scans its cache slice, XLA combines
    # the softmax stats) because most assigned archs have kv_heads that the
    # 16-way model axis does not divide (8/20/40 kv heads).  Archs with
    # divisible kv_heads (olmoe=16, zamba2=32) override to head-sharding.
    "cache_batch": ("pod", "data"),
    "cache_kv_heads": None,
    "cache_seq": "model",
    # layer stacking axis (scan over layers) is never sharded
    "layers": None,
}

# FSDP overlay for >=100B models: weight d_model dims additionally sharded
# over the data axis so resident parameter bytes scale with the full chip
# count (ZeRO-3 style; XLA inserts the per-layer all-gathers).
FSDP_OVERRIDES: dict[str, Any] = {
    "d_model": ("data",),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """A mesh plus the logical->mesh axis mapping active for this program."""

    mesh: Mesh | None
    rules: Mapping[str, Any] = dataclasses.field(default_factory=lambda: DEFAULT_RULES)

    def with_overrides(self, overrides: Mapping[str, Any] | None) -> "ShardingRules":
        if not overrides:
            return self
        merged = dict(self.rules)
        merged.update(overrides)
        return ShardingRules(self.mesh, merged)

    # -- resolution ---------------------------------------------------------
    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        rule = self.rules.get(logical)
        if rule is None:
            return ()
        if isinstance(rule, str):
            rule = (rule,)
        if self.mesh is None:
            return ()
        present = set(self.mesh.axis_names)
        return tuple(a for a in rule if a in present)

    def spec_for(self, logical_axes: Iterable[str | None]) -> P:
        parts: list[Any] = []
        used: set[str] = set()
        for ax in logical_axes:
            mesh_axes = tuple(a for a in self.mesh_axes_for(ax) if a not in used)
            used.update(mesh_axes)
            if len(mesh_axes) == 0:
                parts.append(None)
            elif len(mesh_axes) == 1:
                parts.append(mesh_axes[0])
            else:
                parts.append(mesh_axes)
        # trim trailing Nones — cosmetic, matches PartitionSpec conventions
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def spec_for_shape(
        self, shape: tuple[int, ...], logical_axes: Iterable[str | None]
    ) -> P:
        """Shape-aware resolution: GSPMD/pjit requires every sharded dim to be
        *exactly divisible* by the product of its mesh axes, so per dim we
        keep the longest prefix of the rule's mesh axes that divides the dim
        (dropping from the end).  A dim the rule cannot divide falls back to
        replication — e.g. 20 attention heads on a 16-way 'model' axis, or
        global_batch=1 (long_500k) on the 16-way 'data' axis."""
        parts: list[Any] = []
        used: set[str] = set()
        mesh_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape)) if self.mesh else {}
        for dim, ax in zip(shape, logical_axes):
            cand = [a for a in self.mesh_axes_for(ax) if a not in used]
            while cand:
                prod = int(np.prod([mesh_sizes[a] for a in cand]))
                if dim % prod == 0:
                    break
                cand.pop()
            used.update(cand)
            if len(cand) == 0:
                parts.append(None)
            elif len(cand) == 1:
                parts.append(cand[0])
            else:
                parts.append(tuple(cand))
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


_CTX = threading.local()


def current_rules() -> ShardingRules | None:
    return getattr(_CTX, "rules", None)


@contextlib.contextmanager
def use_sharding_rules(rules: ShardingRules | None):
    """Context manager installing ambient sharding rules for model code."""
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def constrain_layer_params(lp: Any, template: Any) -> Any:
    """Inside a scan-over-layers body, pin the sliced layer parameters to
    their TP-only sharding (d_model replicated).

    §Perf D VERDICT: REFUTED on nemotron-340b train — the constraint forced
    re-gathers in forward, backward AND remat recompute (t_comp 163->292 s,
    temp 45->52.7 GiB) without freeing the hoisted buffer.  Kept as an
    unused utility + the recorded negative result; the 340B-train memory
    story remains multi-pod (batch sharded over pods) per EXPERIMENTS.md."""
    rules = current_rules()
    if rules is None or rules.mesh is None or not rules.mesh_axes_for("d_model"):
        return lp  # no FSDP overlay active
    tp_rules = rules.with_overrides({"d_model": None})

    def one(leaf, spec):
        sharding = NamedSharding(
            tp_rules.mesh, tp_rules.spec_for_shape(tuple(leaf.shape), spec.axes)
        )
        return jax.lax.with_sharding_constraint(leaf, sharding)

    return jax.tree.map(one, lp, template, is_leaf=lambda x: isinstance(x, TensorSpec))


def seq_sharded() -> bool:
    """True when the ambient rules shard the activation 'seq' axis — the
    sequence-parallel mode used by archs whose head counts don't divide the
    model axis (qwen1.5/minicpm3/whisper).  Attention call sites switch to
    an unchunked-q layout so the q shards stay local (see §Perf A2)."""
    rules = current_rules()
    return bool(rules and rules.mesh is not None and rules.mesh_axes_for("seq"))


def resolve_spec(logical_axes: Iterable[str | None], rules: ShardingRules | None = None) -> P:
    rules = rules or current_rules()
    if rules is None:
        return P()
    return rules.spec_for(logical_axes)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Apply a sharding constraint expressed in logical axes (no-op without
    ambient rules / mesh — e.g. in single-device smoke tests)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec_for_shape(tuple(x.shape), logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Declarative parameter leaf: shape + dtype + logical axes + init law."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'ssm_a' | 'ssm_dt'
    dtype: Any = jnp.float32
    scale: float | None = None  # stddev override for 'normal'

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, spec: TensorSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "ssm_a":
        # Mamba2 A is a negative scalar per head: A = -exp(uniform(log 1..16))
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        a = -jnp.exp(u * (np.log(16.0) - np.log(1.0)) + np.log(1.0))
        return a.astype(spec.dtype)
    if spec.init == "ssm_dt":
        # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(spec.dtype)
    if spec.init == "normal":
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def init_from_template(key: jax.Array, template: Any) -> Any:
    """Materialize parameter arrays from a TensorSpec pytree."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_from_template(template: Any, dtype: Any | None = None) -> Any:
    """ShapeDtypeStruct pytree (dry-run stand-ins; never allocates)."""

    def to_sds(s: TensorSpec):
        return jax.ShapeDtypeStruct(s.shape, dtype or s.dtype)

    return jax.tree.map(to_sds, template, is_leaf=_is_spec)


def specs_from_template(template: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree matching the template structure (shape-aware)."""

    def to_spec(s: TensorSpec) -> P:
        return rules.spec_for_shape(s.shape, s.axes)

    return jax.tree.map(to_spec, template, is_leaf=_is_spec)


def shardings_from_template(template: Any, rules: ShardingRules) -> Any:
    """NamedSharding pytree (requires rules.mesh)."""
    assert rules.mesh is not None

    def to_sharding(s: TensorSpec) -> NamedSharding:
        return NamedSharding(rules.mesh, rules.spec_for_shape(s.shape, s.axes))

    return jax.tree.map(to_sharding, template, is_leaf=_is_spec)


def specs_for_axes(abstract: Any, axes: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree for an abstract (ShapeDtypeStruct) pytree whose
    logical axes are given as a parallel pytree of tuples — used for KV
    caches and batch inputs in the dry-run."""

    def one(sds, ax):
        return rules.spec_for_shape(tuple(sds.shape), ax)

    return jax.tree.map(one, abstract, axes, is_leaf=lambda x: isinstance(x, tuple))


def shardings_for_axes(abstract: Any, axes: Any, rules: ShardingRules) -> Any:
    assert rules.mesh is not None

    def one(sds, ax):
        return NamedSharding(rules.mesh, rules.spec_for_shape(tuple(sds.shape), ax))

    return jax.tree.map(one, abstract, axes, is_leaf=lambda x: isinstance(x, tuple))


def stack_specs(spec: TensorSpec, n: int) -> TensorSpec:
    """Prepend a scan-over-layers axis to a TensorSpec."""
    return dataclasses.replace(
        spec, shape=(n, *spec.shape), axes=("layers", *spec.axes)
    )


def stack_template(template: Any, n: int) -> Any:
    return jax.tree.map(lambda s: stack_specs(s, n), template, is_leaf=_is_spec)


def param_count(template: Any) -> int:
    leaves = jax.tree.leaves(template, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def param_bytes(template: Any, dtype_bytes: int = 2) -> int:
    return param_count(template) * dtype_bytes
