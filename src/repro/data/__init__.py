from repro.data.pipeline import SyntheticTokenPipeline, make_batch
