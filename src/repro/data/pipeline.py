"""Deterministic, shard-aware synthetic token pipeline.

Design goals (what a production input pipeline must guarantee):

  * **determinism**: batch ``i`` is a pure function of (seed, i) — restarts
    resume mid-epoch without data loss or duplication (the pipeline state is
    just the step counter, which the checkpoint already stores);
  * **shard-awareness**: each data-parallel host materializes only its slice
    of the global batch (``host_slice``), so input bytes scale with the
    host count rather than the global batch;
  * **structured synthetic text**: tokens follow a deterministic mixture of
    Zipfian unigrams and a repeated-ngram process, giving the LM a learnable
    signal (loss decreases measurably within a few hundred steps) unlike
    uniform noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_prob: float = 0.35
    ngram: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed Zipfian unigram distribution over the vocab
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks**self.zipf_a
        self._p = p / p.sum()
        # a bank of "phrases" the stream repeats (learnable structure)
        self._phrases = rng.integers(
            0, self.vocab_size, size=(256, self.ngram), dtype=np.int64
        )

    # ------------------------------------------------------------------
    def batch(self, step: int) -> dict:
        """The full global batch for ``step`` (deterministic in (seed, step))."""
        return self.host_slice(step, 0, 1)

    def host_slice(self, step: int, host_idx: int, n_hosts: int) -> dict:
        """This host's slice of global batch ``step``."""
        assert self.global_batch % n_hosts == 0
        per = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_idx])
        )
        b, s = per, self.seq_len
        toks = rng.choice(self.vocab_size, size=(b, s + 1), p=self._p).astype(np.int64)
        # overwrite random spans with repeated phrases
        n_spans = int(self.repeat_prob * (s + 1) / self.ngram)
        for i in range(b):
            starts = rng.integers(0, s + 1 - self.ngram, size=n_spans)
            ids = rng.integers(0, len(self._phrases), size=n_spans)
            for st, pid in zip(starts, ids):
                toks[i, st : st + self.ngram] = self._phrases[pid]
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_batch(cfg, batch: int, seq: int, *, step: int = 0, seed: int = 0) -> dict:
    """Convenience: one batch shaped for ``cfg`` (adds stub frames for
    vlm/encdec frontends)."""
    pipe = SyntheticTokenPipeline(cfg.vocab_size, seq, batch, seed=seed)
    out = {k: v for k, v in pipe.batch(step).items()}
    if cfg.family in ("vlm", "encdec"):
        nf = cfg.n_frontend_tokens or 64
        rng = np.random.default_rng(seed + 1)
        out["frames"] = rng.standard_normal((batch, nf, cfg.d_model)).astype(np.float32) * 0.02
    return out
