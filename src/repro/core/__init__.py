"""BlitzScale core: the paper's contribution as composable JAX + host modules.

  topology        — scale-up/scale-out cluster model (Fig. 10)
  parameter_pool  — global O(1)-cached parameter manager (§5.3)
  multicast       — Algorithm 11 interference-free multi-chain planner (§5.1)
  zigzag          — live-scaling pipeline ILP + ILP-free scheduler (§5.2)
  live_scaling    — cooperative execution protocol + jittable split forward
  autoscaler      — load monitor + bound policy + decode pre-scaling (§5.3-4)
  collectives     — TPU data plane: pipelined ppermute chain broadcast
  simulator       — discrete-event MAAS evaluation harness (Fig. 3 method)
"""

from repro.core.autoscaler import Autoscaler, PolicyConfig
from repro.core.live_scaling import LiveSession, cooperative_forward
from repro.core.multicast import MulticastPlan, plan_multicast, validate_plan
from repro.core.parameter_pool import ParameterPool
from repro.core.topology import Role, Topology, make_cluster
from repro.core.zigzag import (
    simulate_best_effort,
    simulate_zigzag,
    solve_pipeline_ilp,
)

__all__ = [
    "Autoscaler",
    "PolicyConfig",
    "LiveSession",
    "cooperative_forward",
    "MulticastPlan",
    "plan_multicast",
    "validate_plan",
    "ParameterPool",
    "Role",
    "Topology",
    "make_cluster",
    "simulate_best_effort",
    "simulate_zigzag",
    "solve_pipeline_ilp",
]
