"""Discrete-event MAAS cluster simulator (the paper's Fig. 3 methodology).

Reproduces the paper's evaluation: real-world-shaped traces are served by a
cluster of instances whose autoscaling *data plane* is modelled per system:

  ==============  =========================================================
  system          data plane on scale-up
  ==============  =========================================================
  blitz           Algorithm-11 multicast over the compute network (+ live
                  ZigZag cooperative execution: the overloaded source
                  instance's throughput ramps with the target's loaded
                  layers, reaching 2x at L/2)
  blitz-nolive    same network multicast, stop-the-world
  blitz-naive     compute network, but unicast from one copy through a
                  single egress, interference-ignorant ("+Network")
  sllm            ServerlessLLM: host-cache hit -> PCIe; miss -> SSD; TTL
                  keepalive makes its host cache O(#hosts touched) (Fig.19)
  allcache        ServerlessLLM-optimal: always PCIe from host cache
  fixed           DistServe/vLLM-style: no autoscaling (full / half
                  provisioning)
  ==============  =========================================================

The *network* data planes (multicast, naive unicast) ride the shared
flow-level simulator ``repro.net.FlowSim``: scale transfers are real flows
that contend — under max-min fair sharing — with serving traffic and with
each other, over the modelled leaf-spine graph (``spine_oversub`` exposes
oversubscribed spines; ``link_latency_s`` / ``switch_latency_s`` enable
the per-hop latency model).  Serving traffic itself is request-granular:
every finished prefill ships its ACTUAL KV volume (``prompt_tokens x
kv_bytes_per_token``) as one prefill→decode flow, and the request only
starts decoding when that flow lands — so scale-up multicast, KV
migration and real serving traffic contend at request granularity.
``per_request_kv=False`` restores the PR-3 behaviour (one persistent
background stream per active prefill instance), the configuration the
golden-trace regression test pins bit-for-bit.  Host-local planes (SSD,
PCIe host cache) remain analytic.

Timing model (per instance): prefill is compute-bound
(``tokens / prefill_tps``), decode is memory-bound (weight pass + per-seq
KV read per round); decode pre-scaling (§5.4) applies to every autoscaling
system, as in the paper.  All timing constants derive from the paper's
A800 cluster (Table 1) so Fig. 3/17 magnitudes are comparable.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import defaultdict, deque
from typing import Callable, Iterable

import numpy as np

from repro.core import multicast as mc
from repro.core import topology as topo_mod
from repro.core.autoscaler import Autoscaler, LoadSample, PolicyConfig
from repro.core.live_scaling import LiveSession
from repro.core.parameter_pool import ParameterPool
from repro.core.topology import Role, Topology, gbps_to_bytes_per_s
from repro.net import Flow, FlowKind, FlowSim, MulticastExecution
from repro.obs.ledger import DEVICE_STATES, DeviceTimeLedger
from repro.obs.trace import NULL_TRACER, NetEventBridge
from repro.workloads.traces import request_kv_bytes

# ---------------------------------------------------------------------------
# Model serving profile
# ---------------------------------------------------------------------------

A800_TFLOPS = 312e12 * 0.45  # effective prefill FLOP/s per GPU (MFU ~0.45)
A800_HBM = 1.6e12  # effective HBM bytes/s per GPU


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    param_bytes: int
    n_layers: int
    devices_per_instance: int
    kv_bytes_per_token: int
    ttft_slo_s: float
    tbt_slo_s: float

    @property
    def prefill_tps(self) -> float:
        """Compute-bound: 2*N FLOPs/token over the instance's GPUs."""
        flops_per_tok = 2.0 * (self.param_bytes / 2)  # bf16 -> N params
        return A800_TFLOPS * self.devices_per_instance / flops_per_tok

    @property
    def weight_pass_s(self) -> float:
        """One decode round reads all weights once (per GPU shard)."""
        return (self.param_bytes / self.devices_per_instance) / A800_HBM

    def kv_read_s(self, ctx_tokens: float) -> float:
        return ctx_tokens * self.kv_bytes_per_token / (A800_HBM * self.devices_per_instance)

    @property
    def kv_capacity_tokens(self) -> int:
        """KV room per instance: 80 GB/GPU minus weights."""
        free = 80e9 * self.devices_per_instance - self.param_bytes * 1.2
        return max(int(free / self.kv_bytes_per_token), 1)


def profile_for(size: str) -> ModelProfile:
    """The paper's three evaluation models (§6: SLOs follow DistServe)."""
    if size == "8b":
        return ModelProfile("llama3-8b", 16_000_000_000, 32, 1,
                            2 * 32 * 8 * 128 * 2, 0.45, 0.15)
    if size == "24b":
        return ModelProfile("mistral-24b", 48_000_000_000, 40, 2,
                            2 * 40 * 8 * 128 * 2, 0.80, 0.175)
    if size == "72b":
        return ModelProfile("qwen2.5-72b", 144_000_000_000, 80, 4,
                            2 * 80 * 8 * 128 * 2, 1.25, 0.20)
    raise ValueError(size)


# ---------------------------------------------------------------------------
# Requests and instances
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt: int
    output: int
    prefill_done: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    decoded: int = 0
    kv_src: int | None = None  # device whose prefill froze this request's KV

    @property
    def ttft(self) -> float | None:
        return None if self.prefill_done is None else self.prefill_done - self.arrival

    def tbts(self) -> list[float]:
        ts = [self.prefill_done] + self.token_times if self.prefill_done else self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


@dataclasses.dataclass
class Instance:
    iid: int
    phase: str  # 'prefill' | 'decode'
    device_ids: tuple[int, ...]
    active_from: float  # when it can serve at full capacity (inf = loading,
    #                     resolved when the scale flows actually complete)
    # live scaling: a session attached to the *source* (overloaded) instance;
    # its throughput multiplier ramps 1 -> 2 as the paired target loads layers
    live_boost: LiveSession | None = None
    queue: deque = dataclasses.field(default_factory=deque)
    busy_until: float = 0.0
    active_reqs: dict = dataclasses.field(default_factory=dict)  # rid -> Request
    kv_tokens: int = 0
    retired: bool = False
    pending_devs: set = dataclasses.field(default_factory=set)  # devices whose
    #   scale flows have not landed yet (network data planes)
    scale_start: float = 0.0

    def boost(self, now: float) -> float:
        if self.live_boost is None:
            return 1.0
        if now >= self.live_boost.done_at():
            self.live_boost = None
            return 1.0
        return self.live_boost.throughput_multiplier(now)


# ---------------------------------------------------------------------------
# System policy descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    data_plane: str  # 'ssd'|'hostcache'|'network_naive'|'network_multicast'|'fixed'|'delay'
    live: bool = False
    autoscale: bool = True
    keepalive_s: float = 300.0  # S-LLM 5-minute TTL
    fixed_prefill: int = 0
    fixed_decode: int = 0
    fixed_delay_s: float = 0.0  # for the Fig. 3 scaling-stop sweep
    allow_interference: bool = False
    control_plane_s: float = 0.05  # CUDA-context-pool / pre-lowered exec (§A.1)
    prewarm: bool = False  # AllCache: parameters start cached on every host


BLITZ = SystemConfig("blitz", "network_multicast", live=True)
BLITZ_NOLIVE = SystemConfig("blitz-nolive", "network_multicast", live=False)
BLITZ_NAIVE = SystemConfig("blitz-naive", "network_naive", live=False,
                           allow_interference=True)
SLLM = SystemConfig("sllm", "hostcache", live=False)
ALLCACHE = SystemConfig("allcache", "hostcache", live=False, keepalive_s=1e18,
                        prewarm=True)
SSD_ONLY = SystemConfig("ssd", "ssd", live=False)


def fixed_system(name: str, n_prefill: int, n_decode: int) -> SystemConfig:
    return SystemConfig(name, "fixed", autoscale=False,
                        fixed_prefill=n_prefill, fixed_decode=n_decode)


def delay_system(delay_s: float) -> SystemConfig:
    """Fig. 3 methodology: a manual scaling stop duration."""
    return SystemConfig(f"delay-{delay_s:g}s", "delay", fixed_delay_s=delay_s)


# ---------------------------------------------------------------------------
# Result metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    system: str
    requests: list[Request]
    gpu_time_s: float  # integral of (allocated devices) dt — the ledger total
    host_cache_peak_bytes: dict[int, int]  # per host
    scale_events: int
    scale_seconds: list[float]  # data-plane durations
    net_scale_bytes: float  # bytes moved over compute network for scaling
    timeline: list[tuple[float, int, int]]  # (t, n_prefill, n_decode)
    kv_stream_bytes: float = 0.0  # per-request KV serving bytes over the net
    kv_re_prefills: int = 0  # requests re-prefilled after their KV source died
    # exclusive-state attribution of gpu_time_s (repro.obs.ledger.DEVICE_STATES
    # order); conservation is exact: sum(device_seconds.values()) == gpu_time_s
    device_seconds: dict[str, float] = dataclasses.field(default_factory=dict)

    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft for r in self.requests if r.ttft is not None])

    def tbts(self) -> np.ndarray:
        out = []
        for r in self.requests:
            out.extend(r.tbts())
        return np.array(out) if out else np.array([0.0])

    def slo_attainment(self, prof: ModelProfile) -> float:
        ok = 0
        n = 0
        for r in self.requests:
            if r.ttft is None:
                n += 1
                continue
            n += 1
            good = r.ttft <= prof.ttft_slo_s
            if good and r.tbts():
                good = float(np.percentile(r.tbts(), 99)) <= prof.tbt_slo_s
            ok += bool(good)
        return ok / max(n, 1)

    def p99_ttft(self) -> float:
        t = self.ttfts()
        return float(np.percentile(t, 99)) if len(t) else float("inf")

    def mean_ttft(self) -> float:
        t = self.ttfts()
        return float(np.mean(t)) if len(t) else float("inf")

    def p99_tbt(self) -> float:
        return float(np.percentile(self.tbts(), 99))

    def mean_tbt(self) -> float:
        return float(np.mean(self.tbts()))

    def host_cache_total(self) -> float:
        return float(sum(self.host_cache_peak_bytes.values()))


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------


class Simulator:
    def __init__(
        self,
        system: SystemConfig,
        prof: ModelProfile,
        *,
        n_hosts: int = 4,
        devs_per_host: int = 8,
        net_gbps: float = 100.0,
        nvlink: bool = True,
        pcie_gbps: float = 256.0,
        ssd_gbps: float = 10.0,
        monitor_dt: float = 0.1,
        spine_oversub: float = 1.0,
        link_latency_s: float = 0.0,
        switch_latency_s: float = 0.0,
        link_profiles=None,
        per_request_kv: bool = True,
        seed: int = 0,
        tracer=None,
        metrics=None,
        slo_monitor=None,
        link_ledger=None,
        flight_recorder=None,
    ):
        self.sys = system
        self.prof = prof
        self.net_gbps = net_gbps
        self.pcie_gbps = pcie_gbps
        self.ssd_gbps = ssd_gbps
        self.monitor_dt = monitor_dt
        # request-granular serving traffic (per-prefill KV flows) only makes
        # sense on the network data planes; False restores the PR-3 model of
        # one persistent background stream per active prefill instance
        self._kv_net = per_request_kv and system.data_plane in (
            "network_multicast", "network_naive"
        )
        # host pseudo-devices join the topology so cold-start unicasts from
        # the O(1) host copy are real flows on the shared network simulator
        self.topo = topo_mod.add_host_sources(
            topo_mod.make_cluster(
                n_hosts, devs_per_host, bw_gbps=net_gbps,
                scaleup_per_host=nvlink,
            ),
            pcie_gbps=pcie_gbps,
        )
        self.flowsim = FlowSim(
            self.topo,
            spine_oversub=spine_oversub,
            link_latency_s=link_latency_s,
            switch_latency_s=switch_latency_s,
            link_profiles=link_profiles,
        )
        self.pool = ParameterPool(self.topo)
        self.pool.register(prof.name, prof.param_bytes)
        self.rng = np.random.default_rng(seed)

        self.instances: dict[int, Instance] = {}
        self._iid = 0
        self.now = 0.0
        self.events: list[tuple[float, int, str, object]] = []
        self._eid = 0
        self.done: set[int] = set()
        self.waiting_decode: deque[Request] = deque()
        # S-LLM style host cache tracking: host -> model -> last_used
        self.host_cache: dict[int, dict[str, float]] = defaultdict(dict)
        self.host_cache_peak: dict[int, int] = defaultdict(int)
        self.scale_seconds: list[float] = []
        self.net_scale_bytes = 0.0
        self.scale_events = 0
        # the device-time ledger IS the GPU-time accounting: every accounted
        # interval lands in exactly one state, and gpu_time_s is defined as
        # the ledger total — attribution conserves by construction
        self.ledger = DeviceTimeLedger()
        self._last_gpu_t = 0.0
        self.timeline: list[tuple[float, int, int]] = []
        self._serving_flows: dict[int, Flow] = {}  # prefill iid -> KV stream
        self._dev2inst: dict[int, Instance] = {}  # scale flows in flight
        self.kv_stream_bytes = 0.0  # per-request KV volume shipped over the net
        self.kv_re_prefills = 0  # KV source died -> re-prefilled elsewhere

        # observability: the null tracer keeps every instrumented site a
        # no-op, and the net bridge is only subscribed when tracing is on —
        # a disabled run's flow-event stream is bit-for-bit unchanged
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        # optional streaming SLO monitor (repro.obs.slo.SLOMonitor): fed at
        # prefill completion (TTFT) and request completion (TBTs)
        self.slo = slo_monitor
        # optional link-time ledger: attaches to the FlowSim so every byte
        # the run moves is attributed to its flow-kind group per link
        self.link_ledger = link_ledger
        if link_ledger is not None:
            self.flowsim.attach_ledger(link_ledger)
        self._bridge = None
        if self.tracer.enabled:
            self._bridge = NetEventBridge(self.tracer)
            self.flowsim.subscribe(self._bridge)
        # optional flight recorder (repro.obs.flightrec.FlightRecorder):
        # rides the same NetEvent subscription for its always-on ring and
        # failure triggers; SLO-page triggers are polled from _monitor
        self.flight_recorder = flight_recorder
        if flight_recorder is not None:
            flight_recorder.attach(self.flowsim)
        self._req_spans: dict[int, object] = {}  # rid -> request root span
        self._decode_spans: dict[int, object] = {}  # rid -> open decode span
        self._scale_spans: dict[int, object] = {}  # iid -> instance-load span
        self._scale_ops: dict[int, object] = {}  # op sid -> scale_op span
        self._scale_pending: dict[int, set[int]] = {}  # op sid -> open iids

        cap_tps = self.prof.prefill_tps
        dec_tps = 32.0 / (self.prof.weight_pass_s + 32 * self.prof.kv_read_s(1024))
        n_accel = sum(1 for d in self.topo.devices if not d.is_host)
        self.scaler = Autoscaler(
            PolicyConfig(max_instances=n_accel // prof.devices_per_instance),
            prefill_capacity_tps=cap_tps * 0.9,
            decode_capacity_tps=dec_tps,
        )
        self._reqs: dict[int, Request] = {}

    # -- event machinery ----------------------------------------------------
    def schedule(self, t: float, fn) -> None:
        """Run ``fn(sim)`` at simulation time ``t`` — the hook failure
        scenarios use (e.g. ``sim.schedule(5.0, lambda s:
        s.flowsim.fail_device(3, s.now))``)."""
        self.push(t, "call", fn)

    def push(self, t: float, kind: str, payload: object = None) -> None:
        if not math.isfinite(t):
            return  # loading instances have active_from=inf until flows land
        self._eid += 1
        # never schedule into the past — a stale net event must not move
        # simulation time backwards
        heapq.heappush(self.events, (max(t, self.now), self._eid, kind, payload))

    def _schedule_net(self) -> None:
        """Keep a poll event at the flow simulator's next completion time;
        any flow mutation moves that time, so this is re-armed after each."""
        t = self.flowsim.next_event_time()
        if t is not None:
            self.push(t, "net")

    # -- tracing helpers ------------------------------------------------------
    def _trace_decode_begin(self, rid: int, iid: int) -> None:
        if self.tracer.enabled and rid not in self._decode_spans:
            self._decode_spans[rid] = self.tracer.begin(
                "decode", self.now, cat="compute",
                parent=self._req_spans.get(rid), iid=iid)

    def _trace_request_done(self, r: Request, t: float) -> None:
        sp = self._decode_spans.pop(r.rid, None)
        if sp is not None:
            self.tracer.end(sp, t)
        root = self._req_spans.pop(r.rid, None)
        if root is not None:
            self.tracer.end(root, t, tokens=r.output)

    def _trace_scale_close(self, iid: int, t: float, *,
                           aborted: bool = False) -> None:
        """Close a loading instance's span; the batch scale_op span closes
        when its last instance does."""
        sp = self._scale_spans.pop(iid, None)
        if sp is None:
            return
        if aborted:
            self.tracer.end(sp, t, aborted=True)
        else:
            self.tracer.instant("serving", t, cat="scale", parent=sp)
            self.tracer.end(sp, t)
        pend = self._scale_pending.get(sp.parent)
        if pend is not None:
            pend.discard(iid)
            if not pend:
                del self._scale_pending[sp.parent]
                op = self._scale_ops.pop(sp.parent, None)
                if op is not None:
                    self.tracer.end(op, t)

    # -- instance management --------------------------------------------------
    def _alloc_devices(self, n_devs: int) -> list[int] | None:
        spares = [d for d in self.topo.spares() if self.flowsim.device_ok(d.id)]
        by_su = self.topo.scaleup_groups([d.id for d in spares])
        ids: list[int] = []
        for su, members in sorted(by_su.items(), key=lambda kv: -len(kv[1])):
            for m in members:
                if len(ids) < n_devs:
                    ids.append(m)
        return ids if len(ids) == n_devs else None

    def _activate_instance(self, phase: str, dev_ids: list[int],
                           active_from: float) -> Instance:
        inst = Instance(self._iid, phase, tuple(dev_ids), active_from,
                        busy_until=active_from)
        self._iid += 1
        self.instances[inst.iid] = inst
        for i in dev_ids:
            d = self.topo.device(i)
            d.role = Role.PREFILL if phase == "prefill" else Role.DECODE
            d.model = self.prof.name
        self.pool.deploy(self.prof.name, dev_ids)
        return inst

    def _retire_instance(self, inst: Instance) -> None:
        inst.retired = True
        self._trace_scale_close(inst.iid, self.now, aborted=True)
        self.pool.reclaim(self.prof.name, inst.device_ids)
        self.instances.pop(inst.iid, None)
        for i in inst.device_ids:
            if self._dev2inst.get(i) is inst:
                self._dev2inst.pop(i, None)
        f = self._serving_flows.pop(inst.iid, None)
        if f is not None:
            self.flowsim.remove(f, self.now, abort=False)
            self._schedule_net()

    def _live_instances(self, phase: str) -> list[Instance]:
        return [i for i in self.instances.values() if i.phase == phase and not i.retired]

    def _active_instances(self, phase: str) -> list[Instance]:
        return [i for i in self._live_instances(phase) if self.now >= i.active_from]

    # -- data plane models -----------------------------------------------------
    def _delay_simple(self, dev_ids: list[int]) -> float:
        """Data-plane seconds for one instance on ssd/hostcache/delay planes
        (host-LOCAL loads — the compute-network planes are real flows)."""
        s = self.sys
        pb = self.prof.param_bytes
        per_dev = pb / self.prof.devices_per_instance
        if s.data_plane == "delay":
            return s.fixed_delay_s
        if s.data_plane == "ssd":
            return per_dev / gbps_to_bytes_per_s(self.ssd_gbps)
        if s.data_plane == "hostcache":
            host = self.topo.device(dev_ids[0]).host
            cache = self.host_cache[host]
            hit = s.prewarm or (
                self.prof.name in cache
                and self.now - cache[self.prof.name] <= s.keepalive_s)
            cache[self.prof.name] = self.now
            self.host_cache_peak[host] = max(self.host_cache_peak[host],
                                             len(cache) * pb)
            bw = self.pcie_gbps if hit else self.ssd_gbps
            return per_dev / gbps_to_bytes_per_s(bw)
        raise ValueError(s.data_plane)

    def _host_source_dev(self, host: int | None) -> int:
        """The pseudo-device holding the O(1) host copy (any host if the
        pool's record is unavailable)."""
        for d in self.topo.devices:
            if d.is_host and (host is None or d.host == host):
                return d.id
        raise RuntimeError("no host pseudo-device in topology")

    def _do_scale(self, phase: str, n_new: int) -> None:
        """Allocate and start loading n_new instances."""
        alloc: list[list[int]] = []
        for _ in range(n_new):
            devs = self._alloc_devices(self.prof.devices_per_instance)
            if devs is None:
                break
            # reserve immediately so subsequent allocs don't reuse
            for i in devs:
                self.topo.device(i).model = self.prof.name
                self.topo.device(i).role = (Role.PREFILL if phase == "prefill"
                                            else Role.DECODE)
            alloc.append(devs)
        if not alloc:
            return
        pb = self.prof.param_bytes

        if self.sys.data_plane in ("network_multicast", "network_naive"):
            self._do_scale_network(phase, alloc)
            return

        for devs in alloc:
            delay = self._delay_simple(devs) + self.sys.control_plane_s
            self.scale_seconds.append(delay)
            self.scale_events += 1
            inst = self._activate_instance(phase, devs, self.now + delay)
            if self.tracer.enabled:
                op = self.tracer.span(
                    "scale_op", self.now, self.now + delay, cat="scale",
                    track="scale", phase=phase, plane=self.sys.data_plane,
                    iid=inst.iid, control_s=self.sys.control_plane_s)
                self.tracer.instant("serving", self.now + delay, cat="scale",
                                    parent=op)
            self.push(self.now + delay, "scale_done", inst.iid)

    def _do_scale_network(self, phase: str, alloc: list[list[int]]) -> None:
        """Compute-network data plane: scale transfers are flows on the
        shared FlowSim, contending with serving streams and each other;
        instances activate when their devices' flows actually land."""
        pb = self.prof.param_bytes
        for devs in alloc:  # roles already set; undo for planning targets
            for i in devs:
                self.topo.device(i).role = Role.FREE
                self.topo.device(i).model = None
        gpu_srcs, host = self.pool.sources(self.prof.name)
        tgt_ids = [i for devs in alloc for i in devs]

        op = None
        if self.tracer.enabled:
            # decision -> plan -> hops -> layer arrivals -> serving, one tree
            op = self.tracer.begin(
                "scale_op", self.now, cat="scale", track="scale",
                phase=phase, plane=self.sys.data_plane, n_instances=len(alloc),
                control_s=self.sys.control_plane_s)

        plan = None
        if self.sys.data_plane == "network_multicast":
            # ONE Algorithm-11 plan covers the whole batch (multi-chain);
            # plan_multicast falls back to the O(1) host copy when every
            # GPU source is pruned or absent (hosts are in the topology).
            # Planned while the targets are still role-FREE, against the
            # FlowSim's latency view so the planner prices the same
            # store-and-forward delays the data plane will charge.
            plan = mc.plan_multicast(
                self.topo, gpu_srcs, tgt_ids, len(tgt_ids),
                allow_interference=self.sys.allow_interference,
                net=self.flowsim, model_bytes=pb,
            )
            if op is not None:
                self.tracer.instant(
                    "plan", self.now, cat="scale", parent=op,
                    chains=len(plan.chains), covered=len(plan.covered))

        insts: list[Instance] = []
        for devs in alloc:
            inst = self._activate_instance(phase, devs, math.inf)
            inst.pending_devs = set(devs)
            inst.scale_start = self.now
            inst.busy_until = math.inf
            for i in devs:
                self._dev2inst[i] = inst
            insts.append(inst)
            self.scale_events += 1
            if op is not None:
                self._scale_spans[inst.iid] = self.tracer.begin(
                    "instance_load", self.now, cat="load", parent=op,
                    iid=inst.iid, devices=list(devs))
        if op is not None:
            self._scale_ops[op.sid] = op
            self._scale_pending[op.sid] = {i.iid for i in insts}
        self.net_scale_bytes += pb * len(alloc)

        if plan is not None:
            t_est = plan.transfer_seconds(pb)
            # degenerate plans (no chains, or only edge-less source-only
            # chains -> t_est == 0) must not feed the live-boost ramp an
            # instant/absurd rate: fall back to the analytic unicast time
            if not plan.chains or t_est <= 0.0 or not math.isfinite(t_est):
                t_est = pb / gbps_to_bytes_per_s(min(self.pcie_gbps, self.net_gbps))
            exec_ = MulticastExecution(
                plan, pb, on_node_ready=self._node_ready,
                tracer=self.tracer if op is not None else None,
                parent_span=op,
            )
            if self._bridge is not None:
                self._bridge.pin_all(exec_.flows, op)
            exec_.start(self.flowsim, self.now)
            uncovered = set(tgt_ids) - set(plan.covered)
            if self.sys.live and phase == "prefill":
                # pair loading targets with the most-loaded active sources;
                # a source's throughput ramps with the target's layer loads
                for _ in alloc:
                    srcs = self._active_instances("prefill")
                    if srcs:
                        src = max(srcs, key=lambda i: len(i.queue))
                        if src.live_boost is None:
                            src.live_boost = LiveSession(
                                self.prof.n_layers,
                                pb // self.prof.n_layers,
                                pb / max(t_est, 1e-9),
                                started_at=self.now,
                            )
        else:  # network_naive: unicast through ONE egress, interference-
            # ignorant source selection (reads from a serving GPU copy when
            # one exists — its KV stream shares the same link direction)
            src = gpu_srcs[0] if gpu_srcs else self._host_source_dev(host)
            uncovered = set()
            for inst in insts:
                f = Flow(
                    FlowKind.COLD_START, src, inst.device_ids[0], float(pb),
                    on_complete=self._unicast_done, payload=inst.iid,
                    tag=f"naive:{inst.iid}",
                )
                if self._bridge is not None:
                    self._bridge.pin(f, self._scale_spans.get(inst.iid))
                self.flowsim.start(f, self.now)
                # the flow lands on one device; siblings fill over scale-up
                inst.pending_devs = {inst.device_ids[0]}
                for i in inst.device_ids[1:]:
                    self._dev2inst.pop(i, None)

        # targets the planner could not reach at all: PCIe host fallback
        for i in sorted(uncovered):
            f = Flow(
                FlowKind.COLD_START, self._host_source_dev(host), i, float(pb),
                on_complete=lambda f, t: self._dev_ready(f.dst, t),
                tag=f"fallback:{i}",
            )
            if self._bridge is not None:
                self._bridge.pin(f, op)
            self.flowsim.start(f, self.now)
        self._schedule_net()

    # -- scale-flow completion plumbing ---------------------------------------
    def _node_ready(self, node, t: float) -> None:
        for i in node.device_ids:
            self._dev_ready(i, t)

    def _unicast_done(self, flow: Flow, t: float) -> None:
        inst = self.instances.get(flow.payload)
        if inst is not None:
            for i in list(inst.pending_devs):
                self._dev_ready(i, t)

    def _dev_ready(self, dev: int, t: float) -> None:
        inst = self._dev2inst.get(dev)
        if inst is None:
            return
        inst.pending_devs.discard(dev)
        self._dev2inst.pop(dev, None)
        if inst.pending_devs or inst.retired:
            return
        delay = (t - inst.scale_start) + self.sys.control_plane_s
        self.scale_seconds.append(delay)
        inst.active_from = t + self.sys.control_plane_s
        inst.busy_until = inst.active_from
        self._trace_scale_close(inst.iid, inst.active_from)
        self.push(inst.active_from, "scale_done", inst.iid)

    # -- serving: prefill ------------------------------------------------------
    def _best_prefill(self) -> Instance | None:
        cands = self._active_instances("prefill")
        if self._kv_net:
            # a prefill whose NIC died can compute but never hand off its
            # KV — only route there when nothing healthy exists at all
            ok = [i for i in cands if self.flowsim.device_ok(i.device_ids[0])]
            cands = ok or cands
        if not cands:
            # fall back to the earliest-activating instance (requests queue)
            pend = self._live_instances("prefill")
            return min(pend, key=lambda i: i.active_from) if pend else None
        return min(cands, key=lambda i: (len(i.queue), max(i.busy_until - self.now, 0.0)))

    def _kick_prefill(self, inst: Instance) -> None:
        if inst.retired or not inst.queue:
            return
        if self.now < inst.active_from:
            self.push(inst.active_from, "prefill_round", inst.iid)
            return
        if inst.busy_until > self.now + 1e-12:
            self.push(inst.busy_until, "prefill_round", inst.iid)
            return
        mult = inst.boost(self.now)  # >= 1; live cooperative execution
        req: Request = inst.queue.popleft()
        service = req.prompt / (self.prof.prefill_tps * mult)
        inst.busy_until = self.now + service
        if self.tracer.enabled:
            root = self._req_spans.get(req.rid)
            if root is not None:
                # partition [arrival, prefill_done] exactly: waiting for the
                # instance's parameters to arrive (load), then behind other
                # requests (queue), then the forward pass itself (compute) —
                # the three causes the attribution report splits TTFT into
                b = min(max(inst.active_from, req.arrival), self.now)
                if b - req.arrival > 1e-12:
                    self.tracer.span("load_wait", req.arrival, b, cat="load",
                                     parent=root, iid=inst.iid)
                if self.now - b > 1e-12:
                    self.tracer.span("queue", b, self.now, cat="queue",
                                     parent=root, iid=inst.iid)
                self.tracer.span("prefill", self.now, inst.busy_until,
                                 cat="compute", parent=root, iid=inst.iid)
        self.push(inst.busy_until, "prefill_done", (inst.iid, req.rid))

    # -- serving: decode -------------------------------------------------------
    def _best_decode(self, req: Request) -> Instance | None:
        need = req.prompt + req.output
        cands = [i for i in self._active_instances("decode")
                 if i.kv_tokens + need <= self.prof.kv_capacity_tokens]
        if not cands:
            return None
        return min(cands, key=lambda i: i.kv_tokens)

    def _admit_waiting(self, inst: Instance) -> None:
        if self._kv_net:
            self._drain_waiting()
            return
        while self.waiting_decode:
            r = self.waiting_decode[0]
            if inst.kv_tokens + r.prompt + r.output > self.prof.kv_capacity_tokens:
                break
            self.waiting_decode.popleft()
            was_empty = not inst.active_reqs
            inst.active_reqs[r.rid] = r
            inst.kv_tokens += r.prompt + r.output
            self._trace_decode_begin(r.rid, inst.iid)
            if was_empty:
                self.push(self.now, "decode_round", inst.iid)

    # -- per-request KV serving streams (request-granular network realism) ----
    def _best_kv_target(self, req: Request) -> Instance | None:
        """A decode instance with KV room AND a live NIC — a per-request KV
        stream must actually be deliverable."""
        need = req.prompt + req.output
        cands = [i for i in self._active_instances("decode")
                 if i.kv_tokens + need <= self.prof.kv_capacity_tokens
                 and self.flowsim.device_ok(i.device_ids[0])]
        return min(cands, key=lambda i: i.kv_tokens) if cands else None

    def _route_kv(self, r: Request) -> None:
        dinst = self._best_kv_target(r)
        if dinst is None:
            self.waiting_decode.append(r)
            return
        self._start_kv_flow(r, dinst)

    def _start_kv_flow(self, r: Request, dinst: Instance) -> None:
        """Ship the request's ACTUAL KV volume prefill→decode as one flow;
        the request starts decoding only when the flow lands.  The KV seat
        on the target is reserved at flow start so concurrent streams never
        oversubscribe its capacity."""
        dinst.kv_tokens += r.prompt + r.output
        src, dst = r.kv_src, dinst.device_ids[0]
        if src is None or src == dst:
            self._kv_landed(dinst.iid, r.rid)  # nothing to cross the wire
            return
        if not self.flowsim.device_ok(src):
            # the device holding the frozen KV died: the pages cannot leave
            # it — pay a real re-prefill on a healthy instance (mirrors the
            # disagg runtime's re_prefills path), then stream from there
            dinst.kv_tokens -= r.prompt + r.output
            self._re_prefill(r)
            return
        size = float(request_kv_bytes(r.prompt, self.prof.kv_bytes_per_token))
        self.kv_stream_bytes += size
        f = Flow(
            FlowKind.SERVING, src, dst, size,
            payload=(dinst.iid, r.rid),
            on_complete=lambda f, t: self.push(t, "kv_landed", f.payload),
            on_abort=lambda f, t: self.push(t, "kv_failed", f.payload),
            tag=f"reqkv:{r.rid}",
        )
        if self._bridge is not None:
            self._bridge.pin(f, self._req_spans.get(r.rid),
                             name="kv_transfer", cat="migration")
        self.flowsim.start(f, self.now)
        self._schedule_net()

    def _kv_landed(self, iid: int, rid: int) -> None:
        r = self._reqs[rid]
        inst = self.instances.get(iid)
        if inst is None or inst.retired:
            self._route_kv(r)  # target died/retired while KV was in flight
            return
        was_empty = not inst.active_reqs
        inst.active_reqs[rid] = r
        self._trace_decode_begin(rid, inst.iid)
        if was_empty:
            self.push(self.now, "decode_round", inst.iid)

    def _re_prefill(self, r: Request) -> None:
        """The request's frozen KV sits on a dead device: re-run prefill on
        a healthy instance (compute-bound, occupies that instance) and
        re-route the KV stream from its device when done."""
        cands = [i for i in self._active_instances("prefill")
                 if self.flowsim.device_ok(i.device_ids[0])]
        if not cands:
            # no healthy prefill anywhere: re-enter through the arrival
            # path, where the request queues, counts as offered load (so
            # the autoscaler provisions a replacement) and re-prefills
            # once an instance exists — parking it in waiting_decode would
            # strand it invisibly forever
            self.kv_re_prefills += 1
            self.push(self.now + 0.05, "arrival", r)
            return
        inst = min(cands, key=lambda i: (len(i.queue), max(i.busy_until - self.now, 0.0)))
        service = r.prompt / self.prof.prefill_tps
        t_done = max(self.now, inst.busy_until) + service
        inst.busy_until = t_done
        r.kv_src = inst.device_ids[0]
        self.kv_re_prefills += 1
        self.push(t_done, "kv_route", r.rid)

    def _drain_waiting(self) -> None:
        """Re-route queued requests now that decode capacity (or a reachable
        target) may have appeared."""
        for _ in range(len(self.waiting_decode)):
            r = self.waiting_decode.popleft()
            dinst = self._best_kv_target(r)
            if dinst is None:
                self.waiting_decode.appendleft(r)
                break
            self._start_kv_flow(r, dinst)

    def _decode_round(self, inst: Instance) -> None:
        if inst.retired or not inst.active_reqs:
            return
        if inst.busy_until > self.now + 1e-12:
            self.push(inst.busy_until, "decode_round", inst.iid)
            return
        batch = list(inst.active_reqs.values())
        ctx = sum(r.prompt + r.decoded for r in batch) / len(batch)
        round_t = self.prof.weight_pass_s + len(batch) * self.prof.kv_read_s(ctx)
        t_end = self.now + round_t
        for r in batch:
            r.decoded += 1
            r.token_times.append(t_end)
            if r.decoded >= r.output:
                inst.active_reqs.pop(r.rid, None)
                inst.kv_tokens -= r.prompt + r.output
                self.done.add(r.rid)
                if self.tracer.enabled:
                    self._trace_request_done(r, t_end)
                if self.slo is not None:
                    for tbt in r.tbts():
                        self.slo.observe_tbt("sim", t_end, tbt)
        inst.busy_until = t_end
        self._admit_waiting(inst)
        if inst.active_reqs:
            self.push(t_end, "decode_round", inst.iid)

    # -- monitoring / autoscaling ---------------------------------------------
    def _sync_serving_flows(self) -> None:
        """Keep one persistent KVCache stream (prefill egress -> decode
        ingress) per active prefill instance on the FlowSim, so scale flows
        contend with live serving traffic (the Fig. 7b interference that
        interference-aware planning avoids and 'blitz-naive' suffers)."""
        if self.sys.data_plane not in ("network_multicast", "network_naive"):
            return
        if self._kv_net:
            return  # serving traffic is per-request KV flows, not streams
        decs = self._active_instances("decode")
        desired: dict[int, tuple[int, int]] = {}
        if decs:
            for inst in self._active_instances("prefill"):
                dst = decs[inst.iid % len(decs)]
                desired[inst.iid] = (inst.device_ids[0], dst.device_ids[0])
        changed = False
        for iid, f in list(self._serving_flows.items()):
            if desired.get(iid) != (f.src, f.dst):
                self.flowsim.remove(f, self.now, abort=False)
                del self._serving_flows[iid]
                changed = True
        for iid, (s, d) in desired.items():
            if iid not in self._serving_flows:
                f = Flow(FlowKind.SERVING, s, d, math.inf, tag=f"serving:{iid}")
                self.flowsim.start(f, self.now)
                self._serving_flows[iid] = f
                changed = True
        if changed:
            self._schedule_net()

    def _monitor(self) -> None:
        self._sync_serving_flows()
        if self._kv_net and self.waiting_decode:
            self._drain_waiting()  # recover from aborts / retired targets
        if self.metrics is not None:
            m = self.metrics
            m.gauge("sim.instances.prefill").set(
                len(self._live_instances("prefill")))
            m.gauge("sim.instances.decode").set(
                len(self._live_instances("decode")))
            m.gauge("sim.waiting_decode").set(len(self.waiting_decode))
            m.counter("sim.scale_events").set(self.scale_events)
            m.counter("sim.net_scale_bytes").set(self.net_scale_bytes)
            m.counter("sim.kv_stream_bytes").set(self.kv_stream_bytes)
            m.snap(self.now)
        if self.flight_recorder is not None:
            self.flight_recorder.poll(self.now)
        if not self.sys.autoscale:
            return
        pre = self._live_instances("prefill")
        dec = self._live_instances("decode")
        q_tokens = sum(r.prompt for i in pre for r in i.queue)
        inflight = sum(1 for i in pre if i.busy_until > self.now)
        ptps = q_tokens / max(self.prof.ttft_slo_s, 1e-3) + inflight * self.prof.prefill_tps
        kv_frac = (max((i.kv_tokens for i in dec), default=0)
                   / self.prof.kv_capacity_tokens)
        dtokens = sum(len(i.active_reqs) for i in dec)
        dtps = dtokens / max(self.prof.weight_pass_s + self.prof.kv_read_s(1024), 1e-9) * 1e-3
        self.scaler.prefill_mon.record(LoadSample(self.now, ptps, 0.0, q_tokens))
        self.scaler.decode_mon.record(
            LoadSample(self.now, dtps, kv_frac, len(self.waiting_decode)))
        d = self.scaler.decide(self.now, len(pre), len(dec))
        if d.prefill_delta > 0:
            self._do_scale("prefill", d.prefill_delta)
        elif d.prefill_delta < 0 and len(pre) > 1:
            idle = [i for i in self._active_instances("prefill")
                    if not i.queue and i.busy_until <= self.now and i.live_boost is None]
            if idle:
                self._retire_instance(idle[0])
        if d.decode_delta > 0:
            self._do_scale("decode", d.decode_delta)
        elif d.decode_delta < 0 and len(dec) > 1:
            idle = [i for i in self._active_instances("decode") if not i.active_reqs]
            if idle:
                self._retire_instance(idle[0])

    def _account_gpu(self, t_new: float) -> None:
        # Partition [self._last_gpu_t, t_new] per instance into exclusive
        # ledger states.  Instance state is piecewise-constant over the
        # interval (transitions coincide with popped events), except
        # active_from, which may fall inside it — split there.
        dt = t_new - self._last_gpu_t
        if dt <= 0:
            return
        t0 = self._last_gpu_t
        led = self.ledger
        for inst in self.instances.values():
            n = len(inst.device_ids)
            af = inst.active_from
            if af >= t_new:
                load, active = dt, 0.0
            elif af <= t0:
                load, active = 0.0, dt
            else:
                load, active = af - t0, t_new - af
            if load > 0.0:
                # loading with work already queued = the stall BLITZSCALE's
                # live loading exists to hide
                led.accrue(
                    "stalled_waiting_layers" if (inst.queue or inst.active_reqs)
                    else "loading_params", load * n)
            if active > 0.0:
                a0 = max(t0, af)
                busy = min(max(inst.busy_until - a0, 0.0), active)
                if busy > 0.0:
                    led.accrue("serving_prefill" if inst.phase == "prefill"
                               else "serving_decode", busy * n)
                idle = active - busy
                if idle > 0.0:
                    led.accrue("allocated_idle", idle * n)
        self._last_gpu_t = t_new

    # -- main loop ---------------------------------------------------------------
    def run(self, trace: list[tuple[float, int, int]]) -> SimResult:
        """trace: list of (arrival_s, prompt_tokens, output_tokens)."""
        reqs = [Request(i, t, p, o) for i, (t, p, o) in enumerate(trace)]
        for r in reqs:
            self._reqs[r.rid] = r
            self.push(r.arrival, "arrival", r)
        horizon = max(t for t, _, _ in trace) + 120.0

        if self.sys.autoscale:
            init_p, init_d = 1, 1
        else:
            init_p, init_d = self.sys.fixed_prefill, self.sys.fixed_decode
        for _ in range(init_p):
            devs = self._alloc_devices(self.prof.devices_per_instance)
            if devs:
                self._activate_instance("prefill", devs, 0.0)
        for _ in range(init_d):
            devs = self._alloc_devices(self.prof.devices_per_instance)
            if devs:
                self._activate_instance("decode", devs, 0.0)

        self.push(0.0, "monitor")
        guard = 0
        while self.events and guard < 5_000_000:
            guard += 1
            t, _, kind, payload = heapq.heappop(self.events)
            if t > horizon:
                break
            self._account_gpu(t)
            self.now = t
            if kind == "arrival":
                r: Request = payload
                if self.tracer.enabled and r.rid not in self._req_spans:
                    self._req_spans[r.rid] = self.tracer.begin(
                        "request", r.arrival, cat="request",
                        track=f"req{r.rid % 8}", rid=r.rid,
                        prompt=r.prompt, output=r.output)
                inst = self._best_prefill()
                if inst is None:
                    self.push(self.now + 0.05, "arrival", r)
                    continue
                inst.queue.append(r)
                self._kick_prefill(inst)
            elif kind in ("prefill_round", "kick_prefill"):
                inst = self.instances.get(payload)
                if inst:
                    self._kick_prefill(inst)
            elif kind == "prefill_done":
                iid, rid = payload
                inst = self.instances.get(iid)
                r = self._reqs[rid]
                r.prefill_done = self.now
                if self.tracer.enabled:
                    root = self._req_spans.get(rid)
                    if root is not None and r.ttft is not None:
                        root.attrs["ttft"] = r.ttft
                if self.metrics is not None and r.ttft is not None:
                    self.metrics.histogram("sim.ttft_s").observe(r.ttft)
                if self.slo is not None and r.ttft is not None:
                    self.slo.observe_ttft("sim", self.now, r.ttft)
                if self._kv_net:
                    # the frozen KV pages live on the prefill device; they
                    # reach decode as a real flow, not an instant handoff
                    r.kv_src = inst.device_ids[0] if inst is not None else None
                    self._route_kv(r)
                else:
                    dinst = self._best_decode(r)
                    if dinst is None:
                        self.waiting_decode.append(r)
                    else:
                        was_empty = not dinst.active_reqs
                        dinst.active_reqs[r.rid] = r
                        dinst.kv_tokens += r.prompt + r.output
                        if was_empty:
                            self.push(self.now, "decode_round", dinst.iid)
                if inst:
                    self._kick_prefill(inst)
            elif kind == "kv_landed":
                self._kv_landed(*payload)
            elif kind == "kv_route":
                self._route_kv(self._reqs[payload])
            elif kind == "call":
                payload(self)  # scheduled scenario hook (failures etc.)
                self._schedule_net()  # the hook may have changed flow rates
            elif kind == "kv_failed":
                iid, rid = payload
                r = self._reqs[rid]
                dinst = self.instances.get(iid)
                if dinst is not None and not dinst.retired:
                    dinst.kv_tokens -= r.prompt + r.output  # release the seat
                self._route_kv(r)  # re-target on a surviving instance
            elif kind == "decode_round":
                inst = self.instances.get(payload)
                if inst:
                    self._decode_round(inst)
            elif kind == "net":
                # settle flow completions (their callbacks finalize scale
                # events) and re-arm at the new next completion time
                self.flowsim.advance_to(self.now)
                self._schedule_net()
            elif kind == "scale_done":
                inst = self.instances.get(payload)
                if inst is not None:
                    if inst.phase == "prefill":
                        # steal queued work from overloaded active siblings
                        sib = self._active_instances("prefill")
                        donors = sorted(sib, key=lambda i: -len(i.queue))
                        for d_inst in donors:
                            if d_inst.live_boost is not None:
                                d_inst.live_boost = None  # rebalance step 3
                            while len(d_inst.queue) > len(inst.queue) + 1:
                                inst.queue.append(d_inst.queue.pop())
                        self._kick_prefill(inst)
                    else:
                        self._admit_waiting(inst)
            elif kind == "monitor":
                self._monitor()
                self.timeline.append(
                    (self.now, len(self._live_instances("prefill")),
                     len(self._live_instances("decode"))))
                if self.now < horizon and len(self.done) < len(reqs):
                    self.push(self.now + self.monitor_dt, "monitor")
        self._account_gpu(self.now)
        # unfinished requests / background flows must not leave dangling spans
        self.tracer.close_open(self.now)
        return SimResult(
            system=self.sys.name,
            requests=reqs,
            gpu_time_s=self.ledger.total(),
            device_seconds=self.ledger.breakdown(),
            host_cache_peak_bytes=dict(self.host_cache_peak),
            scale_events=self.scale_events,
            scale_seconds=self.scale_seconds,
            net_scale_bytes=self.net_scale_bytes,
            timeline=self.timeline,
            kv_stream_bytes=self.kv_stream_bytes,
            kv_re_prefills=self.kv_re_prefills,
        )


def run_system(system: SystemConfig, prof: ModelProfile,
               trace: list[tuple[float, int, int]], **kw) -> SimResult:
    return Simulator(system, prof, **kw).run(trace)
