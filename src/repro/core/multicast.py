"""Online interference-free multicast scale-plan generation (paper §5.1,
Algorithm 11, Figs. 12-14).

Key ideas implemented here:

  * **Serial forwarding chains** ``S -> T1 -> ... -> Tn``: pipelined
    layer-by-layer forwarding makes total transfer time ``|M| / B``
    *independent of the number of receivers* (Fig. 13a) — this is why the
    data plane needs no per-host caching.
  * **Scale-up grouping**: devices in one NVLink/ICI domain collapse into a
    single chain *node*; intra-node distribution is near-free.
  * **Interference-freedom via full-duplex links** (Fig. 7c/d): a device
    whose egress already carries serving traffic (a prefill instance
    streaming KVCache out) is pruned from the source set; reading from a
    *decode* instance instead puts the parameter flow on the opposite link
    direction.
  * **Multi-chain** (Fig. 12): one chain per leaf when every leaf has both
    sources and targets — avoids slow inter-leaf hops and lets more chain
    tails live-scale without interference.
  * **Fastest-first node order** (Fig. 13b): targets with higher aggregate
    bandwidth go earlier in the chain so serving throughput rises sooner.
  * **Parallel sharded transfer** (Fig. 14): when consecutive chain nodes
    have ``g`` devices each holding/awaiting the full parameters, each source
    device ships ``1/g`` of the bytes and the target scale-up domain
    AllGathers — a ``g x`` speedup.
  * **Latency-aware ranking** (post-Fig. 13a realism): when the caller
    passes the data plane's latency view (``net=`` a ``FlowSim`` or
    ``NetworkModel``), chain cost is no longer bandwidth-only — hop ``k``
    of a pipelined chain cannot deliver byte 0 before ``k`` store-and-
    forward stages have elapsed, so a target's projected arrival is
    ``max over chain prefix j of (cum_latency_j + |M|/BW_j)``.  Source
    selection, fastest-first target ordering and multi-chain splitting all
    re-rank on that cost, so deep serial chains lose to wider/shallower
    plans when switching delay dominates and the analytic
    ``transfer_seconds`` matches the FlowSim-realized completion.  A
    zero-latency network plans bit-for-bit like the bandwidth-only
    planner (golden-trace pinned).

The planner is greedy and runs in ``O(S log S + T log T)`` bandwidth-only
and ``O(S * T)`` latency-aware — the paper's answer to NP-hard optimal
multicast on heterogeneous networks.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Iterable, Protocol, Sequence

from repro.core.topology import (
    NVLINK_GBPS,
    Device,
    Role,
    Topology,
    gbps_to_bytes_per_s,
)


class LatencyView(Protocol):
    """What the planner needs from the data plane: per-hop first-byte
    latency.  Both ``repro.net.FlowSim`` and ``repro.net.NetworkModel``
    satisfy it; tests may pass any duck-typed stand-in."""

    def hop_latency(self, src: int, dst: int) -> float: ...  # pragma: no cover


# ---------------------------------------------------------------------------
# Plan data structures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Node:
    """One chain node = all devices of a scale-up domain participating."""

    device_ids: tuple[int, ...]
    scaleup: int
    leaf: int
    agg_bw_gbps: float  # sum of members' scale-out link bandwidth
    is_source: bool = False
    is_host: bool = False

    @property
    def size(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass(frozen=True)
class Edge:
    src: Node
    dst: Node
    bw_gbps: float  # effective bandwidth of this hop (after Fig.14 sharding)
    sharded_ways: int  # Fig. 14 parallelism factor
    intra_scaleup: bool = False  # NVLink/ICI hop — uses no scale-out link
    latency_s: float = 0.0  # first-byte (link + switch) latency of this hop,
    #   as the data plane charges it (0.0 when planned bandwidth-only)


@dataclasses.dataclass
class Chain:
    nodes: list[Node]  # nodes[0] is the source
    edges: list[Edge]

    @property
    def targets(self) -> list[Node]:
        return self.nodes[1:]

    @property
    def is_degenerate(self) -> bool:
        """A source-only chain (no edges) moves no bytes: it has no
        bottleneck to rank on and zero transfer time.  Callers ranking or
        dividing by chain speed must branch on this explicitly — a
        degenerate chain covers no target and must not win any ranking."""
        return not self.edges

    @property
    def bottleneck_gbps(self) -> float:
        """Slowest hop of the chain.  ``inf`` for a degenerate (edge-less)
        chain by convention — check :attr:`is_degenerate` before using this
        in a ranking or as a divisor."""
        if self.is_degenerate:
            return math.inf
        return min(e.bw_gbps for e in self.edges)

    @property
    def latency_seconds(self) -> float:
        """Total store-and-forward first-byte latency along the chain."""
        return sum(e.latency_s for e in self.edges)

    def transfer_seconds(self, model_bytes: int) -> float:
        """Pipelined chain completion under the latency model: hop ``k``'s
        last byte lands at ``cum_latency_k + |M| / BW_k`` (its first byte
        waits for every upstream store-and-forward stage), so the chain
        completes at the max over hops.  At zero latency this reduces to
        the Fig. 13a ``|M| / bottleneck_BW`` exactly — independent of chain
        length; with uniform hop bandwidth it is the closed form
        ``|M|/bottleneck_BW + sum(per-hop latency)``.  Degenerate (edge-
        less) chains explicitly take zero time — they move no bytes."""
        if self.is_degenerate:
            return 0.0
        done, cum_lat = 0.0, 0.0
        for e in self.edges:
            cum_lat += e.latency_s
            done = max(done, cum_lat + model_bytes / gbps_to_bytes_per_s(e.bw_gbps))
        return done

    @property
    def tail(self) -> Node:
        return self.nodes[-1]


@dataclasses.dataclass
class MulticastPlan:
    chains: list[Chain]
    covered: list[int]  # target device ids covered, in arrival order
    gen_seconds: float  # plan-generation wall time (paper: < 40 ms)
    pruned_sources: list[int]  # sources dropped by interference pruning

    def transfer_seconds(self, model_bytes: int) -> float:
        return max((c.transfer_seconds(model_bytes) for c in self.chains), default=0.0)

    @property
    def live_scale_nodes(self) -> list[Node]:
        """Chain tails: their egress carries no forwarding traffic, so they
        can join live scaling without interference (Fig. 12)."""
        return [c.tail for c in self.chains if c.edges]

    def all_edges(self) -> list[Edge]:
        return [e for c in self.chains for e in c.edges]


# ---------------------------------------------------------------------------
# Algorithm 11
# ---------------------------------------------------------------------------


def _group_nodes(
    topo: Topology, ids: Sequence[int], *, is_source: bool
) -> list[Node]:
    """Group device ids by scale-up domain into chain nodes."""
    groups: dict[int, list[int]] = {}
    for i in ids:
        groups.setdefault(topo.scaleup_of(i), []).append(i)
    nodes = []
    for su, members in groups.items():
        d0 = topo.device(members[0])
        nodes.append(
            Node(
                device_ids=tuple(sorted(members)),
                scaleup=su,
                leaf=d0.leaf,
                agg_bw_gbps=sum(topo.bw(i) for i in members),
                is_source=is_source,
                is_host=d0.is_host,
            )
        )
    return nodes


def _prune_sources(topo: Topology, src_ids: Sequence[int]) -> tuple[list[int], list[int]]:
    """Line 1 ``prune()``: drop sources whose *egress* direction already
    carries serving traffic (Fig. 7b interference).  Decode instances keep —
    their egress is free (KVCache flows in); prefill instances drop."""
    kept, pruned = [], []
    for i in src_ids:
        if topo.device(i).egress_busy:
            pruned.append(i)
        else:
            kept.append(i)
    return kept, pruned


def _hop_stats(
    net: LatencyView | None, src: Node, dst: Node
) -> tuple[float, float, int, bool]:
    """(latency_s, effective_bw_gbps, sharded_ways, intra_scaleup) of the
    hop ``src -> dst`` — the same Fig. 14 arithmetic the selection loop
    applies, plus the data plane's per-hop latency (max across the sharded
    sibling pairs, exactly as ``MulticastExecution`` charges it)."""
    ways = min(src.size, dst.size)
    intra = src.scaleup == dst.scaleup and not src.is_host
    if intra:
        eff_bw = NVLINK_GBPS * ways
    else:
        eff_bw = min(src.agg_bw_gbps / src.size, dst.agg_bw_gbps / dst.size) * ways
    lat = 0.0
    if net is not None:
        lat = max(
            (
                net.hop_latency(s, d)
                for s, d in zip(src.device_ids[:ways], dst.device_ids[:ways])
            ),
            default=0.0,
        )
    return lat, eff_bw, ways, intra


def plan_multicast(
    topo: Topology,
    src_ids: Sequence[int],
    tgt_ids: Sequence[int],
    n: int,
    *,
    allow_interference: bool = False,
    net: LatencyView | None = None,
    model_bytes: int | None = None,
) -> MulticastPlan:
    """Generate the scale plan: load parameters from ``src_ids`` onto ``n``
    devices drawn from ``tgt_ids`` (Algorithm 11).

    ``allow_interference=True`` disables Line-1 pruning — the ablation
    baseline showing 1.5x slower scaling / 50% worse tail TBT (Fig. 8).

    ``net`` is the data plane's latency view (a ``repro.net.FlowSim`` or
    ``NetworkModel``; anything with ``hop_latency(src, dst)``).  When it
    carries any latency, source selection and target ordering rank on
    projected arrival time — ``max over chain prefix of (cumulative hop
    latency + |M|/hop_BW)`` — instead of bandwidth alone; pass
    ``model_bytes`` so the bandwidth term is weighed correctly (omitting it
    makes the ranking latency-dominated).  A zero-latency ``net`` (or
    ``net=None``) reproduces the bandwidth-only plan bit-for-bit.
    """
    t0 = time.perf_counter()
    lat_aware = net is not None and getattr(net, "has_latency", True)
    mbytes = float(model_bytes) if model_bytes else 0.0

    # Line 1: prune + group sources by leaf, fastest leaf first
    if allow_interference:
        kept_src, pruned = list(src_ids), []
    else:
        kept_src, pruned = _prune_sources(topo, src_ids)
        if not kept_src:
            # all in-GPU sources interfere -> seed the chain from the O(1)
            # host-cached copy instead (the paper's fallback; PCIe egress of
            # a host carries no serving traffic)
            hosts = [d.id for d in topo.devices if d.is_host]
            if hosts:
                kept_src = hosts[:1]
            elif src_ids:  # degraded cluster with no host tier: last resort
                kept_src, pruned = list(src_ids), []

    src_nodes = _group_nodes(topo, kept_src, is_source=True)
    by_leaf: dict[int, list[Node]] = {}
    for nd in src_nodes:
        by_leaf.setdefault(nd.leaf, []).append(nd)
    leaf_order = sorted(
        by_leaf, key=lambda lf: -sum(nd.agg_bw_gbps for nd in by_leaf[lf])
    )
    src_queue: list[Node] = []
    for lf in leaf_order:
        src_queue.extend(sorted(by_leaf[lf], key=lambda nd: -nd.agg_bw_gbps))

    # Line 2-3: group targets by scale-up domain, order groups (a) by the
    # leaf order of the sources (intra-leaf chains first) then (b) by
    # decreasing aggregate bandwidth (Fig. 13b fastest-first) — or, when
    # latency-aware, by the projected first-hop arrival from the initial
    # source set, so a high-bandwidth target behind a slow link no longer
    # jumps the queue.
    tgt_nodes = _group_nodes(topo, list(tgt_ids), is_source=False)
    src_leaf_rank = {lf: r for r, lf in enumerate(leaf_order)}
    if lat_aware and src_queue:
        init_srcs = list(src_queue)

        def _tgt_eta(nd: Node) -> float:
            best = math.inf
            for s in init_srcs:
                lat, eff_bw, _, _ = _hop_stats(net, s, nd)
                best = min(best, lat + mbytes / gbps_to_bytes_per_s(eff_bw))
            return best

        tgt_nodes.sort(
            key=lambda nd: (
                src_leaf_rank.get(nd.leaf, 1 << 30),
                _tgt_eta(nd),
                -nd.agg_bw_gbps,
            )
        )
    else:
        tgt_nodes.sort(
            key=lambda nd: (src_leaf_rank.get(nd.leaf, 1 << 30), -nd.agg_bw_gbps)
        )

    # Lines 4-10: pop target groups; prefer same-leaf sources with enough
    # aggregate bandwidth (or, latency-aware, whichever source yields the
    # earliest projected arrival — which is what splits deep chains into
    # wider plans when switching delay dominates); freshly scaled targets
    # become sources (chains).
    chains: list[Chain] = []
    chain_of: dict[int, Chain] = {}  # scaleup id of last node -> its chain
    covered: list[int] = []
    m = 0
    # latency-aware chain state, keyed by id() of the live queue-node object
    # (nodes stay referenced by the queue/chains, so ids are stable):
    # cumulative store-and-forward latency at the node, and the node's own
    # projected arrival (original sources: 0.0 — they hold the parameters)
    cum_lat: dict[int, float] = {}
    arrive: dict[int, float] = {}

    for g_tgt in tgt_nodes:
        if m >= n:
            break
        take = g_tgt
        if m + g_tgt.size > n:
            keep = n - m
            take = dataclasses.replace(
                g_tgt,
                device_ids=g_tgt.device_ids[:keep],
                agg_bw_gbps=sum(topo.bw(i) for i in g_tgt.device_ids[:keep]),
            )

        pick: Node | None = None
        if lat_aware:
            if not src_queue:
                break  # no sources at all — caller must register a host copy

            def _cost(s: Node) -> tuple[float, float]:
                lat, eff_bw, _, _ = _hop_stats(net, s, take)
                cum = cum_lat.get(id(s), 0.0) + lat
                eta = max(
                    arrive.get(id(s), 0.0),
                    cum + mbytes / gbps_to_bytes_per_s(eff_bw),
                )
                return (eta, -s.agg_bw_gbps)

            pick = min(src_queue, key=_cost)
        else:
            # Scale-up shortcut: a source inside the *same* NVLink/ICI
            # domain covers the target at scale-up speed (near-free — §5.1)
            same_su = [
                s for s in src_queue if s.scaleup == take.scaleup and not s.is_host
            ]
            # Line 6-7: source selection — same leaf first
            same_leaf = [s for s in src_queue if s.leaf == take.leaf]
            if same_su:
                pick = max(same_su, key=lambda s: s.agg_bw_gbps)
            elif same_leaf and sum(s.agg_bw_gbps for s in same_leaf) >= take.agg_bw_gbps:
                pick = max(same_leaf, key=lambda s: s.agg_bw_gbps)
            elif src_queue:
                pick = max(src_queue, key=lambda s: s.agg_bw_gbps)
            if pick is None:
                break  # no sources at all — caller must register a host copy

        # Fig. 14: parallel sharded transfer when both endpoints have g
        # devices with (to-be-)duplicated parameters
        hop_lat, eff_bw, ways, intra_scaleup = _hop_stats(
            net if lat_aware else None, pick, take
        )
        edge = Edge(src=pick, dst=take, bw_gbps=eff_bw, sharded_ways=ways,
                    intra_scaleup=intra_scaleup, latency_s=hop_lat)

        # the picked node's scale-out egress now carries this chain's
        # forwarding traffic — it must not head a second chain (full-duplex
        # rule: one egress flow per link).  Intra-scale-up hops don't use
        # the scale-out link, so the source stays available.
        if not intra_scaleup:
            src_queue = [s for s in src_queue if s is not pick]

        if pick.scaleup in chain_of and not pick.is_source:
            ch = chain_of.pop(pick.scaleup)
            ch.nodes.append(take)
            ch.edges.append(edge)
        else:
            ch = Chain(nodes=[pick, take], edges=[edge])
            chains.append(ch)
            if pick.scaleup in chain_of:
                chain_of.pop(pick.scaleup, None)
        chain_of[take.scaleup] = ch

        # Line 10: the freshly scaled group becomes a source for what follows
        fresh = dataclasses.replace(take, is_source=False)
        src_queue.insert(0, fresh)
        if lat_aware:
            cum_lat[id(fresh)] = cum_lat.get(id(pick), 0.0) + hop_lat
            arrive[id(fresh)] = max(
                arrive.get(id(pick), 0.0),
                cum_lat[id(fresh)] + mbytes / gbps_to_bytes_per_s(eff_bw),
            )
        covered.extend(take.device_ids)
        m += take.size

    return MulticastPlan(
        chains=chains,
        covered=covered,
        gen_seconds=time.perf_counter() - t0,
        pruned_sources=pruned,
    )


# ---------------------------------------------------------------------------
# Validation (used by property tests and the simulator's safety checks)
# ---------------------------------------------------------------------------


def validate_plan(topo: Topology, plan: MulticastPlan) -> list[str]:
    """Returns a list of violations (empty = plan is sound)."""
    errors: list[str] = []

    # every covered target appears exactly once
    if len(set(plan.covered)) != len(plan.covered):
        errors.append("target covered more than once")

    # per-device flow direction accounting: egress used by at most one
    # multicast flow AND not by serving traffic (full-duplex rule)
    egress_used: dict[int, int] = {}
    ingress_used: dict[int, int] = {}
    for e in plan.all_edges():
        if e.intra_scaleup:
            continue  # NVLink/ICI hop — no scale-out link involved
        # a sharded edge can never span more device pairs than its smaller
        # endpoint: a larger sharded_ways would silently truncate in the
        # slices below and under-count link usage, so flag AND clamp it
        # (the accounting stays sound on whatever pairs actually transfer)
        ways = min(len(e.src.device_ids), len(e.dst.device_ids))
        if e.sharded_ways > ways:
            errors.append(
                f"edge {e.src.device_ids}->{e.dst.device_ids}: sharded_ways "
                f"{e.sharded_ways} exceeds endpoint size {ways}"
            )
        else:
            ways = e.sharded_ways
        for i in e.src.device_ids[:ways]:
            egress_used[i] = egress_used.get(i, 0) + 1
        for i in e.dst.device_ids[:ways]:
            ingress_used[i] = ingress_used.get(i, 0) + 1

    for i, cnt in egress_used.items():
        if cnt > 1:
            errors.append(f"device {i}: {cnt} same-direction egress flows")
        d = topo.device(i)
        if d.egress_busy:
            errors.append(f"device {i}: multicast egress collides with serving egress")
    for i, cnt in ingress_used.items():
        if cnt > 1:
            errors.append(f"device {i}: {cnt} same-direction ingress flows")
        d = topo.device(i)
        if d.ingress_busy and not d.is_host:
            errors.append(f"device {i}: multicast ingress collides with serving ingress")
    return errors


def chain_time_model(
    model_bytes: int,
    chain_bw_gbps: float,
    n_targets: int,
    *,
    pipelined: bool = True,
    total_latency_s: float = 0.0,
) -> float:
    """Fig. 13a analytic model with the latency term: pipelined chain time
    is ~|M|/B + the chain's total store-and-forward first-byte latency
    (``Chain.latency_seconds``), regardless of n; unpipelined
    (store-and-forward of the whole model) is n*|M|/B + the same latency.
    ``total_latency_s=0`` is the original pure-bandwidth model."""
    base = model_bytes / gbps_to_bytes_per_s(chain_bw_gbps)
    return (base if pipelined else base * max(n_targets, 1)) + total_latency_s
