"""Global parameter pool — O(1) host caching (paper §5.3).

Tracks, for every model served by the MAAS, where its parameters live:

  * GPU copies — devices behind deployed serving instances (preferred
    multicast sources: reading from them needs *zero* host cache), and
  * exactly ONE host-DRAM copy cluster-wide (the O(1) invariant), placed
    evenly across hosts at registration so the aggregated host memory of the
    cluster suffices for *all* models (vs. ServerlessLLM caching each model
    on every host it ever touched).

Fault tolerance (paper App. A.1): when a host fails, models whose single
cached copy lived there are re-replicated from any surviving GPU copy (or,
if none, flagged for re-upload from blob storage); the invariant
``copies(model) >= 1`` is restored before the failure handler returns.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable

from repro.core.topology import Device, Role, Topology


class NoAliveHostError(RuntimeError):
    """Raised when a registration needs a host-cache slot but every host in
    the cluster is marked failed — the model cannot satisfy the >=1-copy
    invariant until a host recovers (or the model is re-uploaded from blob
    storage onto a repaired host)."""


@dataclasses.dataclass
class ModelRecord:
    name: str
    size_bytes: int
    host_copy: int | None  # host id of the single cached copy (None = lost!)
    gpu_devices: set[int] = dataclasses.field(default_factory=set)


class ParameterPool:
    """Centralized manager mapping model -> parameter locations."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self.models: dict[str, ModelRecord] = {}
        self._rr = itertools.count()  # round-robin host placement
        self._hosts = sorted({d.host for d in topo.devices})
        self._failed_hosts: set[int] = set()

    # -- registration -------------------------------------------------------
    def register(self, name: str, size_bytes: int) -> None:
        """Distribute the single host copy evenly across hosts (§5.3)."""
        if name in self.models:
            return
        alive = [h for h in self._hosts if h not in self._failed_hosts]
        if not alive:
            raise NoAliveHostError(
                f"cannot register {name!r}: every host is failed — recover a "
                "host (recover_host) before registering new models"
            )
        host = alive[next(self._rr) % len(alive)]
        self.models[name] = ModelRecord(name, size_bytes, host_copy=host)

    # -- deployment tracking --------------------------------------------------
    def deploy(self, name: str, device_ids: Iterable[int]) -> None:
        rec = self.models[name]
        for i in device_ids:
            rec.gpu_devices.add(i)
            self.topo.device(i).model = name

    def reclaim(self, name: str, device_ids: Iterable[int]) -> None:
        rec = self.models[name]
        for i in device_ids:
            rec.gpu_devices.discard(i)
            d = self.topo.device(i)
            if d.model == name:
                d.model = None
                d.role = Role.FREE

    # -- scale-to-zero / teardown (MaaS control plane) -----------------------
    def deactivate(self, name: str) -> list[int]:
        """Scale-to-zero: drop every GPU copy, keeping ONLY the single host
        copy (the O(1) floor a parked model occupies).  Returns the freed
        device ids."""
        devs = sorted(self.models[name].gpu_devices)
        self.reclaim(name, devs)
        return devs

    def evict(self, name: str) -> None:
        """Remove the model from the MAAS entirely — GPU copies reclaimed and
        the host-cache slot released (next use needs a blob-storage re-upload
        + fresh ``register``)."""
        rec = self.models.pop(name, None)
        if rec is None:
            return
        for i in sorted(rec.gpu_devices):
            d = self.topo.device(i)
            if d.model == name:
                d.model = None
                d.role = Role.FREE

    # -- source query (consulted by the scale planner, Fig. 6 step 3) --------
    def sources(self, name: str) -> tuple[list[int], int | None]:
        """Returns (gpu_device_ids, host_id_of_cached_copy)."""
        rec = self.models[name]
        live = {i for i in rec.gpu_devices if self.topo.device(i).host not in self._failed_hosts}
        host = rec.host_copy if rec.host_copy not in self._failed_hosts else None
        return sorted(live), host

    def n_copies(self, name: str) -> int:
        gpus, host = self.sources(name)
        return len(gpus) + (1 if host is not None else 0)

    # -- O(1) metric (paper Fig. 19) -----------------------------------------
    def host_cache_bytes(self) -> dict[int, int]:
        """Bytes of parameter cache held per host — the paper's Fig. 19
        metric.  By construction each model contributes to exactly one host."""
        usage: dict[int, int] = {h: 0 for h in self._hosts}
        for rec in self.models.values():
            if rec.host_copy is not None and rec.host_copy not in self._failed_hosts:
                usage[rec.host_copy] += rec.size_bytes
        return usage

    # -- fault tolerance -------------------------------------------------------
    def fail_host(self, host: int) -> list[str]:
        """Mark a host failed; restore the >=1-copy invariant for every model
        whose cached copy it held.  Returns models that had to be re-homed."""
        self._failed_hosts.add(host)
        rehomed = []
        alive = [h for h in self._hosts if h not in self._failed_hosts]
        for rec in self.models.values():
            rec.gpu_devices = {
                i for i in rec.gpu_devices if self.topo.device(i).host != host
            }
            if rec.host_copy == host:
                # re-replicate from a surviving GPU copy if any, else re-home
                # (in the real system the bytes move over the compute network
                # — the same multicast data plane; here we track placement)
                rec.host_copy = alive[next(self._rr) % len(alive)] if alive else None
                rehomed.append(rec.name)
        return rehomed

    def recover_host(self, host: int) -> None:
        self._failed_hosts.discard(host)

    def invariant_ok(self) -> bool:
        return all(self.n_copies(m) >= 1 for m in self.models)
