"""Load monitoring + scaling policy (paper §5.3-5.4).

The paper's policy (kept deliberately simple — mechanism, not policy, is the
contribution):

  * monitor per-model serving load: tokens/s against profiled per-instance
    capacity, and KVCache occupancy against instance memory;
  * scale UP when the monitored load exceeds an upper bound — allocate
    enough instances to absorb the surplus;
  * scale DOWN with a (sub-second, thanks to fast scaling) timeout when the
    load stays under a lower bound;
  * PD-disaggregation special case (§5.4): *decode pre-scaling* — a surge in
    prefill demand forecasts a decode surge one generation later, so decode
    instances scale simultaneously with prefill at effectively zero extra
    latency cost (applied to all baselines in the evaluation, like the
    paper does);
  * live-scaling a decode instance directly would incast-collide with
    KVCache migration, so decode scale-ups prefer *mutating* a prefill
    instance (same parameters!) into a decode instance while live-scaling a
    replacement prefill.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable


@dataclasses.dataclass
class PolicyConfig:
    upper_util: float = 0.85  # scale up when load/capacity exceeds this
    lower_util: float = 0.30  # scale down below this ...
    scale_down_timeout_s: float = 0.8  # ... for this long (sub-second, §5.3)
    monitor_window_s: float = 1.0
    kv_upper: float = 0.90  # decode KV occupancy scale-up bound
    decode_prescale: bool = True  # §5.4 optimized policy
    max_instances: int = 64


@dataclasses.dataclass
class LoadSample:
    t: float
    tokens_per_s: float
    kv_used_frac: float
    queue_depth: int


class LoadMonitor:
    """Sliding-window load tracker for one model service + phase."""

    def __init__(self, window_s: float = 1.0):
        self.window_s = window_s
        self.samples: deque[LoadSample] = deque()

    def record(self, s: LoadSample) -> None:
        self.samples.append(s)
        while self.samples and self.samples[0].t < s.t - self.window_s:
            self.samples.popleft()

    def avg_tokens_per_s(self) -> float:
        if not self.samples:
            return 0.0
        return sum(s.tokens_per_s for s in self.samples) / len(self.samples)

    def avg_kv_frac(self) -> float:
        if not self.samples:
            return 0.0
        return sum(s.kv_used_frac for s in self.samples) / len(self.samples)

    def max_queue(self) -> int:
        return max((s.queue_depth for s in self.samples), default=0)


@dataclasses.dataclass
class ScaleDecision:
    prefill_delta: int = 0  # +n scale up, -n scale down
    decode_delta: int = 0
    prescaled: bool = False  # decode_delta came from the §5.4 forecast
    reason: str = ""


class Autoscaler:
    """Upper/lower-bound policy with decode pre-scaling."""

    def __init__(
        self,
        policy: PolicyConfig,
        *,
        prefill_capacity_tps: float,  # profiled per-instance tokens/s
        decode_capacity_tps: float,
    ):
        self.policy = policy
        self.pre_cap = prefill_capacity_tps
        self.dec_cap = decode_capacity_tps
        self.prefill_mon = LoadMonitor(policy.monitor_window_s)
        self.decode_mon = LoadMonitor(policy.monitor_window_s)
        self._below_since: dict[str, float | None] = {"prefill": None, "decode": None}

    # ------------------------------------------------------------------
    def phase_pressures(self, n_prefill: int, n_decode: int) -> tuple[float, float]:
        """Per-phase SLO pressure given the current instance counts.

        Dimensionless: 1.0 means the phase's monitored load (tokens/s, or KV
        occupancy for decode) sits exactly at its scale-up bound; >1 means
        under-provisioned *right now*; inf means offered load with zero
        capacity (the fleet treats that as a cold-start request)."""
        p = self.policy
        pre_cap = n_prefill * self.pre_cap * p.upper_util
        dec_cap = n_decode * self.dec_cap * p.upper_util
        pre_load = self.prefill_mon.avg_tokens_per_s()
        dec_load = self.decode_mon.avg_tokens_per_s()
        pre = pre_load / pre_cap if pre_cap > 0 else (float("inf") if pre_load > 0 else 0.0)
        dec = dec_load / dec_cap if dec_cap > 0 else (float("inf") if dec_load > 0 else 0.0)
        kv = self.decode_mon.avg_kv_frac() / p.kv_upper
        return pre, max(dec, kv)

    def slo_pressure(self, n_prefill: int, n_decode: int) -> float:
        """How close this model is to violating its SLO — the fleet
        arbitration signal (MaaS control plane): max over phase pressures."""
        return max(self.phase_pressures(n_prefill, n_decode))

    # ------------------------------------------------------------------
    def decide(
        self, now: float, n_prefill: int, n_decode: int
    ) -> ScaleDecision:
        p = self.policy
        d = ScaleDecision()

        # ---- prefill scale-up: load-based
        load = self.prefill_mon.avg_tokens_per_s()
        cap = max(n_prefill, 1) * self.pre_cap
        if load > p.upper_util * cap and n_prefill < p.max_instances:
            need = int(-(-load // (p.upper_util * self.pre_cap)))  # ceil
            d.prefill_delta = min(need - n_prefill, p.max_instances - n_prefill)
            d.prefill_delta = max(d.prefill_delta, 1)
            d.reason = f"prefill load {load:.0f} > {p.upper_util:.0%} of {cap:.0f}"
            # §5.4 decode pre-scaling: prefill surge forecasts decode surge
            if p.decode_prescale and n_decode < p.max_instances:
                dec_load = self.decode_mon.avg_tokens_per_s()
                dec_need = int(-(-(dec_load + load) // (p.upper_util * self.dec_cap)))
                if dec_need > n_decode:
                    d.decode_delta = min(dec_need - n_decode, p.max_instances - n_decode)
                    d.prescaled = True

        # ---- decode scale-up: load- or KV-pressure based
        kv = self.decode_mon.avg_kv_frac()
        dec_load = self.decode_mon.avg_tokens_per_s()
        dec_cap = max(n_decode, 1) * self.dec_cap
        if d.decode_delta == 0 and n_decode < p.max_instances:
            if dec_load > p.upper_util * dec_cap:
                dec_need = int(-(-dec_load // (p.upper_util * self.dec_cap)))  # ceil
                d.decode_delta = min(
                    max(dec_need - n_decode, 1), p.max_instances - n_decode
                )
                d.reason = d.reason or (
                    f"decode load {dec_load:.0f} > {p.upper_util:.0%} of {dec_cap:.0f}"
                )
            elif kv > p.kv_upper:
                d.decode_delta = 1
                d.reason = d.reason or f"decode KV {kv:.0%} > {p.kv_upper:.0%}"

        # ---- scale-down: timeout below lower bound
        for phase, mon, n_cur, cap_one in (
            ("prefill", self.prefill_mon, n_prefill, self.pre_cap),
            ("decode", self.decode_mon, n_decode, self.dec_cap),
        ):
            if n_cur <= 1:
                self._below_since[phase] = None
                continue
            low = mon.avg_tokens_per_s() < p.lower_util * n_cur * cap_one
            kv_ok = phase != "decode" or mon.avg_kv_frac() < p.lower_util
            if low and kv_ok:
                if self._below_since[phase] is None:
                    self._below_since[phase] = now
                elif now - self._below_since[phase] >= p.scale_down_timeout_s:
                    delta = -1
                    if phase == "prefill" and d.prefill_delta == 0:
                        d.prefill_delta = delta
                    elif phase == "decode" and d.decode_delta == 0:
                        d.decode_delta = delta
                    self._below_since[phase] = now
            else:
                self._below_since[phase] = None
        return d
