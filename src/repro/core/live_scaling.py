"""Live autoscaling: the cooperative execution protocol (paper §4, §5.2).

The scaling abstraction is broken from instance-level to *layer-level*:
while a scaling instance (the *target*) is still receiving parameters, it
executes the first ``k`` loaded layers of every request and forwards the
activation to the overloaded *source* instance, which finishes layers
``k..L``.  The pair's throughput rises from 1/L to 1/max(k, L-k) per
layer-time — 2x once half the layers have landed — so queued requests drain
*during* the transfer instead of after it.

Three-step transition protocol (paper Fig. 9d + §5.2):
  1. REDIRECT   — as loading starts, all queued + new requests are
                  redirected to the target's priority queue (cheap: request
                  payloads are tiny vs. parameters);
  2. COOPERATIVE— target executes loaded layers (ZigZag order), source pulls
                  and completes; throughput ramps with loaded layers;
  3. REBALANCE  — once all L layers landed, requests are split evenly and
                  both run as normal full instances.

``cooperative_forward`` is the *jittable* data-plane primitive: it computes
the exact same function as a monolithic forward (property-tested) while
splitting layer execution at a traced boundary ``k`` — i.e. per-``k``
recompilation is not needed when ``k`` advances during loading.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.zigzag import live_throughput_multiplier
from repro.models import layers as L
from repro.models import transformer as TF
from repro.models.config import ModelConfig


class Phase(enum.Enum):
    REDIRECT = "redirect"
    COOPERATIVE = "cooperative"
    REBALANCED = "rebalanced"


@dataclasses.dataclass
class LiveSession:
    """Host-side state machine coordinating one (source, target) pair.

    Progress is either constant-rate (``link_bytes_per_s``, the planner's
    dedicated-link estimate) or — when ``progress_bytes`` is set — read from
    the flow-level network simulator, so layer arrival reflects whatever
    contention the parameter stream actually experienced.  Callers using
    ``progress_bytes`` must advance their FlowSim to ``now`` before asking.
    """

    n_layers: int
    layer_bytes: int
    link_bytes_per_s: float
    started_at: float
    phase: Phase = Phase.REDIRECT
    # realized bytes delivered to the target (e.g. a FlowSim flow's
    # ``transferred``); overrides the constant-rate model when provided
    progress_bytes: Callable[[], float] | None = None

    def layers_loaded(self, now: float) -> int:
        if self.progress_bytes is not None:
            if self.layer_bytes <= 0:
                return self.n_layers
            return min(self.n_layers, int(self.progress_bytes() / self.layer_bytes))
        if self.link_bytes_per_s <= 0:
            return self.n_layers
        dt = max(0.0, now - self.started_at)
        return min(self.n_layers, int(dt * self.link_bytes_per_s / self.layer_bytes))

    def throughput_multiplier(self, now: float) -> float:
        k = self.layers_loaded(now)
        if k >= self.n_layers:
            self.phase = Phase.REBALANCED
            return 2.0
        if k >= 1 and self.phase is Phase.REDIRECT:
            self.phase = Phase.COOPERATIVE
        return live_throughput_multiplier(k, self.n_layers)

    def done_at(self) -> float:
        return self.started_at + self.n_layers * self.layer_bytes / self.link_bytes_per_s


# ---------------------------------------------------------------------------
# Jittable cooperative forward (layer-split execution)
# ---------------------------------------------------------------------------


def cooperative_forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    k: jax.Array | int,  # layers loaded on the target (traced)
    frames: jax.Array | None = None,
) -> jax.Array:
    """Target executes layers [0, k), source executes [k, L); returns logits.

    In the real deployment the two ranges run on different instances with an
    activation transfer between them; numerically the composition must equal
    the monolithic forward — that equality is the correctness contract
    (tested in tests/test_live_scaling.py).
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = TF._embed(cfg, params, tokens, frames)
    shared = params.get("shared")
    # ---- target side: layers [0, k)
    x = TF.forward_layers_range(cfg, params["layers"], x, 0, k, positions, shared)
    # (activation crosses the network here)
    # ---- source side: layers [k, L)
    x = TF.forward_layers_range(
        cfg, params["layers"], x, k, cfg.n_layers, positions, shared
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg)


def select_live_pairs(
    plan,  # MulticastPlan
    overloaded: list[int],  # device ids of overloaded instances
    *,
    slo_requires_live: bool = True,
) -> list[tuple[int, int]]:
    """§5.2 'Selecting instances for live scaling': pair each overloaded
    instance with a chain-tail node (slowest link, free egress — Fig. 12).
    Returns (source_device, target_device) pairs."""
    if not slo_requires_live:
        return []
    tails = [n.device_ids[0] for n in plan.live_scale_nodes]
    return list(zip(overloaded, tails))
