"""TPU-native multicast data plane (hardware adaptation of §5.1).

The paper's chain multicast is point-to-point NCCL send/recv.  On TPU the
native neighbour-forwarding primitive is ``jax.lax.ppermute`` inside
``shard_map``; a serial forwarding chain becomes a *pipelined systolic
broadcast*: the source rank injects parameter block ``b`` at step ``b``, and
every step each rank forwards the block it holds to its chain successor.
After ``n_blocks + n_ranks - 2`` steps every rank holds all blocks — the
exact Fig. 13(a) pipelining argument (total time ~ |M|/B, independent of the
receiver count), expressed as a ``lax.scan`` over steps.

Fig. 14's parallel sharded transfer maps to: each of the ``g`` source
devices ships a distinct 1/g parameter shard to its peer (one ppermute),
then the target scale-up domain runs ``lax.all_gather`` over its ICI axis.

Both are validated numerically on 8 host devices in
``tests/test_collectives.py`` (subprocess with
``--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Pipelined chain broadcast (serial forwarding multicast, Fig. 13a)
# ---------------------------------------------------------------------------


def chain_broadcast_blocks(
    blocks: jax.Array,  # (n_blocks, block_elems) — valid on rank `src` only
    axis_name: str,
    n_ranks: int,
    src: int = 0,
) -> jax.Array:
    """Inside shard_map: systolic pipelined broadcast along a rank chain.

    Rank order is ``src, src+1, ..., n_ranks-1`` (the planner emits device
    orderings; callers renumber).  Per step each rank forwards its held
    block to its successor while the source injects the next block — hop
    ``h`` of block ``b`` overlaps hop ``h-1`` of block ``b+1``.
    """
    n_blocks = blocks.shape[0]
    rank = jax.lax.axis_index(axis_name)
    chain_pos = rank - src  # position along the chain (0 = source)
    n_steps = n_blocks + n_ranks - 2
    perm = [(i, i + 1) for i in range(n_ranks - 1)]

    def step(carry, s):
        held, out = carry
        # the source injects block s (clamped); everyone else keeps held
        inject = jax.lax.dynamic_index_in_dim(
            blocks, jnp.clip(s, 0, n_blocks - 1), 0, keepdims=False
        )
        cur = jnp.where(chain_pos == 0, inject, held)
        # store: at step s, chain position p holds block (s - p)
        b = s - chain_pos
        valid = (b >= 0) & (b < n_blocks)
        bc = jnp.clip(b, 0, n_blocks - 1)
        stored = jax.lax.dynamic_update_index_in_dim(out, cur, bc, 0)
        out = jnp.where(valid, stored, out)
        # forward to successor
        nxt = jax.lax.ppermute(cur, axis_name, perm)
        return (nxt, out), None

    held0 = jnp.zeros_like(blocks[0])
    out0 = jnp.where(chain_pos == 0, blocks, jnp.zeros_like(blocks))
    (_, out), _ = jax.lax.scan(step, (held0, out0), jnp.arange(n_steps + 1))
    return out


def chain_broadcast(
    params_flat: jax.Array,  # (total_elems,) valid on rank 0 of `axis_name`
    mesh: Mesh,
    axis_name: str,
    n_blocks: int = 16,
) -> jax.Array:
    """Jit-compiled wrapper: broadcast a flat parameter vector from chain
    rank 0 to every rank along `axis_name` (other mesh axes untouched)."""
    n_ranks = mesh.shape[axis_name]
    total = params_flat.shape[0]
    pad = (-total) % n_blocks
    padded = total + pad

    other_axes = tuple(a for a in mesh.axis_names if a != axis_name)
    in_spec = P()  # replicated view in; per-rank copies inside
    out_spec = P()

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(in_spec,),
        out_specs=out_spec,
        check_rep=False,
    )
    def _bcast(flat):
        blocks = jnp.pad(flat, (0, pad)).reshape(n_blocks, padded // n_blocks)
        out = chain_broadcast_blocks(blocks, axis_name, n_ranks)
        return out.reshape(padded)[:total]

    return jax.jit(_bcast)(params_flat)


# ---------------------------------------------------------------------------
# Parallel sharded transfer (Fig. 14): shard-send + AllGather over scale-up
# ---------------------------------------------------------------------------


def sharded_group_transfer(
    shard: jax.Array,  # this device's 1/g parameter shard (source group)
    scaleup_axis: str,  # the target group's ICI axis
    chain_axis: str,
    src_rank: int = 0,
    dst_rank: int = 1,
) -> jax.Array:
    """Inside shard_map: each source device ships its 1/g shard one hop down
    the chain axis (a single ppermute = the cross-group RDMA links used in
    parallel), then the receiving scale-up domain AllGathers over ICI.

    Returns the *full* parameter block on every device of the target group
    (and garbage elsewhere — callers mask by rank).
    """
    moved = jax.lax.ppermute(shard, chain_axis, [(src_rank, dst_rank)])
    return jax.lax.all_gather(moved, scaleup_axis, axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Analytic timing (used by the simulator's data-plane model)
# ---------------------------------------------------------------------------


def pipelined_chain_steps(n_blocks: int, n_ranks: int) -> int:
    """Number of hop-times for the systolic broadcast (vs n_blocks*(R-1)
    unpipelined)."""
    return n_blocks + max(n_ranks - 1, 1) - 1


def chain_broadcast_seconds(
    model_bytes: int, bottleneck_bytes_per_s: float, n_blocks: int, n_ranks: int
) -> float:
    block_t = model_bytes / n_blocks / bottleneck_bytes_per_s
    return block_t * pipelined_chain_steps(n_blocks, n_ranks)
