"""Cluster/network model used by the multicast planner (paper Fig. 10).

The paper models a scale-up + scale-out hybrid network:

  * devices inside a *scale-up group* (NVLink domain on GPU; an ICI-connected
    pod slice on TPU) have ultra-high bandwidth (1.6-3.6 Tbps) — intra-group
    transfers are treated as near-free and groups are collapsed into single
    logical nodes by the planner;
  * devices attach to a *leaf switch* with per-device bandwidth ``BW_i``;
    devices under one leaf have full-mesh min(BW_i, BW_j) connectivity;
  * leaves connect via a spine whose bandwidth is <= intra-leaf (we do not
    model the spine explicitly — ECMP/VLT assumption, §5.1);
  * every link is FULL-DUPLEX: flows in opposite directions on the same link
    do not contend (Fig. 7c) — the cornerstone of interference-free planning.

Device roles track what serving traffic currently occupies each direction of
a device's link so the planner can prune interfering sources/targets.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Iterable, Sequence


class Role(enum.Enum):
    """What an accelerator is currently doing — determines which direction of
    its network link carries serving traffic (PD-disaggregated LLMs move
    KVCache prefill->decode, §2.1)."""

    FREE = "free"
    PREFILL = "prefill"  # egress busy (sends KVCache to decode instances)
    DECODE = "decode"  # ingress busy (receives KVCache)
    COLOCATED = "colocated"  # both directions carry some serving traffic
    HOST_CACHE = "host_cache"  # CPU host holding the O(1) cached copy


@dataclasses.dataclass
class Device:
    """One accelerator (or a CPU host acting as a parameter source)."""

    id: int
    host: int
    leaf: int
    scaleup: int  # scale-up (NVLink/ICI) domain id
    bw_gbps: float  # scale-out link bandwidth
    role: Role = Role.FREE
    model: str | None = None  # model currently deployed (None = spare)
    is_host: bool = False  # CPU host memory source (PCIe-attached)

    @property
    def egress_busy(self) -> bool:
        return self.role in (Role.PREFILL, Role.COLOCATED)

    @property
    def ingress_busy(self) -> bool:
        return self.role in (Role.DECODE, Role.COLOCATED)


@dataclasses.dataclass
class Topology:
    devices: list[Device]

    def __post_init__(self):
        self._by_id = {d.id: d for d in self.devices}

    def device(self, i: int) -> Device:
        return self._by_id[i]

    def leaf_of(self, i: int) -> int:
        return self._by_id[i].leaf

    def scaleup_of(self, i: int) -> int:
        return self._by_id[i].scaleup

    def bw(self, i: int) -> float:
        return self._by_id[i].bw_gbps

    # ------------------------------------------------------------------
    def spares(self) -> list[Device]:
        return [d for d in self.devices if d.role is Role.FREE and not d.is_host]

    def sources_for(self, model: str) -> list[Device]:
        """All devices holding `model` parameters (GPU instances + hosts)."""
        return [d for d in self.devices if d.model == model]

    def scaleup_groups(self, ids: Iterable[int]) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for i in ids:
            out.setdefault(self.scaleup_of(i), []).append(i)
        return out

    def link_bw(self, i: int, j: int) -> float:
        """Effective scale-out bandwidth between two devices (full-mesh
        min() within a leaf; the spine is not modelled — §5.1)."""
        return min(self.bw(i), self.bw(j))


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def make_cluster(
    n_hosts: int,
    devs_per_host: int = 8,
    *,
    hosts_per_leaf: int = 2,
    bw_gbps: float = 200.0,
    scaleup_per_host: bool = True,
    start_id: int = 0,
) -> Topology:
    """A leaf-spine GPU/TPU cluster: each host is one scale-up domain (the
    paper's cluster A: 4x8 A800 + NVLink; our TPU mapping: one ICI slice)."""
    devices: list[Device] = []
    i = start_id
    for h in range(n_hosts):
        leaf = h // hosts_per_leaf
        for _ in range(devs_per_host):
            devices.append(
                Device(
                    id=i,
                    host=h,
                    leaf=leaf,
                    scaleup=h if scaleup_per_host else 0,
                    bw_gbps=bw_gbps,
                )
            )
            i += 1
    return Topology(devices)


def add_host_sources(
    topo: Topology, *, pcie_gbps: float = 256.0, per_host: bool = True
) -> Topology:
    """Append one CPU-host pseudo-device per host: the O(1) cached copy can
    be broadcast from there when no GPU instance holds the model."""
    max_id = max(d.id for d in topo.devices) + 1
    hosts = sorted({d.host for d in topo.devices})
    extra = []
    for k, h in enumerate(hosts):
        leaf = next(d.leaf for d in topo.devices if d.host == h)
        extra.append(
            Device(
                id=max_id + k,
                host=h,
                leaf=leaf,
                scaleup=-1 - h,  # hosts are not in any accelerator scale-up domain
                bw_gbps=pcie_gbps,
                role=Role.HOST_CACHE,
                is_host=True,
            )
        )
    return Topology(topo.devices + extra)


# ---------------------------------------------------------------------------
# Reference hardware constants (paper Table 1/2 + TPU v5e targets)
# ---------------------------------------------------------------------------

# paper Table 1 (cluster A / B)
RDMA_GBPS = 100.0
PCIE_HOST_GPU_GBPS = 128.0
SSD_GBPS = 10.0
NVLINK_GBPS = 1600.0

# TPU v5e single-chip targets (roofline constants, §Roofline)
TPU_BF16_TFLOPS = 197.0
TPU_HBM_GBPS_BYTES = 819.0e9  # bytes/s
TPU_ICI_GBPS_BYTES = 50.0e9  # bytes/s per link


def gbps_to_bytes_per_s(gbps: float) -> float:
    return gbps * 1e9 / 8.0
