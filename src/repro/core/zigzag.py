"""ZigZag live-autoscaling scheduling (paper §5.2, Figs. 15-16).

During live scaling, each request batch is executed as a 2-stage pipeline:
the scaling *target* instance runs the first ``T_i`` layers (those already
loaded), the overloaded *source* instance runs the remaining ``S_i = L-T_i``.
Choosing ``(T_i, S_i)`` per batch is the paper's ILP:

    min  Latency_avg = (sum_req sum_{i<=req} S_i) / N
    s.t. C1  S_i + T_i = L
         C2  sum_{j<=i} T_j <= sum_{j<=i-1} S_j          (pipeline dependency)
         C3  Time_l * (T_i-1) <= sum_{j<i} T_j + (N-i+1)(T_i-1)   (load limit)

where ``Time_l`` is the per-layer load time normalized to per-layer execute
time.  NOTE: the paper prints C3's LHS as ``Time_l * T_i``, but its own
worked example (Fig. 15b, config (2,5) for request 2 with Time_l=6) violates
that form; since the time origin is "first layer loaded", layer ``T_i``
finishes loading at ``Time_l * (T_i - 1)``, which matches the example — we
use that reading (recorded in EXPERIMENTS.md deviations).  The objective is equivalent to maximizing ``sum_i (N-i+1) * T_i``, so
an exact dynamic program over the prefix sum of T solves it in
``O(N^2 L^2)`` — milliseconds for the paper's sizes (the paper reports
<40 ms for Llama3-8B with an off-the-shelf ILP solver).

For many-layer models the paper's ILP-free rule (Fig. 16) is implemented in
:func:`simulate_zigzag`: a shared priority queue ordered by (FCFS, next
layer loaded), the target executes one layer at a time and re-queues, the
source pulls the earliest request only when it has no pending work.
``simulate_best_effort`` is the strawman of Fig. 15(a).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Exact ILP solver (dynamic program)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelinePlan:
    configs: list[tuple[int, int]]  # (T_i, S_i) per request batch
    avg_latency: float  # in layer-execution-time units
    solve_ms: float


def avg_latency_of(configs: Sequence[tuple[int, int]]) -> float:
    """The paper's objective: each request's latency is the source-side
    completion = sum of S_j for j <= i (FIFO queueing + own execution)."""
    n = len(configs)
    tot, pref = 0.0, 0.0
    for _, s in configs:
        pref += s
        tot += pref
    return tot / max(n, 1)


def solve_pipeline_ilp(
    n_requests: int, n_layers: int, time_l: float
) -> PipelinePlan:
    """Exact DP over (request index, prefix sum of T)."""
    import time as _time

    t0 = _time.perf_counter()
    N, L = n_requests, n_layers
    if N == 0:
        return PipelinePlan([], 0.0, 0.0)

    NEG = -1 << 60
    max_pref = N * L
    # dp[p] = best sum of w_i*T_i achievable with prefix sum p after request i
    dp = np.full(max_pref + 1, NEG, dtype=np.int64)
    choice: list[np.ndarray] = []

    # request 1: C2/C3 do not apply (time origin = first layer loaded).
    # T_1 >= 1 must hold only if we want the target involved at all; allow 0.
    # Extra layers beyond the first must have loaded while later requests
    # execute: Time_l*(T_1-1) <= N*(T_1-1) handles the degenerate cases.
    w1 = N
    c1 = np.full(max_pref + 1, -1, dtype=np.int64)
    for t in range(0, min(L, max_pref) + 1):
        if t >= 2 and time_l > N:
            break
        val = w1 * t
        if val > dp[t]:
            dp[t] = val
            c1[t] = t
    choice.append(c1)

    for i in range(2, N + 1):
        w = N - i + 1
        ndp = np.full(max_pref + 1, NEG, dtype=np.int64)
        ci = np.full(max_pref + 1, -1, dtype=np.int64)
        for p in range(max_pref + 1):
            if dp[p] == NEG:
                continue
            # C2: prefT_{i-1} + T_i <= (i-1)L - prefT_{i-1}
            hi = (i - 1) * L - 2 * p
            hi = min(hi, L)
            if hi < 0:
                continue
            for t in range(0, hi + 1):
                # C3: time_l*(T_i-1) <= prefT_{i-1} + (N-i+1)*(T_i-1)
                if t > 0 and time_l * (t - 1) > p + w * (t - 1) + 1e-9:
                    continue
                np_ = p + t
                val = dp[p] + w * t
                if val > ndp[np_]:
                    ndp[np_] = val
                    ci[np_] = t
        dp = ndp
        choice.append(ci)

    best_p = int(np.argmax(dp))
    if dp[best_p] == NEG:
        # infeasible beyond request 1 — degenerate all-source plan
        cfgs = [(0, L)] * N
        return PipelinePlan(cfgs, avg_latency_of(cfgs), (_time.perf_counter() - t0) * 1e3)

    # backtrack
    ts: list[int] = []
    p = best_p
    for i in range(N, 0, -1):
        t = int(choice[i - 1][p])
        ts.append(t)
        p -= t
    ts.reverse()
    cfgs = [(t, L - t) for t in ts]
    return PipelinePlan(cfgs, avg_latency_of(cfgs), (_time.perf_counter() - t0) * 1e3)


# ---------------------------------------------------------------------------
# ILP-free ZigZag scheduler (Fig. 16) — event-driven co-simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScheduleResult:
    completion: list[float]  # per-request completion time (exec-time units)
    avg_latency: float
    makespan: float
    target_layers: list[int]  # layers executed on the target per request


def simulate_zigzag(
    n_requests: int,
    n_layers: int,
    time_l: float,
    *,
    exec_time: Sequence[float] | None = None,
) -> ScheduleResult:
    """The ILP-free rule.  Time unit = one layer execution (per-batch
    ``exec_time`` scales it — the §5.4 LLM regulation parameter).

    Target: repeatedly take the highest-priority request whose
    next-to-execute layer is loaded; execute ONE layer; requeue.
    Source: when idle, pull the earliest request not running on the target
    and finish ALL its remaining layers.
    """
    N, L = n_requests, n_layers
    et = list(exec_time) if exec_time is not None else [1.0] * N
    layers_done = [0] * N  # layers executed so far (on either side)
    on_target = [True] * N  # still eligible for target execution
    done = [False] * N
    completion = [0.0] * N
    tgt_layers = [0] * N

    t_target = 0.0  # target instance free-at time
    t_source = 0.0  # source instance free-at time
    # layer k (0-based) is loaded at time k*time_l, layer 0 at t=0; the
    # epsilon guards against float truncation (t == k*time_l must count
    # layer k as loaded, else the event loop can livelock at that instant)
    loaded = lambda t: min(L, 1 + int((max(t, 0.0) + 1e-9) / time_l)) if time_l > 0 else L

    def next_target_req(now: float) -> int | None:
        nl = loaded(now)
        for i in range(N):
            if done[i] or not on_target[i]:
                continue
            if layers_done[i] < nl and layers_done[i] < L:
                return i  # FCFS among those with next layer loaded
        return None

    def next_source_req() -> int | None:
        for i in range(N):
            if not done[i] and not running_on_target[i]:
                return i
        return None

    running_on_target = [False] * N
    # event loop: advance whichever instance frees first
    guard = 0
    while not all(done) and guard < 100 * N * L + 1000:
        guard += 1
        progressed = False
        # source: pull earliest pending request and run it to completion
        i = next_source_req()
        if i is not None and t_source <= t_target + 1e-12:
            rem = L - layers_done[i]
            if rem > 0:
                on_target[i] = False  # source takes over: finish all layers
                start = max(t_source, 0.0)
                t_source = start + rem * et[i]
                layers_done[i] = L
                done[i] = True
                completion[i] = t_source
                progressed = True
            else:
                done[i] = True
                completion[i] = max(t_source, t_target)
                progressed = True
        if not progressed:
            # target: one layer of the best request
            i = next_target_req(t_target)
            if i is not None:
                running_on_target[i] = True
                t_target = max(t_target, layers_done[i] * time_l) + et[i]
                layers_done[i] += 1
                tgt_layers[i] += 1
                running_on_target[i] = False
                if layers_done[i] >= L:
                    done[i] = True
                    completion[i] = t_target
                progressed = True
        if not progressed:
            # both stalled: advance target clock to the next layer-load event
            nl = loaded(t_target)
            if nl < L:
                t_target = nl * time_l
            else:
                # nothing left for the target; let the source catch up
                t_source = max(t_source, t_target)
                i = next_source_req()
                if i is None:
                    break
    avg = float(np.mean(completion)) if completion else 0.0
    return ScheduleResult(completion, avg, max(completion, default=0.0), tgt_layers)


def simulate_best_effort(
    n_requests: int,
    n_layers: int,
    time_l: float,
    *,
    exec_time: Sequence[float] | None = None,
) -> ScheduleResult:
    """Strawman (Fig. 15a): each batch greedily uses as many loaded layers as
    possible on the target (<= L/2), the rest on the source, strictly FCFS
    with no delaying."""
    N, L = n_requests, n_layers
    et = list(exec_time) if exec_time is not None else [1.0] * N
    t_target, t_source = 0.0, 0.0
    completion = [0.0] * N
    tgt_layers = [0] * N
    loaded = lambda t: min(L, 1 + int((max(t, 0.0) + 1e-9) / time_l)) if time_l > 0 else L
    for i in range(N):
        k = min(loaded(t_target), L // 2)
        # target stage: wait for layer availability as it executes
        start = t_target
        tt = start
        for layer in range(k):
            tt = max(tt, layer * time_l) + et[i]
        t_target = tt
        tgt_layers[i] = k
        # source stage: starts when both the activation arrives and source free
        s = L - k
        t_source = max(t_source, tt) + s * et[i]
        completion[i] = t_source if s > 0 else tt
    avg = float(np.mean(completion)) if completion else 0.0
    return ScheduleResult(completion, avg, max(completion, default=0.0), tgt_layers)


# ---------------------------------------------------------------------------
# Throughput model during live scaling (§4 example)
# ---------------------------------------------------------------------------


def live_throughput_multiplier(k_loaded: int, n_layers: int) -> float:
    """Relative serving throughput of the (source + scaling target) pair vs a
    single instance.  With k layers loaded the scheduler assigns the target
    t = min(k, L//2) layers (never more — over-assigning would make the
    target the bottleneck), so the pipeline rate is 1/max(t, L-t):
    monotone ramp from 1 to 2, reaching 2.0 at k = L/2 (§4)."""
    L = n_layers
    k = max(0, min(k_loaded, L))
    if k == 0:
        return 1.0
    if k >= L:
        return 2.0
    t = min(k, L // 2)
    return L / max(t, L - t, 1)
