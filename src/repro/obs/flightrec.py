"""Anomaly-triggered flight recorder: always-on ring, dump-on-incident.

The streaming SLO monitor can *page* and the FlowSim can kill a leaf —
but until now both fired into the void: by the time anyone looks, the
interesting window (what was on the wire, which scale op was mid-flight,
what the health surface said) is gone.  A :class:`FlightRecorder` is the
production answer: it keeps a bounded, always-on ring of recent
:class:`~repro.net.events.NetEvent`\\ s (a
:class:`~repro.net.events.FlowEventLog` ring buffer) next to the span
tracer, and when an anomaly fires it dumps one **byte-deterministic,
Perfetto-loadable incident bundle**:

  * the last ``window_s`` seconds of spans as regular ``traceEvents``
    (load the file at https://ui.perfetto.dev — unknown top-level keys
    are ignored by the viewer);
  * an ``incident`` header: the trigger + context, the trailing event
    ring (with the ring's ``dropped`` count surfaced, and an explicit
    ``truncated`` flag when eviction is known to have eaten into the
    window), the scale-op critical-path report
    (:mod:`repro.obs.critical_path`), the ``fleet_health()`` snapshot,
    and the link ledger's per-group busy split when one is attached.

Triggers:

  * ``net:device_failed`` / ``net:leaf_failed`` — FlowSim failure events
    observed through the recorder's own subscription (``attach``);
  * ``slo:page`` — the SLO monitor's fleet status escalated to ``page``
    (edge-triggered: one bundle per escalation, re-armed when the fleet
    recovers).  Polled by the host control loop (``Simulator._monitor``,
    ``FleetScheduler.tick``).

Everything is observational: the recorder subscribes like any other
FlowEventLog (subscribers never mutate the data plane), all timestamps
come from the simulation clock, and file contents are
``sort_keys``-serialized — a seeded run produces byte-identical bundles
every time, which is what lets a test pin one.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro.net import events as ev
from repro.obs.critical_path import analyze_scale_ops, summarize_scale_ops
from repro.obs.export import _clean, chrome_trace_doc
from repro.obs.trace import NULL_TRACER

__all__ = ["FlightRecorder", "TRIGGER_KINDS"]

#: the NetEvent kinds that trigger a dump (link failures are survivable
#: re-routes; device/leaf deaths lose capacity and abort flows)
TRIGGER_KINDS = frozenset({ev.DEVICE_FAILED, ev.LEAF_FAILED})


class FlightRecorder:
    """Bounded always-on recording + deterministic incident bundles."""

    def __init__(
        self,
        tracer=None,
        *,
        window_s: float = 5.0,
        ring: int = 1024,
        slo_monitor=None,
        link_ledger=None,
        metrics=None,
        out_dir: str = "incidents",
        max_dumps: int = 8,
    ):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.window_s = float(window_s)
        self.ring = ev.FlowEventLog(maxlen=ring)
        self.slo_monitor = slo_monitor
        self.link_ledger = link_ledger
        self.metrics = metrics
        self.out_dir = out_dir
        self.max_dumps = max_dumps
        self.dumps: list[str] = []  # written bundle paths, in order
        self.skipped = 0  # triggers suppressed by the max_dumps cap
        self._last_status = "ok"
        self._warned_truncated = False

    # -- wiring --------------------------------------------------------------
    def attach(self, flowsim) -> "FlightRecorder":
        """Subscribe to a FlowSim: every NetEvent lands in the ring and
        failure events trigger a dump.  Purely observational — the golden
        flow-event stream of the run is bit-for-bit unchanged."""
        flowsim.subscribe(self._on_net_event)
        return self

    def _on_net_event(self, event: ev.NetEvent) -> None:
        self.ring(event)
        if event.kind in TRIGGER_KINDS:
            ctx: dict[str, Any] = {"kind": event.kind}
            if event.device is not None:
                ctx["device"] = event.device
            if event.leaf is not None:
                ctx["leaf"] = event.leaf
            self.trigger(f"net:{event.kind}", event.t, ctx)

    def poll(self, now: float) -> None:
        """Control-loop hook: dump when the SLO monitor's fleet status
        escalates to ``page`` (edge-triggered — re-armed on recovery)."""
        if self.slo_monitor is None:
            return
        health = self.slo_monitor.fleet_health(now)
        status = health.get("status", "ok")
        if status == "page" and self._last_status != "page":
            paging = sorted(
                name for name, t in health.get("tenants", {}).items()
                if t.get("status") == "page"
            )
            self.trigger("slo:page", now, {"tenants": paging})
        self._last_status = status

    # -- dumping -------------------------------------------------------------
    def trigger(self, trigger: str, t: float, context: dict | None = None) -> str | None:
        """Dump an incident bundle now; returns the path (None when the
        ``max_dumps`` cap suppressed it — a failure storm must not turn
        the recorder into the incident)."""
        if len(self.dumps) >= self.max_dumps:
            self.skipped += 1
            if self.metrics is not None:
                self.metrics.counter("flightrec.skipped_dumps").inc()
            return None
        path = os.path.join(
            self.out_dir,
            f"incident-{len(self.dumps):03d}-{trigger.replace(':', '-')}.json",
        )
        os.makedirs(self.out_dir, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.render(trigger, t, context))
        self.dumps.append(path)
        if self.metrics is not None:
            self.metrics.counter("flightrec.dumps").inc()
        return path

    def render(self, trigger: str, t: float, context: dict | None = None) -> str:
        """The bundle bytes (separated from :meth:`trigger` so tests can
        pin determinism without touching the filesystem)."""
        w0 = t - self.window_s
        spans = list(self.tracer.spans)
        window = [
            s for s in spans
            if s.t0 <= t and (s.t1 is None or s.t1 >= w0)
        ]
        doc = chrome_trace_doc(window)

        truncated = self.ring.truncated_since(w0)
        if truncated and not self._warned_truncated:
            # one-time, not per-dump: a steady-state undersized ring would
            # otherwise bury the signal in its own warnings
            self._warned_truncated = True
            if self.metrics is not None:
                self.metrics.counter("flightrec.truncated_dumps").inc()

        # the op mid-flight at the incident is exactly the interesting one:
        # analyze open spans as-if closed at the trigger time, so its
        # makespan-so-far partition appears in the bundle
        closed = [
            s if s.t1 is not None else dataclasses.replace(s, t1=max(t, s.t0))
            for s in spans
        ]
        cp = analyze_scale_ops(closed, link_ledger=self.link_ledger)
        cp_summary = summarize_scale_ops(
            [r for r in cp if r.t1 >= w0 and r.t0 <= t]
        )

        doc["incident"] = {
            "schema": 1,
            "trigger": trigger,
            "t": t,
            "window_s": self.window_s,
            "seq": len(self.dumps),
            "context": _clean(context or {}),
            "ring": {
                "maxlen": self.ring.maxlen,
                "retained": len(self.ring),
                "dropped": self.ring.dropped,
                "truncated": truncated,
                "events": [e.render() for e in self.ring.since(w0)],
            },
            "critical_path": cp_summary,
            "fleet_health": (
                self.slo_monitor.fleet_health(t)
                if self.slo_monitor is not None else None
            ),
            "link_busy_by_group": (
                self.link_ledger.busy_by_group()
                if self.link_ledger is not None else None
            ),
        }
        return json.dumps(doc, sort_keys=True, separators=(",", ":"))
