"""Fleet utilization ledgers: where every device-second and link-second went.

The paper's headline claims are *resource* claims — 49% less GPU time than
non-autoscaling serving, 94% lower tail latency — but a single
``gpu_time_s`` scalar can show *that* GPU time dropped, never *where it
went*.  Two ledgers close that gap:

:class:`DeviceTimeLedger`
    partitions every device-second a control plane accounts into exclusive
    states:

    * ``serving_prefill`` / ``serving_decode`` — the device ran a forward
      pass of that phase;
    * ``loading_params`` — parameters in flight, no work waiting on them;
    * ``stalled_waiting_layers`` — parameters in flight WITH work queued
      behind them (the latency the paper's live scaling exists to hide);
    * ``allocated_idle`` — held by an instance/grant but executing nothing;
    * ``draining`` — finishing in-flight work before releasing the device.

    The conservation invariant is **by construction**: callers accrue every
    accounted interval into exactly one state, and :meth:`total` sums the
    per-state totals in one fixed order — so ``sum(breakdown().values())
    == total()`` bit-for-bit, and a simulator that defines its
    ``gpu_time_s`` as ``ledger.total()`` gets exact attribution for free.

:class:`LinkLedger`
    attributes per-link busy time and bytes to flow-kind groups
    (``multicast`` / ``kv`` / ``cold_start`` / ``serving``).  FlowSim
    accrues into it on every integration step when one is attached
    (:meth:`repro.net.flowsim.FlowSim.attach_ledger`); detached, the data
    plane is untouched — golden flow-event traces stay bit-for-bit.
    Busy-seconds are capacity-normalized (``moved_bytes / rate_cap``), so
    the per-link sum across all groups can never exceed the elapsed
    horizon (max-min sharing conserves link capacity).
"""

from __future__ import annotations

from repro.net.flows import Flow, FlowKind

__all__ = [
    "DEVICE_STATES",
    "FLOW_GROUPS",
    "DeviceTimeLedger",
    "LinkLedger",
]

#: exclusive device states; the FIXED summation order behind the
#: conservation invariant — never reorder (total() and breakdown() both
#: iterate it, which is what makes their sums bit-identical)
DEVICE_STATES = (
    "serving_prefill",
    "serving_decode",
    "loading_params",
    "allocated_idle",
    "stalled_waiting_layers",
    "draining",
)

#: FlowKind -> attribution group for the link ledger
FLOW_GROUPS = {
    FlowKind.MULTICAST_HOP: "multicast",
    FlowKind.ALLGATHER: "multicast",
    FlowKind.KV_MIGRATION: "kv",
    FlowKind.COLD_START: "cold_start",
    FlowKind.SERVING: "serving",
}


class DeviceTimeLedger:
    """Exclusive-state device-second accounting with exact conservation."""

    __slots__ = ("_totals", "_by_owner")

    def __init__(self):
        self._totals = {s: 0.0 for s in DEVICE_STATES}
        self._by_owner: dict[str, dict[str, float]] = {}

    def accrue(self, state: str, device_seconds: float,
               owner: str | None = None) -> None:
        """Charge ``device_seconds`` to one exclusive ``state`` (optionally
        attributed to an ``owner`` — a tenant/model name)."""
        if device_seconds <= 0.0:
            return
        if state not in self._totals:
            raise ValueError(f"unknown ledger state {state!r} "
                             f"(expected one of {DEVICE_STATES})")
        self._totals[state] += device_seconds
        if owner is not None:
            o = self._by_owner.get(owner)
            if o is None:
                o = self._by_owner[owner] = {s: 0.0 for s in DEVICE_STATES}
            o[state] += device_seconds

    # -- views ---------------------------------------------------------------
    def total(self) -> float:
        """Accounted device-seconds.  Summed in DEVICE_STATES order — the
        same floats in the same order as ``sum(breakdown().values())``, so
        the conservation check is exact, not within-epsilon."""
        t = 0.0
        for s in DEVICE_STATES:
            t += self._totals[s]
        return t

    def breakdown(self) -> dict[str, float]:
        """Per-state totals, every state present, DEVICE_STATES order."""
        return {s: self._totals[s] for s in DEVICE_STATES}

    def owners(self) -> list[str]:
        return sorted(self._by_owner)

    def owner_breakdown(self, owner: str) -> dict[str, float]:
        o = self._by_owner.get(owner)
        return {s: (o[s] if o else 0.0) for s in DEVICE_STATES}

    def utilization(self) -> float:
        """Fraction of accounted device-time doing useful serving work."""
        t = self.total()
        if t <= 0.0:
            return 0.0
        return (self._totals["serving_prefill"]
                + self._totals["serving_decode"]) / t

    def as_metrics(self, prefix: str = "gpu_s") -> dict[str, float]:
        """Flat ``{prefix}.{state}`` mapping for BENCH_*.json records."""
        return {f"{prefix}.{s}": self._totals[s] for s in DEVICE_STATES}


class LinkLedger:
    """Per-link busy time and bytes attributed to flow-kind groups."""

    __slots__ = ("bytes", "busy_s", "cap_seen", "horizon")

    def __init__(self):
        # (link_key, group) -> accumulated value
        self.bytes: dict[tuple, float] = {}
        self.busy_s: dict[tuple, float] = {}
        # link_key -> max rate_cap observed while accruing (degrades shrink
        # the live cap; the bound test compares against the max ever seen)
        self.cap_seen: dict[tuple, float] = {}
        self.horizon = 0.0  # last network time observed (note_time)

    def accrue_flow(self, flow: Flow, moved_bytes: float, dt: float) -> None:
        """Charge one integration step of ``flow``: ``moved_bytes`` crossed
        every link on its path during ``dt`` seconds."""
        if moved_bytes <= 0.0:
            return
        group = FLOW_GROUPS.get(flow.kind, flow.kind.value)
        for link in flow.path:
            key = (link.key, group)
            self.bytes[key] = self.bytes.get(key, 0.0) + moved_bytes
            cap = link.rate_cap
            if cap > 0.0:
                self.busy_s[key] = self.busy_s.get(key, 0.0) + moved_bytes / cap
                prev = self.cap_seen.get(link.key, 0.0)
                if cap > prev:
                    self.cap_seen[link.key] = cap

    def note_time(self, now: float) -> None:
        if now > self.horizon:
            self.horizon = now

    # -- views ---------------------------------------------------------------
    def groups(self) -> list[str]:
        return sorted({g for _, g in self.bytes})

    def links(self) -> list[tuple]:
        return sorted({k for k, _ in self.bytes}, key=repr)

    def bytes_by_group(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for (_, g), v in self.bytes.items():
            out[g] = out.get(g, 0.0) + v
        return {g: out[g] for g in sorted(out)}

    def busy_by_group(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for (_, g), v in self.busy_s.items():
            out[g] = out.get(g, 0.0) + v
        return {g: out[g] for g in sorted(out)}

    def link_busy(self, link_key: tuple) -> float:
        """Capacity-normalized busy-seconds of one link across all groups —
        bounded above by the elapsed horizon."""
        return sum(v for (k, _), v in self.busy_s.items() if k == link_key)

    def link_breakdown(self, link_key: tuple) -> dict[str, float]:
        return {
            g: v for (k, g), v in sorted(self.busy_s.items(), key=lambda kv: kv[0][1])
            if k == link_key
        }

    def busiest(self, n: int = 5) -> list[tuple[tuple, float]]:
        """The ``n`` links with the most attributed busy time."""
        per_link: dict[tuple, float] = {}
        for (k, _), v in self.busy_s.items():
            per_link[k] = per_link.get(k, 0.0) + v
        return sorted(per_link.items(), key=lambda kv: (-kv[1], repr(kv[0])))[:n]
