"""Tail-latency attribution report: where does each request's TTFT go?

The paper's headline (Fig. 17: up to 94% lower tail TTFT) is a claim about
*causes* — queueing behind busy instances vs waiting for parameters to
load vs network contention.  This report answers it per request from the
span trace: every ``request`` root span's TTFT window is decomposed into
its child spans by category

  * ``queue``     — waiting behind other requests on an active instance;
  * ``load``      — waiting for the serving instance's parameters to
                    arrive (the scale-up data plane: what BLITZSCALE's
                    multicast shrinks and ServerlessLLM's SSD path bloats);
  * ``compute``   — the prefill forward pass itself;
  * ``migration`` / ``network`` — KV transfer and raw flow time (post-TTFT
                    for the first token, but reported for the full
                    request lifecycle).

and the aggregate view splits the population at the median and the p99 so
the tail's dominant cause is immediately visible — the paper's Fig-17
story, but queryable.

CLI::

    PYTHONPATH=src python -m repro.obs.report trace.json
    PYTHONPATH=src python -m repro.obs.report --sim --system blitz \\
        --duration 20 --min-attribution 0.95

``--sim`` runs a seeded :class:`repro.core.simulator.Simulator` with
tracing enabled (no trace file needed); ``--min-attribution`` exits
non-zero when any finished request's TTFT is less than the given fraction
attributed to named spans — the CI smoke gate.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.obs.export import chrome_trace, load_chrome
from repro.obs.trace import Span, Tracer

__all__ = [
    "RequestAttribution",
    "attribute_requests",
    "summarize",
    "format_report",
    "run_traced_sim",
    "main",
]

#: categories that partition the TTFT window (emitted by the simulator)
TTFT_CAUSES = ("queue", "load", "compute")
#: categories reported over the request's whole lifetime
ALL_CAUSES = TTFT_CAUSES + ("migration", "network")


@dataclasses.dataclass
class RequestAttribution:
    rid: int
    arrival: float
    ttft: float
    by_cause: dict[str, float]  # seconds inside the TTFT window, per cause
    lifetime_by_cause: dict[str, float]  # over the whole request span
    attributed: float  # sum of TTFT_CAUSES inside the window
    frac: float  # attributed / ttft


def _descendants(spans: list[Span], root: Span) -> list[Span]:
    kids: dict[int, list[Span]] = {}
    for s in spans:
        if s.parent is not None:
            kids.setdefault(s.parent, []).append(s)
    out: list[Span] = []
    stack = [root.sid]
    while stack:
        sid = stack.pop()
        for c in kids.get(sid, ()):
            out.append(c)
            stack.append(c.sid)
    return out


def attribute_requests(spans: list[Span]) -> list[RequestAttribution]:
    """Per-request TTFT decomposition from a span trace.  Requests whose
    prefill never finished (no ``ttft`` attr) are skipped."""
    out: list[RequestAttribution] = []
    for root in spans:
        if root.name != "request":
            continue
        ttft = root.attrs.get("ttft")
        if ttft is None:
            continue
        ttft = float(ttft)
        w0, w1 = root.t0, root.t0 + ttft
        window: dict[str, float] = {}
        lifetime: dict[str, float] = {}
        for s in _descendants(spans, root):
            if s.cat not in ALL_CAUSES or s.t1 is None:
                continue
            lifetime[s.cat] = lifetime.get(s.cat, 0.0) + (s.t1 - s.t0)
            ov = min(s.t1, w1) - max(s.t0, w0)
            if ov > 0.0:
                window[s.cat] = window.get(s.cat, 0.0) + ov
        attributed = sum(window.get(c, 0.0) for c in TTFT_CAUSES)
        out.append(
            RequestAttribution(
                rid=root.attrs.get("rid", -1),
                arrival=root.t0,
                ttft=ttft,
                by_cause=window,
                lifetime_by_cause=lifetime,
                attributed=attributed,
                frac=min(attributed / ttft, 1.0) if ttft > 0 else 1.0,
            )
        )
    return out


def summarize(reqs: list[RequestAttribution]) -> dict:
    """Aggregate attribution: overall percentiles + per-cause breakdown of
    the median half vs the p99 tail — which cause makes the tail slow."""
    if not reqs:
        return {"n_requests": 0}
    ttfts = np.array([r.ttft for r in reqs])
    p50 = float(np.percentile(ttfts, 50))
    p99 = float(np.percentile(ttfts, 99))

    def mean_by_cause(group: list[RequestAttribution]) -> dict[str, float]:
        if not group:
            return {c: 0.0 for c in TTFT_CAUSES}
        return {
            c: float(np.mean([r.by_cause.get(c, 0.0) for r in group]))
            for c in TTFT_CAUSES
        }

    tail = [r for r in reqs if r.ttft >= p99]
    median_half = [r for r in reqs if r.ttft <= p50]
    tail_means = mean_by_cause(tail)
    tail_total = sum(tail_means.values()) or 1.0
    dominant = max(tail_means, key=lambda c: tail_means[c])
    return {
        "n_requests": len(reqs),
        "ttft_p50_s": p50,
        "ttft_p99_s": p99,
        "ttft_mean_s": float(np.mean(ttfts)),
        "min_attribution_frac": float(min(r.frac for r in reqs)),
        "mean_attribution_frac": float(np.mean([r.frac for r in reqs])),
        "median_by_cause_s": mean_by_cause(median_half),
        "tail_by_cause_s": tail_means,
        "tail_share_by_cause": {c: tail_means[c] / tail_total for c in tail_means},
        "tail_dominant_cause": dominant,
        "requests": [dataclasses.asdict(r) for r in reqs],
    }


def format_report(summary: dict) -> str:
    if not summary.get("n_requests"):
        return "no finished requests in trace"
    lines = [
        f"requests analysed: {summary['n_requests']}",
        f"TTFT p50 {summary['ttft_p50_s'] * 1e3:.1f} ms | "
        f"p99 {summary['ttft_p99_s'] * 1e3:.1f} ms | "
        f"mean {summary['ttft_mean_s'] * 1e3:.1f} ms",
        f"TTFT attributed to named spans: min "
        f"{summary['min_attribution_frac'] * 100:.1f}% / mean "
        f"{summary['mean_attribution_frac'] * 100:.1f}%",
        "",
        "| cause | median-half mean (ms) | p99-tail mean (ms) | tail share |",
        "|---|---|---|---|",
    ]
    for c in TTFT_CAUSES:
        lines.append(
            f"| {c} | {summary['median_by_cause_s'][c] * 1e3:.2f} "
            f"| {summary['tail_by_cause_s'][c] * 1e3:.2f} "
            f"| {summary['tail_share_by_cause'][c] * 100:.1f}% |"
        )
    lines.append("")
    lines.append(
        f"tail (p99) TTFT is dominated by: {summary['tail_dominant_cause']}"
    )
    return "\n".join(lines)


def run_traced_sim(
    *,
    system: str = "blitz",
    model: str = "8b",
    duration: float = 20.0,
    rate: float = 4.0,
    seed: int = 0,
    latency: bool = True,
):
    """Run a small seeded simulation with tracing enabled; returns
    ``(tracer, sim_result)``.  The entry point CI's attribution smoke and
    the golden Chrome-trace test share."""
    # lazy import: --sim is a CLI convenience that drives the simulator it
    # normally only *observes*; library code in repro.obs must never depend
    # on repro.core.simulator (the DAG runs the other way)
    from repro.core import simulator as sim_mod  # simcheck: disable=layering -- CLI --sim entrypoint, not library code
    from repro.workloads import traces

    systems = {
        "blitz": sim_mod.BLITZ,
        "blitz-nolive": sim_mod.BLITZ_NOLIVE,
        "blitz-naive": sim_mod.BLITZ_NAIVE,
        "sllm": sim_mod.SLLM,
        "allcache": sim_mod.ALLCACHE,
        "ssd": sim_mod.SSD_ONLY,
    }
    tracer = Tracer()
    s = sim_mod.Simulator(
        systems[system],
        sim_mod.profile_for(model),
        seed=seed,
        tracer=tracer,
        link_latency_s=2e-5 if latency else 0.0,
        switch_latency_s=5e-6 if latency else 0.0,
    )
    trace = traces.burstgpt(duration=duration, base_rate=rate, seed=seed + 11)
    result = s.run(trace)
    return tracer, result


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="attribute each request's TTFT to named spans "
        "(queue/load/compute) and break down tail vs median by cause",
    )
    ap.add_argument("trace", nargs="?", help="chrome-trace JSON exported by repro.obs")
    ap.add_argument("--sim", action="store_true",
                    help="run a small seeded simulator instead of reading a file")
    ap.add_argument("--system", default="blitz")
    ap.add_argument("--model", default="8b")
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-latency", action="store_true",
                    help="--sim: disable the per-hop latency model")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full summary (per-request rows included) here")
    ap.add_argument("--chrome-out", default=None,
                    help="--sim: also export the Chrome trace JSON here")
    ap.add_argument("--min-attribution", type=float, default=None,
                    help="exit non-zero when any request's TTFT attribution "
                    "falls below this fraction (CI gate)")
    ap.add_argument("--scale-ops", action="store_true",
                    help="report scale-operation critical paths (makespan "
                    "partitioned into plan/queue/transfer/stall/cutover) "
                    "instead of request TTFT")
    ap.add_argument("--min-makespan-attribution", type=float, default=None,
                    help="--scale-ops: exit non-zero when any scale op's "
                    "makespan coverage falls below this fraction (CI gate, "
                    "mirrors --min-attribution)")
    args = ap.parse_args(argv)

    if args.sim:
        tracer, _ = run_traced_sim(
            system=args.system, model=args.model, duration=args.duration,
            rate=args.rate, seed=args.seed, latency=not args.no_latency,
        )
        spans = list(tracer.spans)
        if args.chrome_out:
            with open(args.chrome_out, "w") as f:
                f.write(chrome_trace(spans))
            print(f"chrome trace -> {args.chrome_out}")
    elif args.trace:
        spans = load_chrome(args.trace)
    else:
        ap.error("give a trace file or --sim")

    if args.scale_ops:
        from repro.obs.critical_path import (
            analyze_scale_ops,
            format_scale_report,
            summarize_scale_ops,
        )

        reports = analyze_scale_ops(spans)
        summary = summarize_scale_ops(reports)
        print(format_scale_report(reports, summary))
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(summary, f, indent=1, sort_keys=True)
            print(f"\nsummary -> {args.json_out}")
        if args.min_makespan_attribution is not None:
            if not reports:
                print("FAIL: no closed scale_op spans to attribute",
                      file=sys.stderr)
                sys.exit(1)
            bad = [r for r in reports
                   if r.coverage < args.min_makespan_attribution]
            if bad:
                worst = min(bad, key=lambda r: r.coverage)
                print(
                    f"FAIL: {len(bad)} scale op(s) below "
                    f"{args.min_makespan_attribution:.0%} makespan "
                    f"attribution (worst sid={worst.sid} at "
                    f"{worst.coverage:.1%})",
                    file=sys.stderr,
                )
                sys.exit(1)
            print(
                f"makespan attribution gate OK: all {len(reports)} "
                f"scale ops >= {args.min_makespan_attribution:.0%}"
            )
        return summary

    reqs = attribute_requests(spans)
    summary = summarize(reqs)
    print(format_report(summary))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        print(f"\nsummary -> {args.json_out}")
    if args.min_attribution is not None:
        if not reqs:
            print("FAIL: no finished requests to attribute", file=sys.stderr)
            sys.exit(1)
        low = [r for r in reqs if r.frac < args.min_attribution]
        if low:
            print(
                f"FAIL: {len(low)} request(s) below "
                f"{args.min_attribution:.0%} TTFT attribution "
                f"(worst rid={min(low, key=lambda r: r.frac).rid} at "
                f"{min(r.frac for r in low):.1%})",
                file=sys.stderr,
            )
            sys.exit(1)
        print(
            f"attribution gate OK: all {len(reqs)} requests >= "
            f"{args.min_attribution:.0%}"
        )
    return summary


if __name__ == "__main__":
    main()
