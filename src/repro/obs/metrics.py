"""Metric registry: counters, gauges, fixed-bucket histograms, snapshots.

One :class:`MetricRegistry` is the single sink for every numeric signal a
control plane produces.  The three hand-rolled stats dataclasses that grew
up around the repo (``RuntimeStats``, ``FleetStats``, ``TenantStats``) now
share one base, :class:`StatBlock`: plain attribute increments keep working
(``stats.migrations += 1``), but once a block is ``bind()``-ed to a
registry every assignment is mirrored into a named counter — so a fleet-
wide registry sees every tenant's and runtime's counters under one
namespace, and a benchmark can flatten the whole thing into a
``BENCH_*.json`` perf record with :meth:`MetricRegistry.flat`.

Design constraints:

  * **deterministic** — metric names are explicit, snapshots are sorted,
    nothing reads the wall clock; a seeded simulation produces an
    identical registry every run;
  * **cheap** — counters and gauges are one attribute store; histograms
    are a ``bisect`` into fixed bucket bounds (no allocation per observe);
  * **serializable** — ``snapshot()``/``flat()`` emit plain dicts of
    floats, ready for ``json.dump``.

``snap(t)`` appends a timestamped snapshot to ``series`` — the periodic-
snapshot hook the simulator's monitor loop drives, giving post-hoc reports
a time axis without a separate time-series store.
"""

from __future__ import annotations

import dataclasses
from bisect import bisect_left
from collections import deque
from typing import Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "StatBlock",
    "DEFAULT_LATENCY_BUCKETS_S",
]

#: fixed buckets for latency histograms (seconds) — sub-ms to minutes
DEFAULT_LATENCY_BUCKETS_S = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
    0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotonic-by-convention numeric cell (``set`` exists so a bound
    :class:`StatBlock` can mirror plain field assignment)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def set(self, v: float) -> None:
        self.value = float(v)


class Gauge:
    """Last-value-wins numeric cell."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` observations fell at or below
    ``bounds[i]``; the final slot is the overflow bucket."""

    __slots__ = ("name", "bounds", "counts", "total", "count")

    def __init__(self, name: str, bounds: Iterable[float]):
        self.name = name
        self.bounds = tuple(sorted(float(b) for b in bounds))
        if not self.bounds:
            raise ValueError(f"histogram {name!r} needs at least one bucket bound")
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        # bisect_left keeps an observation exactly equal to bounds[i] in
        # bucket i — the documented "at or below bounds[i]" semantics
        self.counts[bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "sum": self.total,
            "count": self.count,
        }


class MetricRegistry:
    """Named counters/gauges/histograms + timestamped snapshot series.

    ``series_maxlen`` bounds the snapshot series as a ring buffer (oldest
    snapshots evicted, ``series_dropped`` counts them) — the same contract
    as ``FlowEventLog``'s ring-buffer mode, so a long-horizon simulation's
    periodic ``snap()`` cannot grow memory without limit.  ``None`` (the
    default) keeps the unbounded behaviour."""

    def __init__(self, *, series_maxlen: int | None = None):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series: deque[tuple[float, dict]] = deque(maxlen=series_maxlen)
        self.series_maxlen = series_maxlen
        self.series_dropped = 0

    # -- cells ---------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(
        self, name: str, bounds: Iterable[float] = DEFAULT_LATENCY_BUCKETS_S
    ) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, bounds)
        return h

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """Sorted, JSON-ready view of every cell."""
        return {
            "counters": {k: self.counters[k].value for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k].value for k in sorted(self.gauges)},
            "histograms": {
                k: self.histograms[k].as_dict() for k in sorted(self.histograms)
            },
        }

    def flat(self) -> dict[str, float]:
        """One flat name->number mapping (histograms contribute their count,
        sum and mean) — the shape ``BENCH_*.json`` perf records store."""
        out: dict[str, float] = {}
        for k in sorted(self.counters):
            out[k] = self.counters[k].value
        for k in sorted(self.gauges):
            out[k] = self.gauges[k].value
        for k in sorted(self.histograms):
            h = self.histograms[k]
            out[f"{k}.count"] = float(h.count)
            out[f"{k}.sum"] = h.total
            out[f"{k}.mean"] = h.mean
        return out

    def snap(self, t: float) -> None:
        """Append a timestamped snapshot to ``series`` (the periodic-
        snapshot hook a monitor loop calls).  At ``series_maxlen`` the
        oldest snapshot is evicted and counted in ``series_dropped``."""
        if (self.series_maxlen is not None
                and len(self.series) == self.series_maxlen):
            self.series_dropped += 1
        self.series.append((float(t), self.snapshot()))


class StatBlock:
    """Base for stats dataclasses: ``as_dict()`` + optional registry backing.

    Subclasses stay ordinary mutable dataclasses — every existing
    ``stats.field += 1`` call site is untouched.  After ``bind(registry,
    prefix)``, each assignment is mirrored to ``registry.counter(f"{prefix}.
    {field}")``, which is what unifies the previously divergent hand-rolled
    counter patterns behind one queryable surface."""

    def as_dict(self) -> dict[str, float]:
        return {
            f.name: getattr(self, f.name) for f in dataclasses.fields(self)  # type: ignore[arg-type]
        }

    def bind(self, registry: MetricRegistry, prefix: str) -> "StatBlock":
        object.__setattr__(self, "_reg", registry)
        object.__setattr__(self, "_prefix", prefix)
        for name, value in self.as_dict().items():
            registry.counter(f"{prefix}.{name}").set(value)
        return self

    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        reg = self.__dict__.get("_reg")
        if reg is not None and not name.startswith("_"):
            reg.counter(f"{self.__dict__['_prefix']}.{name}").set(value)
