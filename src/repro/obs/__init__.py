"""Cross-layer observability: span tracing, metric registry, exporters.

``repro.obs`` is the one place simulation-time telemetry lives:

  * :mod:`repro.obs.trace` — deterministic span tracer + the FlowSim
    :class:`NetEventBridge`;
  * :mod:`repro.obs.metrics` — counters/gauges/histograms behind one
    :class:`MetricRegistry`, plus the :class:`StatBlock` base the serving
    stats dataclasses share;
  * :mod:`repro.obs.export` — Chrome trace-event JSON (Perfetto) and the
    deterministic text form the golden tests pin;
  * :mod:`repro.obs.report` — TTFT attribution CLI
    (``python -m repro.obs.report``).

Everything here is **off by default**: the :data:`NULL_TRACER` no-op is
the universal default collaborator, so an un-instrumented run has zero
behavioural or output difference.
"""

from repro.obs.export import chrome_trace, load_chrome, text_trace
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    StatBlock,
)
from repro.obs.trace import NULL_TRACER, NetEventBridge, NullTracer, Span, Tracer

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "NetEventBridge",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "StatBlock",
    "DEFAULT_LATENCY_BUCKETS_S",
    "chrome_trace",
    "text_trace",
    "load_chrome",
]
