"""Cross-layer observability: span tracing, metric registry, exporters.

``repro.obs`` is the one place simulation-time telemetry lives:

  * :mod:`repro.obs.trace` — deterministic span tracer + the FlowSim
    :class:`NetEventBridge`;
  * :mod:`repro.obs.metrics` — counters/gauges/histograms behind one
    :class:`MetricRegistry`, plus the :class:`StatBlock` base the serving
    stats dataclasses share;
  * :mod:`repro.obs.ledger` — the fleet utilization ledgers: exclusive-
    state device-second accounting (with an exact conservation invariant)
    and per-link busy-time attribution by flow kind;
  * :mod:`repro.obs.slo` — streaming SLO monitor: P² quantiles, burn-rate
    windows, ``fleet_health()``;
  * :mod:`repro.obs.export` — Chrome trace-event JSON (Perfetto) and the
    deterministic text form the golden tests pin;
  * :mod:`repro.obs.report` — TTFT attribution CLI
    (``python -m repro.obs.report``);
  * :mod:`repro.obs.critical_path` — scale-operation makespan attribution
    (``python -m repro.obs.report --scale-ops``) with an exact
    conservation invariant (segments telescope to the span window in
    rational arithmetic);
  * :mod:`repro.obs.flightrec` — anomaly-triggered flight recorder:
    always-on NetEvent ring + deterministic Perfetto-loadable incident
    bundles on SLO page / device failure;
  * :mod:`repro.obs.perfdiff` — BENCH_*.json perf-regression differ
    (``python -m repro.obs.perfdiff``), the CI perf gate.

Everything here is **off by default**: the :data:`NULL_TRACER` no-op is
the universal default collaborator, so an un-instrumented run has zero
behavioural or output difference.
"""

from repro.obs.critical_path import (
    SCALE_SEGMENTS,
    BottleneckHop,
    ScaleOpReport,
    analyze_scale_ops,
    format_scale_report,
    summarize_scale_ops,
)
from repro.obs.export import chrome_trace, chrome_trace_doc, load_chrome, text_trace
from repro.obs.flightrec import FlightRecorder
from repro.obs.ledger import DEVICE_STATES, DeviceTimeLedger, LinkLedger
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    StatBlock,
)
from repro.obs.slo import P2Quantile, SLOMonitor
from repro.obs.trace import NULL_TRACER, NetEventBridge, NullTracer, Span, Tracer

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "NetEventBridge",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "StatBlock",
    "DEFAULT_LATENCY_BUCKETS_S",
    "DEVICE_STATES",
    "DeviceTimeLedger",
    "LinkLedger",
    "P2Quantile",
    "SLOMonitor",
    "chrome_trace",
    "chrome_trace_doc",
    "text_trace",
    "load_chrome",
    "SCALE_SEGMENTS",
    "BottleneckHop",
    "ScaleOpReport",
    "analyze_scale_ops",
    "summarize_scale_ops",
    "format_scale_report",
    "FlightRecorder",
]
