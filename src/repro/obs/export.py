"""Trace exporters: Chrome trace-event JSON (Perfetto-viewable) + text form.

``chrome_trace`` renders a span list as the Chrome ``traceEvents`` JSON
format — load the file at https://ui.perfetto.dev (or chrome://tracing) to
see request lifecycles and scale operations on per-track lanes.  Output is
**byte-deterministic** for a deterministic span list: spans are emitted in
sid order, dict keys are sorted, track→tid assignment follows first
appearance, and every number derives from simulation state (never the wall
clock) — which is what lets the golden test pin a seeded run's export
byte-for-byte.

``text_trace`` is the compact one-line-per-span form the unit tests diff;
``load_chrome`` parses an exported JSON back into :class:`Span` objects so
``repro.obs.report`` can analyse traces from disk as well as in-process.
"""

from __future__ import annotations

import json
from typing import Iterable

from repro.obs.trace import Span

__all__ = ["chrome_trace", "chrome_trace_doc", "text_trace", "load_chrome"]

_US = 1e6  # chrome trace timestamps are microseconds


def _clean(v):
    """JSON-safe attr values (tuples -> lists; exotic objects -> repr-free
    str so no memory addresses can leak into a golden file)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_clean(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _clean(x) for k, x in sorted(v.items())}
    return str(v)


def _tid_for(spans: Iterable[Span]) -> dict[str, int]:
    """track name -> tid, in order of first appearance (deterministic)."""
    tids: dict[str, int] = {}
    for s in spans:
        name = s.track or "main"
        if name not in tids:
            tids[name] = len(tids) + 1
    return tids


def chrome_trace_doc(spans: list[Span], *, pid: int = 1) -> dict:
    """The Chrome trace-event document as a dict — callers that need to
    attach extra top-level keys (Perfetto ignores unknown ones, which is
    what lets the flight recorder ship a single-file incident bundle that
    still loads in the trace viewer) embed alongside ``traceEvents``
    before serializing."""
    ordered = sorted(spans, key=lambda s: s.sid)
    tids = _tid_for(ordered)
    events: list[dict] = [
        {
            "ph": "M",
            "pid": pid,
            "tid": tid,
            "name": "thread_name",
            "args": {"name": track},
        }
        for track, tid in tids.items()
    ]
    for s in ordered:
        t1 = s.t1 if s.t1 is not None else s.t0
        args = {"sid": s.sid, "parent": s.parent}
        for k, v in s.attrs.items():
            args[k] = _clean(v)
        base = {
            "name": s.name,
            "cat": s.cat or "default",
            "pid": pid,
            "tid": tids[s.track or "main"],
            "ts": s.t0 * _US,
            "args": args,
        }
        if t1 > s.t0:
            base["ph"] = "X"
            base["dur"] = (t1 - s.t0) * _US
        else:
            base["ph"] = "i"
            base["s"] = "t"
        events.append(base)
    return {"displayTimeUnit": "ms", "traceEvents": events}


def chrome_trace(spans: list[Span], *, pid: int = 1) -> str:
    """Render ``spans`` as a Chrome trace-event JSON string."""
    return json.dumps(
        chrome_trace_doc(spans, pid=pid),
        sort_keys=True,
        separators=(",", ":"),
    )


def text_trace(spans: list[Span]) -> list[str]:
    """One deterministic line per span: ``sid parent cat name t0 t1 k=v…``
    (repr floats — bit-for-bit comparable, like the flow-event golden)."""
    out = []
    for s in sorted(spans, key=lambda x: x.sid):
        parts = [
            str(s.sid),
            str(s.parent) if s.parent is not None else "-",
            s.cat or "-",
            s.name,
            repr(float(s.t0)),
            repr(float(s.t1)) if s.t1 is not None else "open",
        ]
        for k in sorted(s.attrs):
            parts.append(f"{k}={_clean(s.attrs[k])}")
        out.append(" ".join(parts))
    return out


def load_chrome(source: str) -> list[Span]:
    """Parse a ``chrome_trace`` export (JSON string or file path) back into
    spans — the report CLI's on-disk entry point."""
    text = source
    if not source.lstrip().startswith("{"):
        with open(source) as f:
            text = f.read()
    doc = json.loads(text)
    tracks: dict[int, str] = {}
    spans: list[Span] = []
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            tracks[e["tid"]] = e["args"]["name"]
    for e in doc.get("traceEvents", []):
        ph = e.get("ph")
        if ph not in ("X", "i"):
            continue
        args = dict(e.get("args", {}))
        sid = args.pop("sid", len(spans))
        parent = args.pop("parent", None)
        t0 = e["ts"] / _US
        t1 = t0 + (e.get("dur", 0.0) / _US)
        spans.append(
            Span(
                sid=sid,
                name=e["name"],
                cat=e.get("cat", ""),
                t0=t0,
                t1=t1,
                parent=parent,
                track=tracks.get(e.get("tid")),
                attrs=args,
            )
        )
    spans.sort(key=lambda s: s.sid)
    return spans
