"""Span-based cross-layer tracer with deterministic IDs.

A :class:`Span` is one named, categorised interval of simulation time with
an optional causal parent — request lifecycle spans
(``request`` → ``load_wait``/``queue`` → ``prefill`` → ``kv_transfer`` →
``decode``) and scale-operation spans (``scale_op`` → ``plan`` →
``flow:multicast_hop`` → ``layer_arrival`` → ``serving``) both nest this
way.  IDs are monotone integers assigned in emission order and times come
from the caller's simulation clock, never the wall clock, so a seeded run
produces a byte-identical trace every time (the golden-trace property the
Chrome-export tests pin).

Tracing is **zero-cost when disabled**: the default collaborator everywhere
is :data:`NULL_TRACER`, whose methods are argument-ignoring no-ops that
return a shared dummy span, and instrumented call sites guard any non-
trivial attribute computation behind ``tracer.enabled``.  No subscriber is
attached to the FlowSim unless a real tracer is installed, so existing
golden flow-event traces are bit-for-bit unchanged.

:class:`NetEventBridge` adapts the FlowSim's :class:`~repro.net.events
.NetEvent` subscription stream into spans: each flow's started→completed/
aborted lifecycle becomes one ``network``-category span, and scenario
mutations (link degraded/failed, device/leaf failed) become instant
events.  ``pin(flow, parent)`` attaches a causal parent *before* the flow
starts — how a KV stream lands under its request span and a multicast hop
under its scale operation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.net import events as ev

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER", "NetEventBridge"]


@dataclasses.dataclass
class Span:
    """One interval of simulation time.  ``t1 is None`` = still open;
    ``t1 == t0`` = instant event."""

    sid: int
    name: str
    cat: str
    t0: float
    t1: float | None = None
    parent: int | None = None  # parent span's sid (causal link)
    track: str | None = None  # display lane (Chrome-trace thread)
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    @property
    def closed(self) -> bool:
        return self.t1 is not None


_NULL_SPAN = Span(sid=-1, name="", cat="", t0=0.0, t1=0.0)


class Tracer:
    """Collects spans; IDs are emission-ordered integers (deterministic)."""

    enabled = True

    def __init__(self):
        self.spans: list[Span] = []
        self._next_sid = 0

    def _parent_sid(self, parent) -> int | None:
        if isinstance(parent, Span):
            return parent.sid if parent.sid >= 0 else None
        return parent

    def begin(
        self,
        name: str,
        t: float,
        *,
        cat: str = "",
        parent: "Span | int | None" = None,
        track: str | None = None,
        **attrs: Any,
    ) -> Span:
        """Open a span at simulation time ``t``.  ``track`` defaults to the
        parent's (children render in their root's display lane)."""
        if track is None and isinstance(parent, Span) and parent.sid >= 0:
            track = parent.track
        s = Span(
            self._next_sid, name, cat, float(t),
            parent=self._parent_sid(parent), track=track, attrs=attrs,
        )
        self._next_sid += 1
        self.spans.append(s)
        return s

    def end(self, span: Span, t: float, **attrs: Any) -> None:
        """Close ``span`` at ``t`` (clamped so t1 >= t0; re-closing and the
        null span are no-ops)."""
        if span is None or span.sid < 0 or span.t1 is not None:
            return
        span.t1 = max(float(t), span.t0)
        if attrs:
            span.attrs.update(attrs)

    def span(
        self, name: str, t0: float, t1: float, **kw: Any
    ) -> Span:
        """Emit an already-closed span (for intervals known only in
        hindsight, e.g. queue wait measured when service starts)."""
        s = self.begin(name, t0, **kw)
        s.t1 = max(float(t1), s.t0)
        return s

    def instant(self, name: str, t: float, **kw: Any) -> Span:
        s = self.begin(name, t, **kw)
        s.t1 = s.t0
        return s

    # -- lifecycle -----------------------------------------------------------
    def open_spans(self) -> list[Span]:
        return [s for s in self.spans if s.t1 is None]

    def close_open(self, t: float, **attrs: Any) -> int:
        """Close every still-open span at ``t`` (end of a run: background
        flows and unfinished requests must not leave dangling spans).
        Returns how many were closed."""
        n = 0
        for s in self.spans:
            if s.t1 is None:
                self.end(s, t, **attrs)
                n += 1
        return n

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def children_of(self, span: Span) -> list[Span]:
        return [s for s in self.spans if s.parent == span.sid]


class NullTracer:
    """The zero-cost default: every method is a no-op returning a shared
    dummy span, so instrumented code never branches on ``None``."""

    enabled = False
    spans: tuple = ()

    def begin(self, *a: Any, **kw: Any) -> Span:
        return _NULL_SPAN

    def end(self, *a: Any, **kw: Any) -> None:
        return None

    def span(self, *a: Any, **kw: Any) -> Span:
        return _NULL_SPAN

    def instant(self, *a: Any, **kw: Any) -> Span:
        return _NULL_SPAN

    def close_open(self, *a: Any, **kw: Any) -> int:
        return 0


NULL_TRACER = NullTracer()


class NetEventBridge:
    """FlowSim subscriber turning :class:`NetEvent`\\ s into spans.

    Subscribe it exactly like a :class:`FlowEventLog`::

        bridge = NetEventBridge(tracer)
        flowsim.subscribe(bridge)

    Flow lifecycle edges open/close one span per flow; scenario mutations
    become instant events.  A consumer that knows a flow's causal context
    calls ``pin(flow, parent_span)`` before starting it — optionally
    renaming/recategorising the span (the simulator pins per-request KV
    flows as ``kv_transfer``/``migration`` under the request span)."""

    def __init__(self, tracer: Tracer):
        self.tracer = tracer
        self._open: dict[int, Span] = {}  # id(flow) -> span
        self._pins: dict[int, tuple] = {}  # id(flow) -> (parent, name, cat)
        # (parent sid, chain) -> {hop idx -> first hop-span sid}: lets hop k
        # record its upstream hop's sid as an ATTR rather than a tree parent
        # (pipelined hops overlap their upstream's interval, so tree-nesting
        # them would violate the child-within-parent well-formedness that
        # the span tests pin)
        self._hops: dict[tuple, dict[int, int]] = {}

    def pin(
        self, flow, parent: Span | None, *, name: str | None = None,
        cat: str | None = None,
    ) -> None:
        self._pins[id(flow)] = (parent, name, cat)

    def pin_all(self, flows, parent: Span | None, **kw: Any) -> None:
        for f in flows:
            self.pin(f, parent, **kw)

    def __call__(self, event: ev.NetEvent) -> None:
        k = event.kind
        if k == ev.FLOW_STARTED:
            f = event.flow
            parent, name, cat = self._pins.pop(id(f), (None, None, None))
            extra: dict[str, Any] = {}
            chain = getattr(f, "chain", None)
            key = None
            if chain is not None:
                extra["chain"] = chain
                extra["hop"] = f.hop
                if f.extra_latency_s > 0.0:
                    # the store-and-forward prefix charged for upstream hops:
                    # the critical-path analyzer splits a hop's duration into
                    # latency vs bandwidth/contention with it
                    extra["lat"] = f.extra_latency_s
                psid = parent.sid if isinstance(parent, Span) else parent
                if psid is not None:  # unpinned flows have no stable scope
                    key = (psid, chain)
                    up = self._hops.get(key, {}).get(f.hop - 1)
                    if up is not None:
                        extra["upstream"] = up  # sid of the hop this one forwards
            sp = self.tracer.begin(
                name or f"flow:{f.kind.value}",
                event.t,
                cat=cat or "network",
                parent=parent,
                track=None if parent is not None else "net",
                kind=f.kind.value,
                src=f.src,
                dst=f.dst,
                size=float(f.size),
                tag=f.tag,
                **extra,
            )
            if key is not None:
                self._hops.setdefault(key, {}).setdefault(f.hop, sp.sid)
            self._open[id(f)] = sp
        elif k in (ev.FLOW_COMPLETED, ev.FLOW_ABORTED):
            sp = self._open.pop(id(event.flow), None)
            if sp is not None:
                if k == ev.FLOW_ABORTED:
                    self.tracer.end(sp, event.t, aborted=True)
                else:
                    self.tracer.end(sp, event.t)
        else:  # link/device/leaf scenario mutations
            attrs: dict[str, Any] = {}
            if event.link_key is not None:
                attrs["link"] = ":".join(str(x) for x in event.link_key)
            if event.device is not None:
                attrs["device"] = event.device
            if event.leaf is not None:
                attrs["leaf"] = event.leaf
            self.tracer.instant(k, event.t, cat="net", track="net", **attrs)
