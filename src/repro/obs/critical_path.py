"""Scale-operation critical-path attribution: where did the makespan go?

The TTFT report (:mod:`repro.obs.report`) explains the *request* side of
the paper's headline; this module explains the *scaling* side.  λScale's
observation — and BLITZSCALE's Fig. 13/14 design pressure — is that a
scale-up's makespan is dominated by whichever multicast chain hop or
layer-stall sits on the critical path.  For every closed ``scale_op``
span this analyzer partitions the end-to-end window ``[t0, t1]`` into
causally-ordered, mutually-exclusive segments:

  * ``plan``     — Algorithm-11 plan generation (the ``plan`` instant's
                   offset from the op start; zero in the simulator, where
                   planning is modelled as instantaneous);
  * ``queue``    — grant/queue wait: the op is decided but no parameter
                   byte is moving yet (fleet arbitration latency, FlowSim
                   admission);
  * ``transfer`` — at least one of the op's pinned parameter flows
                   (multicast hop / AllGather / cold-start unicast) is in
                   flight;
  * ``stall``    — no flow is moving but downstream instances are still
                   waiting on layer propagation (the
                   ``stalled_waiting_layers`` window the DeviceTimeLedger
                   accrues device-side);
  * ``cutover``  — every flow has landed; the control-plane activation
                   window (CUDA-context pool / pre-lowered executables,
                   §A.1) until the op closes.

**Conservation is exact, not within-epsilon**: segment values are
accumulated in rational arithmetic (``fractions.Fraction`` over the span
boundaries, which represents every float exactly), and the elementary
intervals telescope, so ``sum(exact_breakdown().values()) ==
Fraction(t1) - Fraction(t0)`` holds bit-for-bit for every op — the same
conservation-by-construction idiom as the
:class:`~repro.obs.ledger.DeviceTimeLedger`.  The float view
(:meth:`ScaleOpReport.breakdown`) sums in one fixed segment order, so
``sum(breakdown().values()) == attributed_s`` is also exact.

The analyzer also identifies the **bottleneck hop** — the longest pinned
parameter flow — and classifies why it was slow:

  * ``latency``    — the store-and-forward prefix (the ``lat`` attr the
                     tracer bridge stamps from ``Flow.extra_latency_s``)
                     dominates its duration: a deep chain under per-hop
                     switching delay, the thing the latency-aware planner
                     trades width against;
  * ``contention`` — its realized rate fell well below the best sibling
                     hop's rate: another flow squeezed its max-min share
                     (the competing flow-kind group is named from the
                     :class:`~repro.obs.ledger.LinkLedger` when one is
                     attached);
  * ``bandwidth``  — neither: the hop ran at (or near) the best rate any
                     hop achieved — link-rate bound, the healthy case.

CLI: ``python -m repro.obs.report --sim --scale-ops`` (the
``--min-makespan-attribution`` flag is the CI gate mirroring the ≥95%
TTFT-attribution gate).
"""

from __future__ import annotations

import dataclasses
from fractions import Fraction

from repro.obs.trace import Span

__all__ = [
    "SCALE_SEGMENTS",
    "BottleneckHop",
    "ScaleOpReport",
    "analyze_scale_ops",
    "summarize_scale_ops",
    "format_scale_report",
]

#: exclusive makespan segments; the FIXED summation order behind the
#: conservation invariant — never reorder (attributed_s and breakdown()
#: both iterate it, which is what makes their sums bit-identical)
SCALE_SEGMENTS = ("plan", "queue", "transfer", "stall", "cutover")

#: a hop whose realized rate is below this fraction of the best sibling
#: hop's rate lost its max-min share to competing traffic
_CONTENTION_RATE_FRAC = 0.7
#: a hop whose store-and-forward prefix exceeds this fraction of its
#: duration is latency-bound, not bandwidth-bound
_LATENCY_SHARE = 0.5


@dataclasses.dataclass
class BottleneckHop:
    """The longest parameter flow of one scale op + why it was slow."""

    sid: int
    tag: str
    kind: str
    src: int
    dst: int
    t0: float
    t1: float
    size: float
    chain: int | None
    hop: int | None
    upstream: int | None  # sid of the hop this one forwarded (attr link)
    latency_s: float  # store-and-forward prefix charged to this hop
    cause: str  # latency | contention | bandwidth
    competing_group: str | None = None  # from the LinkLedger, if attached

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    @property
    def rate(self) -> float:
        return self.size / self.duration if self.duration > 0 else 0.0


@dataclasses.dataclass
class ScaleOpReport:
    """One ``scale_op`` span's exact makespan partition."""

    sid: int
    t0: float
    t1: float
    phase: str
    plane: str
    n_instances: int
    n_flows: int
    segments_exact: dict[str, Fraction]  # SCALE_SEGMENTS order, exact
    bottleneck: BottleneckHop | None
    aborted: bool = False

    @property
    def makespan(self) -> float:
        return self.t1 - self.t0

    def breakdown(self) -> dict[str, float]:
        """Per-segment seconds, every segment present, SCALE_SEGMENTS
        order (the float view of the exact partition)."""
        return {s: float(self.segments_exact[s]) for s in SCALE_SEGMENTS}

    @property
    def attributed_s(self) -> float:
        """Summed in SCALE_SEGMENTS order — the same floats in the same
        order as ``sum(breakdown().values())``, so that check is exact."""
        t = 0.0
        for s in SCALE_SEGMENTS:
            t += float(self.segments_exact[s])
        return t

    @property
    def coverage(self) -> float:
        """attributed / makespan — the CI-gated fraction (≥0.95 mirrors
        the TTFT-attribution gate).  1.0 for zero-width ops."""
        if self.makespan <= 0.0:
            return 1.0
        return self.attributed_s / self.makespan

    def conserved(self) -> bool:
        """The exact invariant: segments telescope to the span window in
        rational arithmetic — bit-for-bit, every op, every seed."""
        total = Fraction(0)
        for s in SCALE_SEGMENTS:
            total += self.segments_exact[s]
        return total == Fraction(self.t1) - Fraction(self.t0)

    def as_dict(self) -> dict:
        d = {
            "sid": self.sid,
            "t0": self.t0,
            "t1": self.t1,
            "phase": self.phase,
            "plane": self.plane,
            "n_instances": self.n_instances,
            "n_flows": self.n_flows,
            "makespan_s": self.makespan,
            "segments_s": self.breakdown(),
            "attributed_s": self.attributed_s,
            "coverage": self.coverage,
            "aborted": self.aborted,
        }
        if self.bottleneck is not None:
            d["bottleneck"] = dataclasses.asdict(self.bottleneck)
        return d


def _descendants(spans: list[Span], root: Span) -> list[Span]:
    kids: dict[int, list[Span]] = {}
    for s in spans:
        if s.parent is not None:
            kids.setdefault(s.parent, []).append(s)
    out: list[Span] = []
    stack = [root.sid]
    while stack:
        sid = stack.pop()
        for c in kids.get(sid, ()):
            out.append(c)
            stack.append(c.sid)
    return out


def _is_flow(span: Span) -> bool:
    return span.name.startswith("flow:") or span.cat == "network"


def _classify(flow: Span, best_rate: float, link_ledger) -> tuple[str, str | None]:
    dur = (flow.t1 or flow.t0) - flow.t0
    lat = float(flow.attrs.get("lat", 0.0))
    if dur > 0.0 and lat / dur >= _LATENCY_SHARE:
        return "latency", None
    rate = float(flow.attrs.get("size", 0.0)) / dur if dur > 0 else 0.0
    if best_rate > 0.0 and rate < _CONTENTION_RATE_FRAC * best_rate:
        competing = None
        if link_ledger is not None:
            busy = {g: v for g, v in link_ledger.busy_by_group().items()
                    if g != "multicast"}
            if busy:
                competing = max(sorted(busy), key=lambda g: busy[g])
        return "contention", competing
    return "bandwidth", None


def _analyze_one(op: Span, spans: list[Span], link_ledger) -> ScaleOpReport:
    desc = _descendants(spans, op)
    flows = sorted(
        (s for s in desc if _is_flow(s) and s.t1 is not None),
        key=lambda s: s.sid,
    )
    plan_t = min(
        (s.t0 for s in desc if s.name == "plan"), default=op.t0
    )
    t0, t1 = op.t0, op.t1

    # elementary boundaries: every flow edge (clipped to the window) plus
    # the plan instant and, for flowless simple planes, the recorded
    # control-plane tail — elementary intervals never straddle a label edge
    cuts = {t0, t1}
    if t0 < plan_t < t1:
        cuts.add(plan_t)
    for f in flows:
        for x in (f.t0, f.t1):
            if t0 < x < t1:
                cuts.add(x)
    # the recorded control-plane activation window bounds the cutover
    # segment from the right: anything between the last flow landing and
    # that window is a *stall* (straggler instances, retired-before-active
    # engines), not cutover
    control_s = float(op.attrs.get("control_s", 0.0))
    ctl_cut = max(t0, t1 - control_s) if control_s > 0.0 else None
    if ctl_cut is not None and t0 < ctl_cut < t1:
        cuts.add(ctl_cut)
    bounds = sorted(cuts)

    first_flow = min((f.t0 for f in flows), default=t1)
    last_flow = max((f.t1 for f in flows), default=t0)

    def label(a: float, b: float) -> str:
        if flows:
            for f in flows:
                if f.t0 <= a and f.t1 >= b:
                    return "transfer"
            if b <= plan_t:
                return "plan"
            if b <= first_flow:
                return "queue"
            if a >= last_flow:
                if ctl_cut is None or a >= ctl_cut:
                    return "cutover"
                return "stall"  # flows landed, instances still not active
            return "stall"  # a gap while downstream hops are still pending
        # simple data planes (ssd / hostcache / delay): one opaque load
        # interval; the span records the control-plane tail so the cutover
        # carve-out is exact, the rest is the data-plane transfer
        if ctl_cut is not None and a >= ctl_cut:
            return "cutover"
        if b <= plan_t:
            return "plan"
        return "transfer"

    seg = {s: Fraction(0) for s in SCALE_SEGMENTS}
    for a, b in zip(bounds, bounds[1:]):
        seg[label(a, b)] += Fraction(b) - Fraction(a)

    # bottleneck: the longest parameter hop (ties -> lowest sid); prefer
    # multicast hops, fall back to whatever flow the op actually moved
    hops = [f for f in flows if f.attrs.get("kind") == "multicast_hop"] or flows
    bottleneck = None
    if hops:
        best_rate = max(
            (float(f.attrs.get("size", 0.0)) / (f.t1 - f.t0)
             for f in hops if f.t1 > f.t0),
            default=0.0,
        )
        worst = max(hops, key=lambda f: (f.t1 - f.t0, -f.sid))
        cause, competing = _classify(worst, best_rate, link_ledger)
        bottleneck = BottleneckHop(
            sid=worst.sid,
            tag=str(worst.attrs.get("tag", "")),
            kind=str(worst.attrs.get("kind", "")),
            src=int(worst.attrs.get("src", -1)),
            dst=int(worst.attrs.get("dst", -1)),
            t0=worst.t0,
            t1=worst.t1,
            size=float(worst.attrs.get("size", 0.0)),
            chain=worst.attrs.get("chain"),
            hop=worst.attrs.get("hop"),
            upstream=worst.attrs.get("upstream"),
            latency_s=float(worst.attrs.get("lat", 0.0)),
            cause=cause,
            competing_group=competing,
        )

    aborted = bool(op.attrs.get("aborted")) or any(
        s.attrs.get("aborted") for s in desc if s.cat == "load"
    )
    return ScaleOpReport(
        sid=op.sid,
        t0=t0,
        t1=t1,
        phase=str(op.attrs.get("phase", "?")),
        plane=str(op.attrs.get("plane", "?")),
        n_instances=int(op.attrs.get("n_instances", 1)),
        n_flows=len(flows),
        segments_exact=seg,
        bottleneck=bottleneck,
        aborted=aborted,
    )


def analyze_scale_ops(spans, *, link_ledger=None) -> list[ScaleOpReport]:
    """Partition every closed ``scale_op`` span's makespan.  Accepts the
    tracer's span list or one re-loaded from a Chrome export
    (:func:`repro.obs.export.load_chrome`)."""
    spans = list(spans)
    return [
        _analyze_one(op, spans, link_ledger)
        for op in sorted(spans, key=lambda s: s.sid)
        if op.name == "scale_op" and op.t1 is not None
    ]


def summarize_scale_ops(reports: list[ScaleOpReport]) -> dict:
    """Aggregate view: coverage (the CI gate input), per-segment totals,
    and the bottleneck-cause census."""
    if not reports:
        return {"n_ops": 0}
    totals = {s: 0.0 for s in SCALE_SEGMENTS}
    for r in reports:
        for s, v in r.breakdown().items():
            totals[s] += v
    makespans = sorted(r.makespan for r in reports)
    causes: dict[str, int] = {}
    for r in reports:
        if r.bottleneck is not None:
            causes[r.bottleneck.cause] = causes.get(r.bottleneck.cause, 0) + 1
    worst = min(reports, key=lambda r: r.coverage)
    grand = sum(totals.values())
    return {
        "n_ops": len(reports),
        "n_aborted": sum(1 for r in reports if r.aborted),
        "min_coverage": worst.coverage,
        "worst_op_sid": worst.sid,
        "mean_coverage": sum(r.coverage for r in reports) / len(reports),
        "makespan_mean_s": sum(makespans) / len(makespans),
        "makespan_max_s": makespans[-1],
        "segment_totals_s": totals,
        "segment_shares": {
            s: (totals[s] / grand if grand > 0 else 0.0) for s in SCALE_SEGMENTS
        },
        "bottleneck_causes": {c: causes[c] for c in sorted(causes)},
        "ops": [r.as_dict() for r in reports],
    }


def format_scale_report(reports: list[ScaleOpReport],
                        summary: dict | None = None) -> str:
    """Deterministic text report (the golden test pins one)."""
    if not reports:
        return "no closed scale_op spans in trace"
    summary = summary if summary is not None else summarize_scale_ops(reports)
    lines = [
        f"scale ops analysed: {summary['n_ops']} "
        f"({summary['n_aborted']} aborted)",
        f"makespan attribution: min {summary['min_coverage'] * 100:.2f}% / "
        f"mean {summary['mean_coverage'] * 100:.2f}%",
        "",
        "| op | phase | t0 (s) | makespan (ms) | "
        + " | ".join(SCALE_SEGMENTS)
        + " | bottleneck | cause |",
        "|---|---|---|---|" + "---|" * len(SCALE_SEGMENTS) + "---|---|",
    ]
    for r in reports:
        b = r.breakdown()
        cells = " | ".join(f"{b[s] * 1e3:.3f}" for s in SCALE_SEGMENTS)
        bn = r.bottleneck
        lines.append(
            f"| {r.sid} | {r.phase} | {r.t0:.6f} | {r.makespan * 1e3:.3f} "
            f"| {cells} "
            f"| {bn.tag if bn else '-'} | {bn.cause if bn else '-'} |"
        )
    lines.append("")
    shares = summary["segment_shares"]
    dominant = max(SCALE_SEGMENTS, key=lambda s: shares[s])
    lines.append(
        "fleet-wide makespan shares: "
        + ", ".join(f"{s} {shares[s] * 100:.1f}%" for s in SCALE_SEGMENTS)
    )
    lines.append(f"scale-up makespan is dominated by: {dominant}")
    causes = summary["bottleneck_causes"]
    if causes:
        lines.append(
            "bottleneck hops: "
            + ", ".join(f"{c}={n}" for c, n in causes.items())
        )
    return "\n".join(lines)
