"""BENCH_*.json perf-record differ — the CI perf-regression gate.

The repo records its perf trajectory as ``BENCH_<name>.json`` files
(``benchmarks.common.bench_record``: flat metrics + git SHA + seed + smoke
flag).  Committed records ARE the baseline; this tool diffs a fresh run
against them metric-by-metric and exits non-zero on regressions::

    PYTHONPATH=src python -m repro.obs.perfdiff OLD.json NEW.json --tolerance 0.1
    PYTHONPATH=src python -m repro.obs.perfdiff benchmarks/baselines/smoke . \\
        --tolerance 0.25 --json-out perfdiff_report.json

OLD/NEW are single records or directories of them (directory mode pairs
files by name — the CI job points OLD at the committed smoke baselines and
NEW at the repo root where the fresh smoke run just wrote).

Per-metric **direction rules** (first ``fnmatch`` wins) decide what counts
as a regression:

  * ``lower_better``  — latency/GPU-time style: worse when it grows;
  * ``higher_better`` — attainment/throughput style: worse when it shrinks;
  * ``either``        — deterministic counters/bytes: any drift beyond
    tolerance is flagged (a seeded simulation should not drift silently);
  * ``info``          — wall-clock timings: machine-dependent, never gate.

Tolerances are relative (``--tolerance``, per-rule overrides possible via
:func:`diff_records`' ``rules``); ``--atol`` floors the denominator so a
baseline of exactly 0 doesn't turn any noise into an infinite delta.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
from fnmatch import fnmatch

__all__ = [
    "DEFAULT_RULES",
    "rule_for",
    "MetricDiff",
    "DiffReport",
    "diff_records",
    "diff_paths",
    "main",
]

LOWER_BETTER = "lower_better"
HIGHER_BETTER = "higher_better"
EITHER = "either"
INFO = "info"

#: ``(metric-name pattern, direction)`` or ``(pattern, direction,
#: tolerance)`` — first match wins; a 3-tuple's tolerance overrides the
#: CLI-wide one for that metric.  Wall-clock timings never gate (CI
#: runners and dev machines disagree); simulated seconds/bytes/counters
#: are deterministic under a fixed seed, so any drift beyond tolerance is
#: worth failing loudly over.
DEFAULT_RULES: tuple[tuple, ...] = (
    ("*wall_s*", INFO),
    # tracing overhead IS wall-clock derived, but it's a ratio of two
    # timings taken back-to-back on the same machine, so it gates —
    # with a wide per-rule tolerance absorbing scheduler noise on top of
    # the benchmark's own min-of-repeats stabilisation
    ("*overhead_frac*", LOWER_BETTER, 2.0),
    ("*_ms*", INFO),  # plan-gen / ILP solver wall-clock
    # flow-event throughput of the net_scale benchmark is wall-clock
    # derived, so it gates only on near-total collapse (machine speed
    # varies; note a higher-better metric can never drop more than -100%,
    # so the tolerance must stay < 1.0 to gate at all); the
    # incremental-vs-full SPEEDUP is a same-machine ratio of back-to-back
    # runs, so it gates tighter — it is the metric that catches the
    # incremental engine quietly degenerating to full solves
    ("*events_per_s*", HIGHER_BETTER, 0.9),
    ("*speedup*", HIGHER_BETTER, 0.6),
    ("*attainment*", HIGHER_BETTER),
    ("*throughput*", HIGHER_BETTER),
    ("*ttft*", LOWER_BETTER),
    ("*tbt*", LOWER_BETTER),
    ("*latency*", LOWER_BETTER),
    ("*gpu_time*", LOWER_BETTER),
    ("*gpu_seconds*", LOWER_BETTER),
    ("*", EITHER),
)

_GATED = {LOWER_BETTER, HIGHER_BETTER, EITHER}


def rule_for(name: str, rules=DEFAULT_RULES) -> tuple[str, float | None]:
    """(direction, per-rule tolerance override or None) for ``name``."""
    for rule in rules:
        if fnmatch(name, rule[0]):
            return rule[1], (rule[2] if len(rule) > 2 else None)
    return EITHER, None


def direction_for(name: str, rules=DEFAULT_RULES) -> str:
    return rule_for(name, rules)[0]


@dataclasses.dataclass
class MetricDiff:
    bench: str
    name: str
    old: float | None
    new: float | None
    rel_delta: float  # (new-old)/max(|old|, atol); 0.0 for missing/added
    direction: str
    status: str  # ok | regression | improvement | info | missing | added

    def describe(self) -> str:
        if self.status == "missing":
            return f"{self.bench}:{self.name}: missing from new record"
        if self.status == "added":
            return f"{self.bench}:{self.name}: new metric (no baseline)"
        arrow = "+" if self.rel_delta >= 0 else ""
        return (
            f"{self.bench}:{self.name}: {self.old:g} -> {self.new:g} "
            f"({arrow}{self.rel_delta * 100:.1f}%, {self.direction})"
        )


@dataclasses.dataclass
class DiffReport:
    diffs: list[MetricDiff] = dataclasses.field(default_factory=list)
    warnings: list[str] = dataclasses.field(default_factory=list)

    def regressions(self) -> list[MetricDiff]:
        return [d for d in self.diffs if d.status == "regression"]

    def improvements(self) -> list[MetricDiff]:
        return [d for d in self.diffs if d.status == "improvement"]

    def missing(self) -> list[MetricDiff]:
        return [d for d in self.diffs if d.status == "missing"]

    def extend(self, other: "DiffReport") -> None:
        self.diffs.extend(other.diffs)
        self.warnings.extend(other.warnings)

    def as_dict(self) -> dict:
        return {
            "n_metrics": len(self.diffs),
            "n_regressions": len(self.regressions()),
            "n_improvements": len(self.improvements()),
            "n_missing": len(self.missing()),
            "warnings": list(self.warnings),
            "diffs": [dataclasses.asdict(d) for d in self.diffs],
        }

    def format(self, *, verbose: bool = False) -> str:
        lines = []
        for w in self.warnings:
            lines.append(f"WARNING: {w}")
        shown = [
            d for d in self.diffs
            if verbose or d.status in ("regression", "improvement", "missing")
        ]
        if shown:
            lines.append("| metric | old | new | delta | rule | status |")
            lines.append("|---|---|---|---|---|---|")
            order = {"regression": 0, "missing": 1, "improvement": 2}
            for d in sorted(shown, key=lambda d: (order.get(d.status, 3),
                                                  d.bench, d.name)):
                old = "-" if d.old is None else f"{d.old:g}"
                new = "-" if d.new is None else f"{d.new:g}"
                delta = (
                    "-" if d.old is None or d.new is None
                    else f"{d.rel_delta * 100:+.1f}%"
                )
                lines.append(
                    f"| {d.bench}:{d.name} | {old} | {new} | {delta} "
                    f"| {d.direction} | {d.status} |"
                )
        n_reg = len(self.regressions())
        lines.append(
            f"{len(self.diffs)} metric(s) compared: {n_reg} regression(s), "
            f"{len(self.improvements())} improvement(s), "
            f"{len(self.missing())} missing"
        )
        return "\n".join(lines)


def diff_records(
    old: dict,
    new: dict,
    *,
    tolerance: float = 0.1,
    atol: float = 1e-9,
    rules=DEFAULT_RULES,
) -> DiffReport:
    """Diff two ``bench_record`` dicts metric-by-metric."""
    rep = DiffReport()
    bench = old.get("bench", new.get("bench", "?"))
    if old.get("smoke") != new.get("smoke"):
        rep.warnings.append(
            f"{bench}: comparing smoke={old.get('smoke')} baseline against "
            f"smoke={new.get('smoke')} run — magnitudes are not comparable"
        )
    if old.get("schema") != new.get("schema"):
        rep.warnings.append(
            f"{bench}: record schema changed "
            f"({old.get('schema')} -> {new.get('schema')})"
        )
    om, nm = old.get("metrics", {}), new.get("metrics", {})
    for name in sorted(set(om) | set(nm)):
        if name not in nm:
            rep.diffs.append(MetricDiff(bench, name, float(om[name]), None,
                                        0.0, direction_for(name, rules),
                                        "missing"))
            continue
        if name not in om:
            rep.diffs.append(MetricDiff(bench, name, None, float(nm[name]),
                                        0.0, direction_for(name, rules),
                                        "added"))
            continue
        ov, nv = float(om[name]), float(nm[name])
        direction, rule_tol = rule_for(name, rules)
        tol = tolerance if rule_tol is None else rule_tol
        rel = (nv - ov) / max(abs(ov), atol)
        if direction == INFO:
            status = "info"
        elif direction == LOWER_BETTER:
            status = ("regression" if rel > tol
                      else "improvement" if rel < -tol else "ok")
        elif direction == HIGHER_BETTER:
            status = ("regression" if rel < -tol
                      else "improvement" if rel > tol else "ok")
        else:  # EITHER: a seeded run drifting either way is a finding
            status = "regression" if abs(rel) > tol else "ok"
        rep.diffs.append(MetricDiff(bench, name, ov, nv, rel, direction, status))
    return rep


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _records_in(path: str) -> dict[str, str]:
    """Map BENCH_*.json basename -> full path under a directory."""
    return {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(path, "BENCH_*.json"))
    }


def diff_paths(
    old_path: str,
    new_path: str,
    *,
    tolerance: float = 0.1,
    atol: float = 1e-9,
    rules=DEFAULT_RULES,
) -> DiffReport:
    """Diff two records, or two directories of records paired by filename."""
    if os.path.isdir(old_path) != os.path.isdir(new_path):
        raise ValueError("OLD and NEW must both be files or both directories")
    if not os.path.isdir(old_path):
        return diff_records(_load(old_path), _load(new_path),
                            tolerance=tolerance, atol=atol, rules=rules)
    rep = DiffReport()
    olds, news = _records_in(old_path), _records_in(new_path)
    if not olds:
        rep.warnings.append(f"no BENCH_*.json records under {old_path}")
    for name in sorted(olds):
        if name not in news:
            rep.warnings.append(f"{name}: baseline has no fresh counterpart")
            continue
        rep.extend(diff_records(_load(olds[name]), _load(news[name]),
                                tolerance=tolerance, atol=atol, rules=rules))
    for name in sorted(set(news) - set(olds)):
        rep.warnings.append(f"{name}: fresh record has no committed baseline")
    return rep


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.perfdiff",
        description="diff BENCH_*.json perf records; exit non-zero on "
        "regressions (per-metric direction rules, relative tolerance)",
    )
    ap.add_argument("old", help="baseline record or directory of records")
    ap.add_argument("new", help="fresh record or directory of records")
    ap.add_argument("--tolerance", type=float, default=0.1,
                    help="relative tolerance before a drift gates (default 0.1)")
    ap.add_argument("--atol", type=float, default=1e-9,
                    help="denominator floor for zero baselines")
    ap.add_argument("--json-out", default=None,
                    help="write the full diff report (JSON) here")
    ap.add_argument("--fail-on-missing", action="store_true",
                    help="also fail when a baseline metric disappeared")
    ap.add_argument("--verbose", action="store_true",
                    help="print every compared metric, not just findings")
    args = ap.parse_args(argv)

    rep = diff_paths(args.old, args.new, tolerance=args.tolerance,
                     atol=args.atol)
    print(rep.format(verbose=args.verbose))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rep.as_dict(), f, indent=1, sort_keys=True)
        print(f"report -> {args.json_out}")
    failed = bool(rep.regressions()) or (
        args.fail_on_missing and rep.missing()
    )
    if failed:
        print("PERF GATE: FAIL", file=sys.stderr)
        return 1
    print("PERF GATE: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
