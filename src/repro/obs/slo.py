"""Streaming SLO monitor: P² quantiles + burn-rate windows + fleet_health().

The paper's autoscaler (and the SLO-aware coordinated scaling of "Taming
the Chaos") assumes something watches SLO attainment *online* — not a
post-hoc percentile over a finished run.  This module is that watcher:

  * :class:`P2Quantile` — the Jain & Chlamtac P² streaming estimator:
    O(1) memory per quantile, no sample buffer, deterministic for a
    deterministic observation stream;
  * per-tenant TTFT/TBT quantiles plus **SLO burn rate** over sliding
    windows (SRE convention: ``violation_rate / error_budget``, so burn
    1.0 consumes the budget exactly at the sustainable pace, and a fast
    window burning >> 1 pages before the slow window notices);
  * :meth:`SLOMonitor.fleet_health` — one JSON-ready summary the
    FleetScheduler exposes (observe-only this PR: the fleet *reads* it,
    nothing acts on it yet — the hook is the point).

Feed it directly (``observe_ttft`` / ``observe_tbt``) or from a span trace
(:meth:`SLOMonitor.ingest_spans` consumes the tracer's ``request`` root
spans, whose ``ttft`` attr the simulator already stamps).
"""

from __future__ import annotations

__all__ = ["P2Quantile", "SLOMonitor", "DEFAULT_WINDOWS_S"]

from collections import deque

#: default burn-rate windows (seconds): a fast page window + a slow trend
DEFAULT_WINDOWS_S = (30.0, 300.0)


class P2Quantile:
    """Jain & Chlamtac's P² algorithm: streaming quantile in O(1) memory.

    Five markers track (min, q/2, q, (1+q)/2, max); marker heights move by
    piecewise-parabolic interpolation as observations arrive.  Until five
    observations exist the estimate is the nearest rank of the sorted
    buffer."""

    __slots__ = ("q", "_h", "_n", "_np", "_dn", "count")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self._h: list[float] = []  # marker heights (or first <5 observations)
        self._n: list[float] = []  # marker positions
        self._np: list[float] = []  # desired positions
        self._dn = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        if self.count <= 5:
            self._h.append(v)
            if self.count == 5:
                self._h.sort()
                self._n = [0.0, 1.0, 2.0, 3.0, 4.0]
                q = self.q
                self._np = [0.0, 2.0 * q, 4.0 * q, 2.0 + 2.0 * q, 4.0]
            return
        h, n = self._h, self._n
        if v < h[0]:
            h[0] = v
            k = 0
        elif v >= h[4]:
            h[4] = v
            k = 3
        else:
            k = 0
            while v >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self._np[i] += self._dn[i]
        for i in (1, 2, 3):
            d = self._np[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                d <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                d = 1.0 if d > 0 else -1.0
                # piecewise-parabolic candidate, linear fallback when the
                # parabola would break marker monotonicity
                hp = h[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1])
                )
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:
                    j = i + int(d)
                    h[i] = h[i] + d * (h[j] - h[i]) / (n[j] - n[i])
                n[i] += d

    def value(self) -> float | None:
        """Current estimate; None before any observation."""
        if self.count == 0:
            return None
        if self.count < 5:
            s = sorted(self._h)
            return s[min(int(self.q * len(s)), len(s) - 1)]
        return self._h[2]


class _BurnWindow:
    """Sliding-window violation counter -> burn rate."""

    __slots__ = ("horizon", "_events", "bad", "n")

    def __init__(self, horizon_s: float):
        self.horizon = horizon_s
        self._events: deque[tuple[float, bool]] = deque()
        self.bad = 0
        self.n = 0

    def add(self, t: float, violated: bool) -> None:
        self._events.append((t, violated))
        self.n += 1
        if violated:
            self.bad += 1
        self._expire(t)

    def _expire(self, now: float) -> None:
        cutoff = now - self.horizon
        ev = self._events
        while ev and ev[0][0] < cutoff:
            _, v = ev.popleft()
            self.n -= 1
            if v:
                self.bad -= 1

    def burn(self, now: float, error_budget: float) -> float:
        """``violation_rate / error_budget`` over the window; 0 when empty."""
        self._expire(now)
        if self.n == 0:
            return 0.0
        rate = self.bad / self.n
        if error_budget <= 0.0:
            return float("inf") if rate > 0.0 else 0.0
        return rate / error_budget


class _TenantState:
    __slots__ = ("ttft_p50", "ttft_p99", "tbt_p50", "tbt_p99",
                 "ttft_windows", "tbt_windows",
                 "ttft_n", "ttft_bad", "tbt_n", "tbt_bad", "last_t")

    def __init__(self, windows_s):
        self.ttft_p50 = P2Quantile(0.5)
        self.ttft_p99 = P2Quantile(0.99)
        self.tbt_p50 = P2Quantile(0.5)
        self.tbt_p99 = P2Quantile(0.99)
        self.ttft_windows = {w: _BurnWindow(w) for w in windows_s}
        self.tbt_windows = {w: _BurnWindow(w) for w in windows_s}
        self.ttft_n = self.ttft_bad = 0
        self.tbt_n = self.tbt_bad = 0
        self.last_t = 0.0


class SLOMonitor:
    """Per-tenant streaming TTFT/TBT quantiles + SLO burn rate.

    ``target`` is the attainment objective (0.99 -> a 1% error budget);
    ``burn_warn`` / ``burn_page`` translate window burn rates into a
    status: any window at/above ``burn_warn`` -> ``warn``, any at/above
    ``burn_page`` -> ``page`` (the SRE fast-burn page)."""

    def __init__(
        self,
        *,
        ttft_slo_s: float | None = None,
        tbt_slo_s: float | None = None,
        windows_s=DEFAULT_WINDOWS_S,
        target: float = 0.99,
        burn_warn: float = 1.0,
        burn_page: float = 10.0,
    ):
        self.default_slo = (ttft_slo_s, tbt_slo_s)
        self.windows_s = tuple(float(w) for w in windows_s)
        self.target = target
        self.error_budget = 1.0 - target
        self.burn_warn = burn_warn
        self.burn_page = burn_page
        self._slos: dict[str, tuple[float | None, float | None]] = {}
        self._tenants: dict[str, _TenantState] = {}
        self._now = 0.0

    # -- configuration -------------------------------------------------------
    def set_slo(self, tenant: str, *, ttft_slo_s: float | None = None,
                tbt_slo_s: float | None = None) -> None:
        """Per-tenant SLO override (falls back to the constructor default)."""
        self._slos[tenant] = (ttft_slo_s, tbt_slo_s)

    def _slo_for(self, tenant: str) -> tuple[float | None, float | None]:
        return self._slos.get(tenant, self.default_slo)

    def _state(self, tenant: str) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantState(self.windows_s)
        return st

    # -- observation ---------------------------------------------------------
    def observe_ttft(self, tenant: str, t: float, value: float) -> None:
        st = self._state(tenant)
        st.ttft_p50.observe(value)
        st.ttft_p99.observe(value)
        slo = self._slo_for(tenant)[0]
        bad = slo is not None and value > slo
        st.ttft_n += 1
        st.ttft_bad += bad
        for w in st.ttft_windows.values():
            w.add(t, bad)
        st.last_t = max(st.last_t, t)
        self._now = max(self._now, t)

    def observe_tbt(self, tenant: str, t: float, value: float) -> None:
        st = self._state(tenant)
        st.tbt_p50.observe(value)
        st.tbt_p99.observe(value)
        slo = self._slo_for(tenant)[1]
        bad = slo is not None and value > slo
        st.tbt_n += 1
        st.tbt_bad += bad
        for w in st.tbt_windows.values():
            w.add(t, bad)
        st.last_t = max(st.last_t, t)
        self._now = max(self._now, t)

    def ingest_spans(self, spans, tenant: str = "default") -> int:
        """Feed finished ``request`` root spans (the tracer's stream): each
        span's ``ttft`` attr is observed at its completion time.  Returns
        the number of requests ingested."""
        n = 0
        for sp in spans:
            if getattr(sp, "name", None) != "request":
                continue
            ttft = sp.attrs.get("ttft")
            if ttft is None:
                continue
            t = sp.t1 if sp.t1 is not None else sp.t0 + float(ttft)
            self.observe_ttft(sp.attrs.get("tenant", tenant), t, float(ttft))
            n += 1
        return n

    # -- reporting -----------------------------------------------------------
    def _status(self, burns: dict[str, float]) -> str:
        worst = max(burns.values(), default=0.0)
        if worst >= self.burn_page:
            return "page"
        if worst >= self.burn_warn:
            return "warn"
        return "ok"

    def tenant_health(self, tenant: str, now: float | None = None) -> dict:
        st = self._state(tenant)
        now = self._now if now is None else now
        burns = {}
        for w in self.windows_s:
            b_ttft = st.ttft_windows[w].burn(now, self.error_budget)
            b_tbt = st.tbt_windows[w].burn(now, self.error_budget)
            burns[f"{w:g}s"] = max(b_ttft, b_tbt)
        return {
            "requests": st.ttft_n,
            "ttft_p50_s": st.ttft_p50.value(),
            "ttft_p99_s": st.ttft_p99.value(),
            "tbt_p50_s": st.tbt_p50.value(),
            "tbt_p99_s": st.tbt_p99.value(),
            "ttft_attainment": (
                1.0 - st.ttft_bad / st.ttft_n if st.ttft_n else None
            ),
            "tbt_attainment": (
                1.0 - st.tbt_bad / st.tbt_n if st.tbt_n else None
            ),
            "burn_rate": burns,
            "status": self._status(burns),
        }

    def fleet_health(self, now: float | None = None) -> dict:
        """The fleet-readable summary: per-tenant health + the worst status
        fleet-wide.  JSON-ready (no NaN/inf for empty tenants — absent data
        is None)."""
        now = self._now if now is None else now
        tenants = {
            name: self.tenant_health(name, now) for name in sorted(self._tenants)
        }
        order = {"ok": 0, "warn": 1, "page": 2}
        worst = max(
            (t["status"] for t in tenants.values()),
            key=lambda s: order[s],
            default="ok",
        )
        return {
            "now": now,
            "target": self.target,
            "windows_s": list(self.windows_s),
            "tenants": tenants,
            "status": worst,
        }
