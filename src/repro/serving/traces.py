"""Compatibility shim: trace generators moved to :mod:`repro.workloads.traces`.

The generators started life here, but ``repro.core.simulator`` sizes its
per-request KV flows from :func:`request_kv_bytes` — a ``core -> serving``
import that violated the layering DAG (simcheck rule ``layering``).  The
implementation now lives in ``repro.workloads`` at the bottom of the DAG;
this module keeps every historical ``from repro.serving import traces``
call site working.
"""

from repro.workloads.traces import (  # noqa: F401
    TRACES,
    _emit,
    _lognormal_tokens,
    azure_code,
    azure_conv,
    burstgpt,
    kv_volumes,
    multi_model_mix,
    request_kv_bytes,
    scale_to_capacity,
    zipf_weights,
)

__all__ = [
    "TRACES",
    "azure_code",
    "azure_conv",
    "burstgpt",
    "kv_volumes",
    "multi_model_mix",
    "request_kv_bytes",
    "scale_to_capacity",
    "zipf_weights",
]
